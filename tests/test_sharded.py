"""Sharded incremental recoloring (DESIGN.md §15): differential 1-shard
bit-identity, multi-shard properness, re-plans, the ColoringService path,
and the degradation ladder — all on forced host CPU devices.

Same trick as test_distributed.py: conftest pins the main pytest process to
one device, so the mesh cases run in a dedicated subprocess that sets
XLA_FLAGS before importing jax and reports one JSON blob on stdout.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import json
import numpy as np
import jax
from repro import api
from repro.core import coloring as col
from repro.dynamic import (ColoringService, ShardedColoringState, delta,
                           recolor_sharded)
from repro.dynamic.incremental import recolor_incremental
from repro.graphs import generators as gen
from repro.obs import metrics as obs_metrics
from repro.resilience import ladder

out = {}
g = gen.mesh2d(24, 24)
n = g.n_vertices

def stream(seed, k):
    rng = np.random.default_rng(seed)
    for _ in range(k):
        ins = rng.integers(0, n, size=(40, 2)).astype(np.int64)
        dels = rng.integers(0, n, size=(15, 2)).astype(np.int64)
        yield ins[ins[:, 0] != ins[:, 1]], dels

# -- 1-shard differential: the sharded stack replays mode="incremental"
# bit-for-bit (same seed, same update stream) --------------------------------
mesh1 = jax.make_mesh((1,), ("data",))
r_ref = api.color(g, mode="incremental", seed=0)
r_sh = api.color(g, mode="incremental", backend="distributed", mesh=mesh1,
                 seed=0)
ident = bool(np.array_equal(r_ref.colors, r_sh.colors))
st_ref, st_sh = r_ref.state, r_sh.state
for ins, dels in stream(7, 5):
    st_ref = recolor_incremental(st_ref, ins, dels)
    st_sh = recolor_sharded(st_sh, ins, dels)
    ident = ident and bool(np.array_equal(st_ref.colors, st_sh.colors))
    ident = ident and (st_ref.C, st_ref.last_rounds, st_ref.last_conflicts,
                       st_ref.last_gather_passes) == \
        (st_sh.C, st_sh.last_rounds, st_sh.last_conflicts,
         st_sh.last_gather_passes)
out["one_shard"] = {"identical": ident,
                    "halo_bytes": int(st_sh.last_halo_bytes)}

# -- multi-shard: proper within the static color envelope, replans heal -----
for D in (4, 8):
    mesh = jax.make_mesh((D,), ("data",))
    st = api.color(g, mode="incremental", backend="distributed", mesh=mesh,
                   seed=0).state
    proper = bool(col.is_proper(g, st.colors))
    for ins, dels in stream(11, 4):
        st = recolor_sharded(st, ins, dels)
        proper = proper and bool(col.is_proper(delta.state_to_csr(st),
                                               st.colors))
    rng = np.random.default_rng(13)
    big = rng.integers(0, n, size=(3000, 2)).astype(np.int64)
    st = recolor_sharded(st, big[big[:, 0] != big[:, 1]], None)
    proper = proper and bool(col.is_proper(delta.state_to_csr(st),
                                           st.colors))
    out[f"shards{D}"] = {
        "proper": proper, "colors": int(st.n_colors),
        "bound": int(delta.state_to_csr(st).max_degree + 1),
        "replans": int(st.replans),
        "halo_bytes_per_round": int(st.halo_bytes_per_round),
        "n_shards_in_summary": int(st.summary()["n_shards"]),
    }

# -- service: sharded tenant next to a local one; halo-bytes counter,
# snapshot/restore, artifact queries ----------------------------------------
mesh8 = jax.make_mesh((8,), ("data",))
svc = ColoringService(megabatch=True)
svc.add_graph("sh", g, mesh=mesh8, seed=0)
svc.add_graph("loc", g, seed=0)
rng = np.random.default_rng(3)
for _ in range(2):
    ins = rng.integers(0, n, size=(25, 2)).astype(np.int64)
    ins = ins[ins[:, 0] != ins[:, 1]]
    svc.submit("sh", inserts=ins)
    svc.submit("loc", inserts=ins)
    svc.step()
sh_proper = bool(col.is_proper(svc.graph("sh"), svc.colors("sh")))
loc_proper = bool(col.is_proper(svc.graph("loc"), svc.colors("loc")))
hb = int(obs_metrics.counter("service.halo_bytes", tenant="sh").value)
hb_loc = int(obs_metrics.counter("service.halo_bytes", tenant="loc").value)
snap = svc.snapshot("sh")
svc.submit("sh", inserts=np.array([[0, 5]], np.int64))
svc.step("sh")
v_after = svc.restore("sh", snap)
sched = svc.vertex_schedule("sh")
out["service"] = {
    "sh_proper": sh_proper, "loc_proper": loc_proper,
    "sharded_is_sharded": isinstance(svc.snapshot("sh"),
                                     ShardedColoringState),
    "halo_bytes": hb, "halo_bytes_local": hb_loc,
    "restore_version": int(v_after),
    "schedule_covers": int(sum(len(c) for c in sched)) == n,
}

# -- ladder: budget exhaustion degrades with rung attribution ---------------
st = api.color(g, mode="incremental", backend="distributed", mesh=mesh8,
               seed=0).state
st_poor = dataclasses.replace(st, C=1, max_cap_retries=0)
# insert edges between same-colored vertices: guaranteed conflicts, and
# repairing them under C=1 must overflow the cap immediately
c0 = st.colors
ins = np.array([(u, v) for u in range(40) for v in range(u + 1, 60)
                if c0[u] == c0[v]][:16], np.int64)
st2, rung = ladder.apply_with_ladder(st_poor, ins, np.zeros((0, 2),
                                                            np.int64))
st3 = ladder.oracle_state(st_poor, ins, np.zeros((0, 2), np.int64))
out["ladder"] = {
    "rung": int(rung),
    "still_sharded": isinstance(st2, ShardedColoringState),
    "proper": bool(col.is_proper(delta.state_to_csr(st2), st2.colors)),
    "attributed": int(st2.last_degrade_rung) == int(rung),
    "oracle_rung": int(st3.last_degrade_rung),
    "oracle_proper": bool(col.is_proper(delta.state_to_csr(st3),
                                        st3.colors)),
}
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def sharded_results():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=500)
    assert p.returncode == 0, p.stderr[-2000:]
    return json.loads(p.stdout.strip().splitlines()[-1])


def test_one_shard_bit_identity(sharded_results):
    """The ISSUE's differential bar: a 1-shard mesh replays the
    single-device incremental engine bit-for-bit across a 5-batch update
    stream — colors AND (C, rounds, conflicts, gather passes)."""
    r = sharded_results["one_shard"]
    assert r["identical"]
    assert r["halo_bytes"] > 0


def test_multi_shard_proper_within_envelope(sharded_results):
    for D in (4, 8):
        r = sharded_results[f"shards{D}"]
        assert r["proper"], r
        assert r["colors"] <= r["bound"], r
        assert r["n_shards_in_summary"] == D


def test_replan_heals_capacity(sharded_results):
    """The 3000-edge batch must overflow the initial halo slack and force
    at least one re-plan — and the coloring stays proper through it."""
    assert sharded_results["shards8"]["replans"] >= 1


def test_halo_bytes_boundary_not_n(sharded_results):
    """Bytes/round ∝ boundary: the 8-shard payload must stay well under an
    O(n) all-gather of the 576-vertex mesh's colors."""
    r = sharded_results["shards8"]
    assert 0 < r["halo_bytes_per_round"] < 8 * 4 * 576


def test_service_sharded_tenant(sharded_results):
    r = sharded_results["service"]
    assert r["sh_proper"] and r["loc_proper"]
    assert r["sharded_is_sharded"]
    assert r["halo_bytes"] > 0          # counted for the sharded tenant
    assert r["halo_bytes_local"] == 0   # never for the local one
    assert r["schedule_covers"]
    assert r["restore_version"] > 0


def test_ladder_on_sharded_state(sharded_results):
    r = sharded_results["ladder"]
    assert r["rung"] >= 1 and r["attributed"]
    assert r["still_sharded"] and r["proper"]
    assert r["oracle_rung"] == 2 and r["oracle_proper"]


def test_mesh_required():
    """The engine names the fix when called without a mesh (parent process:
    no multi-device requirement)."""
    from repro import api
    from repro.graphs import generators as gen
    with pytest.raises(ValueError, match="requires a device mesh"):
        api.color(gen.mesh2d(4, 4), mode="incremental",
                  backend="distributed")
