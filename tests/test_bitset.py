"""Bit-parity property tests: packed-bitset forbidden sets vs the dense
oracle (DESIGN.md §10).

Unit level: pack / scatter-then-pack / mex / overflow must agree with the
dense (rows, C) table + argmin formulation exactly, across word-aligned and
ragged caps.  Engine level: every coloring engine run with
``forbidden_impl="bitset"`` must reproduce the ``"dense"`` run bit-for-bit
(colors AND summary — rounds, conflicts, retries), including the overflow
COO side-channel, the native distance-2 two-hop path, and bipartite partial
coloring, on rmat/mesh/bipartite families.

Hypothesis-optional with a seeded-numpy fallback, like the rest of the
harness (the container has no network; hard-requiring hypothesis would make
the module uncollectable).
"""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro import api
from repro.core import bitset
from repro.core import coloring as col
from repro.core import distance2 as d2
from repro.graphs import generators as gen

CAPS = (32, 64, 96, 256)


# --------------------------------------------------------------------------
# unit parity: pack / mex / overflow vs the dense formulation
# --------------------------------------------------------------------------

def _dense_forbidden(nbrc, C):
    return np.asarray(col._forbidden_from_nbrc(jnp.asarray(nbrc), C))


def _rand_nbrc(rng, rows, W, C):
    """Neighbor-color panels incl. FILL (-1) and out-of-cap colors."""
    nbrc = rng.integers(-1, int(C * 1.25) + 2, size=(rows, W))
    return nbrc.astype(np.int32)


@pytest.mark.parametrize("C", CAPS)
def test_pack_matches_dense_table(C):
    rng = np.random.default_rng(C)
    nbrc = _rand_nbrc(rng, 64, 17, C)
    words = bitset.pack_from_nbrc(jnp.asarray(nbrc), C)
    assert words.shape == (64, bitset.n_words(C))
    np.testing.assert_array_equal(np.asarray(bitset.to_dense(words, C)),
                                  _dense_forbidden(nbrc, C))


@pytest.mark.parametrize("C", CAPS)
def test_mex_and_overflow_match_dense(C):
    rng = np.random.default_rng(100 + C)
    # mix of sparse rows, saturated rows (every color 0..C-1 present), and
    # all-FILL rows — the three mex regimes
    sparse = _rand_nbrc(rng, 32, 9, C)
    full = np.tile(np.arange(C, dtype=np.int32), (8, 1))
    empty = np.full((8, C), -1, np.int32)
    for nbrc in (sparse, np.concatenate([full, empty])):
        nbrc_j = jnp.asarray(nbrc)
        dense = col._forbidden_from_nbrc(nbrc_j, C)
        want_mex, want_ovf = col._mex(dense)
        got_mex, got_ovf = bitset.mex_words(
            bitset.pack_from_nbrc(nbrc_j, C), C)
        np.testing.assert_array_equal(np.asarray(got_mex),
                                      np.asarray(want_mex))
        np.testing.assert_array_equal(np.asarray(got_ovf),
                                      np.asarray(want_ovf))


@pytest.mark.parametrize("C", [4, 40, 97])
def test_ragged_caps_tail_masked(C):
    """Caps that are not multiples of 32: tail bits must be pre-forbidden,
    mex must never return >= C, overflow must mean 'all C colors taken'."""
    rng = np.random.default_rng(C)
    nbrc = _rand_nbrc(rng, 48, 11, C)
    words = bitset.pack_from_nbrc(jnp.asarray(nbrc), C)
    dense = col._forbidden_from_nbrc(jnp.asarray(nbrc), C)
    want_mex, want_ovf = col._mex(dense)
    got_mex, got_ovf = bitset.mex_words(words, C)
    np.testing.assert_array_equal(np.asarray(got_mex), np.asarray(want_mex))
    np.testing.assert_array_equal(np.asarray(got_ovf), np.asarray(want_ovf))
    assert int(np.asarray(got_mex).max()) < C
    # saturated row at a ragged cap
    sat = np.tile(np.arange(C, dtype=np.int32), (2, 1))
    m, o = bitset.mex_words(bitset.pack_from_nbrc(jnp.asarray(sat), C), C)
    assert bool(np.asarray(o).all()) and int(np.asarray(m).max()) == 0


@pytest.mark.parametrize("C", CAPS)
def test_scatter_then_pack_matches_dense_coo(C):
    """COO snapshot route: dense scatter -> pack == dense scatter."""
    rng = np.random.default_rng(C + 7)
    n_rows, m = 50, 300
    src = rng.integers(-1, n_rows, size=m).astype(np.int32)
    dst = rng.integers(-1, n_rows, size=m).astype(np.int32)
    colors = rng.integers(-1, C + 20, size=n_rows).astype(np.int32)
    a = (jnp.asarray(src), jnp.asarray(dst), jnp.asarray(colors))
    dense = col._forbidden_coo(*a, n_rows, C)
    packed = col._snapshot_coo(*a, n_rows, C, "bitset")
    np.testing.assert_array_equal(
        np.asarray(bitset.to_dense(packed, C)), np.asarray(dense))
    # and the merged mex agrees
    wm, wo = col._mex(dense)
    gm, go = bitset.mex_words(packed, C)
    np.testing.assert_array_equal(np.asarray(gm), np.asarray(wm))
    np.testing.assert_array_equal(np.asarray(go), np.asarray(wo))


def test_or_color_incremental_equals_batch_pack():
    """The kernels' per-column inline pack == the batch pack."""
    rng = np.random.default_rng(5)
    C, rows, W = 96, 40, 13
    nbrc = _rand_nbrc(rng, rows, W, C)
    forb = bitset.init_words(rows, C)
    for j in range(W):
        forb = bitset.or_color(forb, jnp.asarray(nbrc[:, j]), C)
    np.testing.assert_array_equal(
        np.asarray(forb),
        np.asarray(bitset.pack_from_nbrc(jnp.asarray(nbrc), C)))


def test_ws_accounting():
    """The advertised shrink: 8x at word-aligned caps (4x floor at C=128
    is the acceptance bar the benchmarks report)."""
    for C in CAPS:
        dense = bitset.ws_bytes(1000, C, "dense")
        packed = bitset.ws_bytes(1000, C, "bitset")
        assert dense == 1000 * C and packed == 1000 * bitset.n_words(C) * 4
        assert dense / packed >= 4.0
    assert bitset.ws_bytes(1, 128, "dense") / bitset.ws_bytes(
        1, 128, "bitset") == 8.0
    with pytest.raises(ValueError):
        bitset.ws_bytes(1, 32, "nope")


def test_unknown_impl_rejected():
    with pytest.raises(ValueError):
        api.color(gen.mesh2d(4, 4), forbidden_impl="packed")


# --------------------------------------------------------------------------
# engine-level differential: bitset run == dense run, bit for bit
# --------------------------------------------------------------------------

GRAPHS = {
    "rmat_b": lambda: gen.rmat_b(9, edge_factor=8),
    "mesh3d": lambda: gen.mesh3d(5, 5, 5),
    "bipartite": lambda: gen.bipartite_random(150, 100, 4.0, seed=7),
}


def _assert_identical(rb, rd, what):
    np.testing.assert_array_equal(rb.colors, rd.colors, err_msg=what)
    assert rb.summary() == rd.summary(), what


@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("algo", sorted(col.ALGORITHMS))
def test_engine_bitset_equals_dense(gname, algo):
    g = GRAPHS[gname]()
    fn = col.ALGORITHMS[algo]
    _assert_identical(fn(g, seed=7, forbidden_impl="bitset"),
                      fn(g, seed=7, forbidden_impl="dense"),
                      f"{algo}/{gname}")


@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_compact_bitset_equals_dense(gname):
    g = GRAPHS[gname]()
    _assert_identical(api.color(g, algorithm="rsoc_compact", seed=3, forbidden_impl="bitset"),
                      api.color(g, algorithm="rsoc_compact", seed=3, forbidden_impl="dense"),
                      f"rsoc_compact/{gname}")


def test_overflow_coo_bitset_equals_dense():
    """Capped-width hubs spill into the COO side-channel: the packed
    snapshot path (scatter-then-pack) must reproduce the dense run."""
    g = gen.rmat_b(9, edge_factor=16)
    rb = api.color(g, algorithm="rsoc", seed=3, ell_cap=8, forbidden_impl="bitset")
    rd = api.color(g, algorithm="rsoc", seed=3, ell_cap=8, forbidden_impl="dense")
    _assert_identical(rb, rd, "rsoc/ovf")
    assert col.is_proper(g, rb.colors)
    cb = api.color(g, algorithm="rsoc_compact", seed=3, ell_cap=8, forbidden_impl="bitset")
    cd = api.color(g, algorithm="rsoc_compact", seed=3, ell_cap=8, forbidden_impl="dense")
    _assert_identical(cb, cd, "rsoc_compact/ovf")


@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_distance2_bitset_equals_dense(gname):
    g = GRAPHS[gname]()
    nb = api.color(g, distance=2, seed=1, forbidden_impl="bitset")
    nd = api.color(g, distance=2, seed=1, forbidden_impl="dense")
    _assert_identical(nb, nd, f"d2/{gname}")
    assert d2.is_distance_d_proper(g, nb.colors, 2)


def test_bipartite_partial_bitset_equals_dense():
    g = GRAPHS["bipartite"]()
    pb = api.color(g, distance=2, mode="partial", n_left=150, seed=1, forbidden_impl="bitset")
    pd = api.color(g, distance=2, mode="partial", n_left=150, seed=1, forbidden_impl="dense")
    _assert_identical(pb, pd, "bipartite_partial")
    assert d2.is_bipartite_partial_proper(g, 150, pb.colors)


def test_cap_doubling_retry_bitset_equals_dense():
    """Force overflow (tiny explicit C) so the shared _run_with_retry
    doubles the cap: retry trajectory must match across impls."""
    g = gen.mesh2d(12, 12)
    rb = api.color(g, algorithm="rsoc", seed=0, C=2, forbidden_impl="bitset")
    rd = api.color(g, algorithm="rsoc", seed=0, C=2, forbidden_impl="dense")
    _assert_identical(rb, rd, "retry")
    assert rb.retries > 0 and rb.overflow


# --------------------------------------------------------------------------
# randomized sweeps across caps (hypothesis when available, numpy fallback)
# --------------------------------------------------------------------------

def _check_pack_mex(nbrc, C):
    nbrc_j = jnp.asarray(nbrc)
    dense = col._forbidden_from_nbrc(nbrc_j, C)
    want = col._mex(dense)
    got = bitset.mex_words(bitset.pack_from_nbrc(nbrc_j, C), C)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2**31 - 1), st.sampled_from(CAPS),
           st.integers(1, 40), st.integers(1, 24))
    @settings(max_examples=30, deadline=None)
    def test_property_pack_mex_parity(seed, C, rows, W):
        rng = np.random.default_rng(seed)
        _check_pack_mex(_rand_nbrc(rng, rows, W, C), C)
else:
    @pytest.mark.parametrize("case", range(10))
    def test_property_pack_mex_parity(case):
        rng = np.random.default_rng(6000 + case)
        C = CAPS[case % len(CAPS)]
        rows, W = int(rng.integers(1, 40)), int(rng.integers(1, 24))
        _check_pack_mex(_rand_nbrc(rng, rows, W, C), C)
