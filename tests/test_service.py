"""ColoringService megabatched stepping + lifecycle semantics (DESIGN.md
§13): the stacked fast path must be bit-identical to the per-tenant loop
(including when a tenant escapes to the retry path), planning must be
bit-identical to per-tenant planning, and the service's cache/metrics
lifecycle must not leak state across remove/re-add or rollback."""
import numpy as np
import pytest

from repro.core import coloring as col
from repro.dynamic import (ArtifactCache, ColoringService, slot_key,
                           state_to_csr)
from repro.dynamic import delta
from repro.graphs import generators as gen
from repro.obs import metrics as obs_metrics

# One slot class across tenants: explicit shape knobs + ell_cap below the
# max degree (see megabatch.slot_key).  Small shapes keep the fused-step
# compile fast in CI.
OPTS = dict(seed=0, n_chunks=2, ell_cap=6, C=16, ovf_cap=64, delta_cap=32,
            frontier_frac=0.5)


def _pair(n_tenants=3, n=64, **over):
    """(loop_svc, mega_svc) with identically-seeded same-shape tenants."""
    opts = {**OPTS, **over}
    pair = []
    for mega in (False, True):
        svc = ColoringService(megabatch=mega, **opts)
        for i in range(n_tenants):
            svc.add_graph(f"g{i}", gen.erdos_renyi(n, 5.0, seed=i))
        pair.append(svc)
    keys = {slot_key(pair[1].snapshot(f"g{i}")) for i in range(n_tenants)}
    assert len(keys) == 1, keys
    return pair


def _submit_stream(svcs, n_tenants, n, steps, bpp=2, seed=3):
    """Submit identical random batches to every service, step, repeat."""
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        for t in range(n_tenants):
            for _b in range(bpp):
                ins = rng.integers(0, n, (6, 2))
                ins = ins[ins[:, 0] != ins[:, 1]]
                dels = rng.integers(0, n, (3, 2))
                for svc in svcs:
                    svc.submit(f"g{t}", inserts=ins, deletes=dels)
        for svc in svcs:
            svc.step()


def _assert_identical(loop_svc, mega_svc, n_tenants):
    for i in range(n_tenants):
        nm = f"g{i}"
        assert np.array_equal(loop_svc.colors(nm), mega_svc.colors(nm)), nm
        assert loop_svc.version(nm) == mega_svc.version(nm), nm
        st = mega_svc.snapshot(nm)
        assert col.is_proper(state_to_csr(st), st.colors), nm


# --------------------------------------------------------------------------
# megabatched step: bit-identical to the per-tenant loop
# --------------------------------------------------------------------------

def test_mega_step_bit_identical_to_loop():
    n_tenants, n = 3, 64
    loop_svc, mega_svc = _pair(n_tenants, n)
    bat0 = obs_metrics.counter_value("service.mega", outcome="batched")
    _submit_stream([loop_svc, mega_svc], n_tenants, n, steps=3)
    _assert_identical(loop_svc, mega_svc, n_tenants)
    # the fast path actually ran (and charged its outcome counter)
    assert obs_metrics.counter_value("service.mega",
                                     outcome="batched") > bat0


def test_mega_escape_bit_identical_to_loop():
    """A tenant blowing past its color cap escapes the stacked dispatch to
    the per-tenant retry path mid-group; every tenant must still land
    bit-identical to the loop service."""
    n_tenants, n = 3, 64
    loop_svc, mega_svc = _pair(n_tenants, n, C=8)
    esc0 = (obs_metrics.counter_value("service.mega", outcome="escaped")
            + obs_metrics.counter_value("service.mega", outcome="solo"))
    # K_12 on tenant 0 needs 12 colors > C=8: cap-doubling retry territory
    k = 12
    ii, jj = np.meshgrid(np.arange(k), np.arange(k))
    clique = np.stack([ii[ii < jj], jj[ii < jj]], 1)
    rng = np.random.default_rng(5)
    others = [rng.integers(0, n, (6, 2)) for _ in range(1, n_tenants)]
    for svc in (loop_svc, mega_svc):
        svc.submit("g0", inserts=clique)
        for t in range(1, n_tenants):
            svc.submit(f"g{t}", inserts=others[t - 1])
    loop_svc.step()
    mega_svc.step()
    _assert_identical(loop_svc, mega_svc, n_tenants)
    assert mega_svc.snapshot("g0").n_colors >= k
    assert (obs_metrics.counter_value("service.mega", outcome="escaped")
            + obs_metrics.counter_value("service.mega",
                                        outcome="solo")) > esc0


def test_megabatch_min_falls_back_to_loop():
    svc = ColoringService(megabatch=True, megabatch_min=4, **OPTS)
    for i in range(2):
        svc.add_graph(f"g{i}", gen.erdos_renyi(64, 5.0, seed=i))
    n0 = obs_metrics.counter_value("service.mega", outcome="loop")
    for i in range(2):
        svc.submit(f"g{i}", inserts=[[0, 9]])
    svc.step()
    assert obs_metrics.counter_value("service.mega",
                                     outcome="loop") == n0 + 2


# --------------------------------------------------------------------------
# group planning: bit-identical to per-tenant plan_updates
# --------------------------------------------------------------------------

def test_plan_group_matches_plan_updates():
    rng = np.random.default_rng(17)
    cap, n_pad = 8, 64
    for trial in range(25):
        n_slots = int(rng.integers(1, 5))
        batches = []
        for _ in range(n_slots):
            k_i, k_d = rng.integers(0, 30, 2)      # over-cap waves included
            ins = rng.integers(0, n_pad, (k_i, 2)).astype(np.int32)
            dels = rng.integers(0, n_pad, (k_d, 2)).astype(np.int32)
            batches.append((ins, dels))
        ovf_w, ell_w, ins_w, touched = delta.plan_group(batches, cap, n_pad)
        for b, (ins, dels) in enumerate(batches):
            ref = delta.plan_updates(ins, dels, cap, n_pad)
            for got, want in ((ovf_w, ref.ovf_del), (ell_w, ref.ell_del),
                              (ins_w, ref.ins)):
                for j in range(got.shape[0]):
                    exp = want[j] if j < len(want) else delta.empty_wave(cap)
                    assert np.array_equal(got[j, b], exp), (trial, b, j)
            assert np.array_equal(touched[b], ref.touched), (trial, b)


# --------------------------------------------------------------------------
# lifecycle: remove/re-add, snapshot/rollback, eviction, max_rounds
# --------------------------------------------------------------------------

def test_remove_readd_clears_tenant_metrics():
    # metrics are process-global and keyed by graph name: use a name no
    # other test steps, so the absolute count asserts can't be polluted
    nm = "readd-metrics-tenant"
    svc = ColoringService(**OPTS)
    svc.add_graph(nm, gen.mesh2d(8, 8))
    svc.submit(nm, inserts=[[0, 9]])
    svc.step(nm)
    assert svc.step_latency(nm)["count"] == 1
    svc.remove_graph(nm)
    svc.add_graph(nm, gen.mesh2d(8, 8))
    # the re-added tenant must not inherit the departed tenant's histogram
    assert svc.step_latency(nm)["count"] == 0


def test_snapshot_rollback_reversions_above_current():
    svc = ColoringService(**OPTS)
    svc.add_graph("g", gen.mesh2d(8, 8))
    snap = svc.snapshot("g")
    colors0 = svc.colors("g").copy()
    sched0 = svc.vertex_schedule("g")

    for _ in range(2):
        svc.submit("g", inserts=[[0, 9], [3, 17]])
        svc.step("g")
    v_stepped = svc.version("g")
    assert v_stepped == snap.version + 2

    v_restored = svc.restore("g", snap)
    # re-versioned ABOVE everything seen: a version number may never repeat
    # with different contents or the memo would serve stale artifacts
    assert v_restored > v_stepped
    np.testing.assert_array_equal(svc.colors("g"), colors0)
    # memoized artifact from the snapshot's ORIGINAL version is not served
    # for the restored state; it is rebuilt under the new version
    sched1 = svc.vertex_schedule("g")
    assert sched1 is not sched0
    assert svc.vertex_schedule("g") is sched1

    with pytest.raises(ValueError):
        svc.restore("g", _other_size_snap(svc))    # wrong graph size
    with pytest.raises(TypeError):
        svc.restore("g", object())


def _other_size_snap(svc):
    tmp = ColoringService(**OPTS)
    tmp.add_graph("t", gen.mesh2d(4, 4))
    return tmp.snapshot("t")


def test_artifact_cache_eviction_semantics():
    cache = ArtifactCache(budget_bytes=2048)
    a = np.zeros(300, np.int64)                    # 2400 B: alone over budget
    # the just-inserted artifact is never evicted in the same breath, even
    # when it alone exceeds the budget
    assert cache.put(("g", "a"), 0, a) == []
    assert len(cache) == 1 and cache.get(("g", "a"), 0) is not None
    # a second insert evicts the LRU first entry
    b = np.zeros(200, np.int64)
    assert cache.put(("g", "b"), 0, b) == [("g", "a")]
    assert cache.get(("g", "a"), 0) is None
    assert cache.get(("g", "b"), 0) is not None
    # version mismatch is a miss, not a stale hit
    assert cache.get(("g", "b"), 1) is None
    cache.drop_name("g")
    assert len(cache) == 0 and cache.nbytes == 0


def test_service_memo_eviction_counter_and_requery():
    svc = ColoringService(memo_budget_mb=1e-4, **OPTS)   # ~100 B budget
    svc.add_graph("g", gen.mesh2d(8, 8))
    ev0 = obs_metrics.counter_value("service.memo", kind="vertex_schedule",
                                    outcome="evict")
    sched = svc.vertex_schedule("g")               # admitted despite budget
    assert all(np.array_equal(a, b)
               for a, b in zip(sched, svc.vertex_schedule("g")))
    svc.edge_colors("g")        # evicts the schedule (and csr along the way)
    assert obs_metrics.counter_value("service.memo", kind="vertex_schedule",
                                     outcome="evict") == ev0 + 1
    # evicted artifact is simply rebuilt on re-query — same contents
    again = svc.vertex_schedule("g")
    assert all(np.array_equal(a, b) for a, b in zip(sched, again))


def test_max_rounds_persisted_from_spec():
    svc = ColoringService(max_rounds=1, **OPTS)
    svc.add_graph("g", gen.mesh2d(8, 8))
    assert svc.snapshot("g").max_rounds == 1
    svc.submit("g", inserts=[[0, 9], [1, 10]])
    svc.step("g")
    # the persisted bound caps every subsequent incremental repair
    assert svc.snapshot("g").last_rounds <= 1


def test_step_stats_lazy_mapping():
    svc = ColoringService(**OPTS)
    for i in range(2):
        svc.add_graph(f"g{i}", gen.mesh2d(8, 8))
    svc.submit("g0", inserts=[[0, 9]])
    stats = svc.step()
    assert set(stats) == {"g0", "g1"} and len(stats) == 2
    d = stats["g0"]
    assert d["version"] == 1 and "rounds" in d
    assert stats["g0"] is d                        # computed once, cached
