"""Env-driven chaos sweep (``make chaos``, DESIGN.md §14.5).

Skipped entirely unless ``REPRO_FAULTS`` is set — the driver arms one fault
class per invocation (under both kernel backends) and this module pushes a
fixed multi-tenant workload through a ``ColoringService``, asserting the
recovery matrix's promises:

  * after every step, every committed (non-quarantined) state is proper and
    its version is monotone — no half-applied batch is ever observable;
  * the faulted run is **deterministic**: an identically-seeded second run
    (same spec, ``faults.reset()`` between) commits bit-identical states
    and quarantines the same tenants for the same reasons;
  * every quarantined tenant carries a structured reason, still serves its
    last-good proper coloring, and — once the fault is suppressed — heals
    back to a proper state with its dead letters replayed;
  * for *non-degrading* fault classes (everything except ``cap.exhaust`` /
    ``ovf.exhaust``), the healed+drained service is **bit-identical** to a
    fault-free reference run over the accepted batches; degrading classes
    commit proper-but-different colorings (the ladder's contract), which
    the determinism assertion pins instead.

Dead letters observed before healing are exported as JSONL when
``REPRO_DEADLETTER_DIR`` is set (uploaded as CI chaos artifacts).
"""
import os

import numpy as np
import pytest

from repro.core import coloring as col
from repro.dynamic.service import ColoringService
from repro.graphs import csr
from repro.resilience import faults
from repro.resilience.errors import InjectedFault, QuarantinedError

pytestmark = pytest.mark.skipif(
    not os.environ.get("REPRO_FAULTS"),
    reason="chaos tests only run with REPRO_FAULTS set (make chaos)")

OPTS = dict(seed=0, n_chunks=2, ell_cap=6, C=16, ovf_cap=64, delta_cap=32,
            frontier_frac=0.5, max_cap_retries=2, max_ovf_growth=2)
N = 48
TENANTS = ("t0", "t1", "t2")
STEPS = 6
DEGRADING_SITES = {"cap.exhaust", "ovf.exhaust"}


def _sites() -> set:
    return set(faults.parse_spec(os.environ["REPRO_FAULTS"]))


def _graph(s: int):
    r = np.random.default_rng(s)
    e = r.integers(0, N, (120, 2))
    e = e[e[:, 0] != e[:, 1]]
    return csr.from_edges(N, e)


def _stream(seed: int = 3) -> list:
    r = np.random.default_rng(seed)
    out = []
    for _ in range(STEPS):
        per = {}
        for nm in TENANTS:
            ins = r.integers(0, N, (6, 2))
            ins = ins[ins[:, 0] != ins[:, 1]]
            dels = r.integers(0, N, (2, 2))
            per[nm] = (ins, dels)
        out.append(per)
    return out


def _run(megabatch: bool):
    """Push the fixed stream through one faulted service.

    Returns (svc, accepted, record): ``accepted`` is the per-tenant list of
    batches the submit path took (injected submit faults retry 3x, then the
    batch is abandoned — the reference run sees the same list), ``record``
    is the per-step outcome trace the determinism assertion compares.
    """
    svc = ColoringService(megabatch=megabatch, quarantine_after=2, **OPTS)
    for i, nm in enumerate(TENANTS):
        svc.add_graph(nm, _graph(i))
    accepted = {nm: [] for nm in TENANTS}
    record = []
    last_v = {nm: 0 for nm in TENANTS}
    for per in _stream():
        for nm, (ins, dels) in per.items():
            for _attempt in range(3):
                try:
                    svc.submit(nm, inserts=ins, deletes=dels)
                except InjectedFault:
                    continue              # submit-path fault: bounded retry
                except QuarantinedError:
                    break
                else:
                    accepted[nm].append((ins, dels))
                    break
        stats = svc.step()
        row = {}
        for nm in TENANTS:
            s = stats[nm]
            if svc.quarantined(nm) is None:
                # invariant: a committed state is always proper — never a
                # half-applied or corrupted batch
                assert col.is_proper(svc.graph(nm), svc.colors(nm)), nm
            assert s["version"] >= last_v[nm], nm
            last_v[nm] = s["version"]
            row[nm] = (int(s["version"]), s.get("rolled_back"),
                       s.get("quarantined"), int(s["degrade_rung"]))
        record.append(row)
    return svc, accepted, record


def _reference(accepted: dict):
    """Fault-free run over exactly the accepted batches (loop path; the
    mega path is bit-identical to it by the §13 differential tests)."""
    with faults.suppress():
        ref = ColoringService(megabatch=False, **OPTS)
        for i, nm in enumerate(TENANTS):
            ref.add_graph(nm, _graph(i))
        for nm in TENANTS:
            for ins, dels in accepted[nm]:
                ref.submit(nm, inserts=ins, deletes=dels)
            ref.step(nm)
    return ref


def _export(svc, tag: str) -> None:
    d = os.environ.get("REPRO_DEADLETTER_DIR")
    if not d or not svc.dead_letters():
        return
    os.makedirs(d, exist_ok=True)
    site = "_".join(sorted(_sites())).replace(".", "-")
    svc.export_dead_letters(os.path.join(d, f"{site}_{tag}.jsonl"))


@pytest.mark.parametrize("megabatch", [False, True],
                         ids=["loop", "mega"])
def test_chaos_recovery(megabatch):
    faults.reset()
    svc, accepted, _record = _run(megabatch)
    _export(svc, "mega" if megabatch else "loop")

    # quarantined tenants: structured reason + last-good still proper
    for nm, q in svc.quarantined().items():
        assert q.reason in ("injected", "cap_exhausted", "ovf_exhausted",
                            "improper", "error"), q.reason
        assert col.is_proper(svc.graph(nm), svc.colors(nm)), nm
        assert svc.dead_letters(nm), nm      # the drain was preserved

    # fault gone: heal every frozen tenant, drain every requeued batch
    with faults.suppress():
        for nm in list(svc.quarantined()):
            svc.heal(nm)
            assert svc.quarantined(nm) is None
        guard = 0
        while any(svc.pending(nm) for nm in TENANTS):
            svc.step()
            guard += 1
            assert guard < 32, "pending queue failed to drain"

    ref = _reference(accepted)
    degrading = bool(_sites() & DEGRADING_SITES)
    for nm in TENANTS:
        assert col.is_proper(svc.graph(nm), svc.colors(nm)), nm
        if not degrading:
            # recovery contract: bit-identical to the run that never failed
            assert np.array_equal(svc.colors(nm), ref.colors(nm)), nm
            assert svc.version(nm) == ref.version(nm), nm


@pytest.mark.parametrize("megabatch", [False, True],
                         ids=["loop", "mega"])
def test_chaos_deterministic_replay(megabatch):
    faults.reset()
    svc1, _a1, rec1 = _run(megabatch)
    faults.reset()
    svc2, _a2, rec2 = _run(megabatch)
    assert rec1 == rec2
    assert sorted(svc1.quarantined()) == sorted(svc2.quarantined())
    for nm, q in svc1.quarantined().items():
        assert svc2.quarantined(nm).reason == q.reason
    for nm in TENANTS:
        assert np.array_equal(svc1.colors(nm), svc2.colors(nm)), nm
        assert svc1.version(nm) == svc2.version(nm), nm


def test_kernel_fallback_forced_parity():
    """``kernel.fallback`` never changes results: a forced jnp fallback is
    bit-identical to the requested backend (the parity contract)."""
    if "kernel.fallback" not in _sites():
        pytest.skip("kernel.fallback not armed")
    import jax.numpy as jnp

    from repro.graphs.csr import FILL
    from repro.kernels import ops

    backend = os.environ.get("REPRO_KERNEL_BACKEND", "pallas_interpret")
    r = np.random.default_rng(0)
    R = 256                       # one full block: R % block_rows == 0
    ell_np = r.integers(0, R, (R, 8)).astype(np.int32)
    ell_np[r.random((R, 8)) < 0.4] = FILL
    ell = jnp.asarray(ell_np)
    colors = jnp.asarray(r.integers(-1, 16, (R,)).astype(np.int32))
    faults.reset()
    forced = ops.firstfit(ell, colors, C=32, backend=backend)
    with faults.suppress():
        want = ops.firstfit(ell, colors, C=32, backend=backend)
    for a, b in zip(forced, want):
        assert np.array_equal(np.asarray(a), np.asarray(b))
