"""Tier-1 tests for the self-healing layer (DESIGN.md §14): deterministic
fault injection, retry budgets + the degradation ladder, transactional
steps with bit-exact rollback, quarantine + dead-letter + heal, strict
submit validation, and a stateful service fuzz.

Everything here runs with ``REPRO_FAULTS`` unset — faults are armed
per-test through ``faults.inject`` scopes, so the suite also pins the
off-path contract (faults off => behavior bit-identical to pre-§14).
The env-driven chaos sweep lives in tests/test_chaos.py (``make chaos``).
"""
import dataclasses

import numpy as np
import pytest

from repro import api
from repro.core import coloring as col
from repro.dynamic import incremental as inc
from repro.dynamic.service import ColoringService
from repro.graphs import csr
from repro.resilience import faults, ladder
from repro.resilience.errors import (CapRetryExhausted, HealFailed,
                                     ImproperColoring, InjectedFault,
                                     OvfGrowthExhausted, QuarantinedError)

OPTS = dict(seed=0, n_chunks=2, ell_cap=6, C=16, ovf_cap=64, delta_cap=32,
            frontier_frac=0.5)
N = 64


def _clique(n: int):
    e = np.array([(u, v) for u in range(n) for v in range(u + 1, n)],
                 np.int64)
    return csr.from_edges(n, e)


def _graph(s: int = 0, n: int = N, m: int = 150):
    r = np.random.default_rng(s)
    e = r.integers(0, n, (m, 2))
    e = e[e[:, 0] != e[:, 1]]
    return csr.from_edges(n, e)


def _batch(r, n: int = N, k: int = 8):
    ins = r.integers(0, n, (k, 2))
    ins = ins[ins[:, 0] != ins[:, 1]]
    dels = r.integers(0, n, (3, 2))
    return ins, dels


@pytest.fixture(autouse=True)
def _faults_off():
    """Every test starts and ends with injection disarmed."""
    faults.install(None)
    yield
    faults.install(None)


# --------------------------------------------------------------------------
# fault-injection harness
# --------------------------------------------------------------------------

def test_spec_parsing_round_trip():
    plan = faults.parse_spec(
        "cap.exhaust:p=0.5:seed=7;service.step:times=2:after=1;"
        "color.corrupt:k=3")
    assert set(plan) == {"cap.exhaust", "service.step", "color.corrupt"}
    assert plan["cap.exhaust"].p == 0.5 and plan["cap.exhaust"].seed == 7
    assert plan["service.step"].times == 2 and plan["service.step"].after == 1
    assert plan["color.corrupt"].k == 3


def test_spec_rejects_unknown_site_and_param():
    with pytest.raises(ValueError, match="unknown fault site"):
        faults.parse_spec("cap.explode")
    with pytest.raises(ValueError, match="unknown fault param"):
        faults.parse_spec("cap.exhaust:frequency=2")


def test_fires_is_deterministic_and_replayable():
    spec = "service.step:p=0.4:seed=11"
    with faults.inject(spec):
        a = [faults.fires("service.step") for _ in range(64)]
        faults.reset()
        b = [faults.fires("service.step") for _ in range(64)]
    assert a == b and any(a) and not all(a)


def test_after_and_times_policies():
    with faults.inject("service.step:after=2:times=1"):
        got = [faults.fires("service.step") for _ in range(6)]
    assert got == [False, False, True, False, False, False]


def test_inject_scopes_nest_and_restore():
    assert not faults.active()
    with faults.inject("cap.exhaust"):
        assert faults.active() and faults.fires("cap.exhaust")
        with faults.suppress():
            assert not faults.active()
            assert not faults.fires("cap.exhaust")
        assert faults.active()
    assert not faults.active()


def test_check_raises_injected_fault_with_meta():
    with faults.inject("service.submit"):
        with pytest.raises(InjectedFault) as ei:
            faults.check("service.submit", tenant="t")
    assert ei.value.site == "service.submit"
    assert ei.value.meta == {"tenant": "t"}


def test_off_path_is_bit_identical():
    """Faults off => colors byte-identical to a run that never imported the
    fault machinery (the off path is a module-global None check)."""
    g = _graph(0)
    a = api.color(g, algorithm="rsoc", seed=0)
    with faults.inject("service.step"):    # armed but never on this path
        b = api.color(g, algorithm="rsoc", seed=0)
    assert np.array_equal(a.colors, b.colors)
    assert a.final_C == b.final_C and a.n_rounds == b.n_rounds


# --------------------------------------------------------------------------
# retry budgets
# --------------------------------------------------------------------------

def test_spec_validates_budget_fields():
    with pytest.raises(ValueError, match="max_cap_retries"):
        api.ColoringSpec(max_cap_retries=-1).validate()
    with pytest.raises(ValueError, match="max_ovf_growth"):
        api.ColoringSpec(max_ovf_growth=-2).validate()
    api.ColoringSpec(max_cap_retries=0, max_ovf_growth=0).validate()


def test_genuine_cap_exhaustion_raises():
    g = _clique(16)          # needs 16 colors
    with pytest.raises(CapRetryExhausted) as ei:
        api.color(g, algorithm="rsoc", C=4, max_cap_retries=0)
    assert ei.value.budget == 0 and not ei.value.forced
    assert ei.value.engine == "rsoc"
    # same task with the budget lifted converges fine
    res = api.color(g, algorithm="rsoc", C=4)
    assert col.is_proper(g, res.colors) and res.retries > 0


def test_forced_cap_exhaustion_raises():
    g = _graph(0)
    with faults.inject("cap.exhaust"):
        with pytest.raises(CapRetryExhausted) as ei:
            api.color(g, algorithm="rsoc", seed=0)
    assert ei.value.forced


def test_genuine_ovf_exhaustion_raises():
    # hub rows spill past a tiny overflow buffer; budget 0 forbids growing
    g = _graph(3, n=32, m=60)
    st = inc.dynamic_state(g, n_chunks=2, ell_cap=2, ell_slack=0, ovf_cap=8,
                           delta_cap=16, max_ovf_growth=0)
    r = np.random.default_rng(5)
    ins = r.integers(0, 32, (60, 2))
    ins = ins[ins[:, 0] != ins[:, 1]]
    with pytest.raises(OvfGrowthExhausted) as ei:
        inc.recolor_incremental(st, inserts=ins)
    assert ei.value.budget == 0 and not ei.value.forced
    # unbounded budget applies the same batch by growing
    st2 = dataclasses.replace(st, max_ovf_growth=None)
    out = inc.recolor_incremental(st2, inserts=ins)
    assert out.ovf_grows > 0


def test_budgets_unused_are_bit_identical():
    """Finite-but-unexercised budgets change nothing: same colors, same
    versions as the unbounded default."""
    g = _graph(1)
    r1 = api.color(g, mode="incremental", **OPTS)
    r2 = api.color(g, mode="incremental", max_cap_retries=10,
                   max_ovf_growth=10, **OPTS)
    assert np.array_equal(r1.colors, r2.colors)
    b = _batch(np.random.default_rng(2))
    s1 = inc.recolor_incremental(r1.state, inserts=b[0], deletes=b[1])
    s2 = inc.recolor_incremental(r2.state, inserts=b[0], deletes=b[1])
    assert np.array_equal(s1.colors, s2.colors)
    assert s1.version == s2.version == 1


# --------------------------------------------------------------------------
# degradation ladder
# --------------------------------------------------------------------------

def test_ladder_rung0_is_plain_recolor():
    st = api.color(_graph(0), mode="incremental", **OPTS).state
    ins, dels = _batch(np.random.default_rng(7))
    want = inc.recolor_incremental(st, ins, dels)
    got, rung = ladder.apply_with_ladder(st, ins, dels)
    assert rung == 0 and got.last_degrade_rung == 0
    assert np.array_equal(got.colors, want.colors)
    assert got.version == want.version


def test_ladder_degrades_to_scratch_on_ovf_exhaustion():
    st = api.color(_graph(0), mode="incremental", **OPTS).state
    ins, dels = _batch(np.random.default_rng(8))
    with faults.inject("ovf.exhaust"):
        got, rung = ladder.apply_with_ladder(st, ins, dels)
    assert rung == 1 and got.last_degrade_rung == 1
    assert got.version == st.version + 1
    g2 = ladder.updated_graph(st, ins, dels)
    assert col.is_proper(g2, got.colors)


def test_ladder_degrades_to_oracle_when_scratch_also_fails():
    st = api.color(_graph(0), mode="incremental", **OPTS).state
    ins, dels = _batch(np.random.default_rng(9))
    with faults.inject("cap.exhaust"):     # kills rung 0 AND rung 1
        got, rung = ladder.apply_with_ladder(st, ins, dels)
    assert rung == 2 and got.last_degrade_rung == 2
    assert got.version == st.version + 1
    g2 = ladder.updated_graph(st, ins, dels)
    assert col.is_proper(g2, got.colors)


def test_incremental_engine_falls_back_to_oracle_encode():
    g = _clique(16)
    res = api.color(g, mode="incremental", C=4, max_cap_retries=0,
                    n_chunks=2, delta_cap=16)
    assert res.degrade_rung == 2
    assert res.state.last_degrade_rung == 2
    assert col.is_proper(g, res.colors)
    # the oracle-encoded state still accepts incremental batches
    st = inc.recolor_incremental(res.state, inserts=[[0, 1]])
    assert st.version == 1 and st.last_degrade_rung == 0


# --------------------------------------------------------------------------
# transactional step: rollback, requeue, quarantine, heal
# --------------------------------------------------------------------------

def test_rollback_is_bit_exact_and_requeues():
    svc = ColoringService(megabatch=False, quarantine_after=99, **OPTS)
    svc.add_graph("a", _graph(0))
    ins, dels = _batch(np.random.default_rng(1))
    before = svc.snapshot("a")
    svc.submit("a", inserts=ins, deletes=dels)
    with faults.inject("service.step:times=1"):
        stats = svc.step("a")
    assert stats["a"]["rolled_back"] == "injected"
    assert svc.snapshot("a") is before       # never committed
    assert svc.pending("a") == 1             # requeued at the front
    # the retried step is bit-identical to one that never failed
    ref = inc.recolor_incremental(before, ins, dels)
    svc.step("a")
    assert np.array_equal(svc.colors("a"), ref.colors)
    assert svc.version("a") == ref.version == 1


def test_quarantine_after_repeated_failures_then_heal_replay():
    r = np.random.default_rng(2)
    batches = [_batch(r) for _ in range(3)]
    # fault-free reference
    ref = ColoringService(megabatch=False, **OPTS)
    ref.add_graph("a", _graph(0))
    for ins, dels in batches:
        ref.submit("a", inserts=ins, deletes=dels)
        ref.step("a")

    svc = ColoringService(megabatch=False, quarantine_after=2, **OPTS)
    svc.add_graph("a", _graph(0))
    with faults.inject("service.step"):
        svc.submit("a", inserts=batches[0][0], deletes=batches[0][1])
        s1 = svc.step("a")
        assert s1["a"]["rolled_back"] == "injected"
        svc.submit("a", inserts=batches[1][0], deletes=batches[1][1])
        s2 = svc.step("a")
        assert s2["a"]["quarantined"] == "injected"
        # frozen: submits bounce, steps no-op with the structured reason
        with pytest.raises(QuarantinedError):
            svc.submit("a", inserts=batches[2][0])
        s3 = svc.step("a")
        assert s3["a"]["quarantined"] == "injected"
        assert svc.version("a") == 0         # last-good still served
    q = svc.quarantined("a")
    assert q.reason == "injected" and q.failures == 2
    letters = svc.dead_letters("a")
    assert len(letters) == 1 and letters[0].n_edges() > 0
    # cause gone -> replay heal applies the dead letters bit-identically
    v = svc.heal("a")
    assert svc.quarantined("a") is None and svc.dead_letters("a") == []
    assert v == 2
    svc.submit("a", inserts=batches[2][0], deletes=batches[2][1])
    svc.step("a")
    assert np.array_equal(svc.colors("a"), ref.colors("a"))
    assert svc.version("a") == ref.version("a")


def test_heal_falls_back_to_scratch_when_replay_still_fails():
    svc = ColoringService(megabatch=False, quarantine_after=1, **OPTS)
    svc.add_graph("a", _graph(0))
    ins, dels = _batch(np.random.default_rng(3))
    svc.submit("a", inserts=ins, deletes=dels)
    with faults.inject("service.step"):
        svc.step("a")
    assert svc.quarantined("a") is not None
    # replay re-raises inside the ladder?  service.step faults don't fire
    # in heal (no step), so force replay failure via ovf.exhaust+cap.exhaust
    # -> ladder still absorbs those.  Use color-corrupt-style failure
    # instead: corrupt the dead letter so replay verifies improper is not
    # possible either (ladder output is always proper) — so exercise the
    # explicit scratch mode.
    v = svc.heal("a", mode="scratch")
    assert svc.quarantined("a") is None
    assert v == 1
    # scratch heal recolors the CURRENT graph; dead letters are kept
    assert len(svc.dead_letters("a")) == 1
    assert col.is_proper(svc.graph("a"), svc.colors("a"))


def test_heal_requires_quarantine_and_validates_mode():
    svc = ColoringService(**OPTS)
    svc.add_graph("a", _graph(0))
    with pytest.raises(ValueError, match="not quarantined"):
        svc.heal("a")
    with pytest.raises(KeyError):
        svc.heal("nope")


def test_corrupt_step_caught_by_verification():
    svc = ColoringService(megabatch=False, quarantine_after=99, **OPTS)
    svc.add_graph("a", _graph(0))
    ins, dels = _batch(np.random.default_rng(4))
    svc.submit("a", inserts=ins, deletes=dels)
    with faults.inject("color.corrupt:times=1"):
        stats = svc.step("a")
        assert stats["a"]["rolled_back"] == "improper"
        assert svc.version("a") == 0
        stats = svc.step("a")                # fault exhausted -> clean
    assert svc.version("a") == 1
    assert col.is_proper(svc.graph("a"), svc.colors("a"))


def test_budget_exhaustion_degrades_and_commits_not_rolls_back():
    svc = ColoringService(megabatch=False, **OPTS)
    svc.add_graph("a", _graph(0))
    ins, dels = _batch(np.random.default_rng(6))
    svc.submit("a", inserts=ins, deletes=dels)
    with faults.inject("ovf.exhaust"):
        stats = svc.step("a")
    assert "rolled_back" not in stats["a"]
    assert stats["a"]["degrade_rung"] == 1   # scratch rung committed
    assert svc.version("a") == 1
    assert col.is_proper(svc.graph("a"), svc.colors("a"))


def test_mega_group_fault_falls_back_to_per_tenant():
    svc = ColoringService(megabatch=True, megabatch_min=2,
                          quarantine_after=99, **OPTS)
    svc.add_graph("x", _graph(0))
    svc.add_graph("y", _graph(0))
    r = np.random.default_rng(7)
    with faults.inject("service.step:times=1"):   # fires on the group only
        for nm in ("x", "y"):
            ins, dels = _batch(r)
            svc.submit(nm, inserts=ins, deletes=dels)
        svc.step()
    for nm in ("x", "y"):
        assert svc.version(nm) == 1
        assert col.is_proper(svc.graph(nm), svc.colors(nm))


# --------------------------------------------------------------------------
# satellites: strict submit validation + restore semantics
# --------------------------------------------------------------------------

def test_submit_strict_validation_names_tenant():
    svc = ColoringService(**OPTS)
    svc.add_graph("z", _graph(2))
    with pytest.raises(ValueError, match=r"graph 'z'.*self-loop"):
        svc.submit("z", inserts=[[3, 3]])
    with pytest.raises(ValueError, match=r"graph 'z'.*integer"):
        svc.submit("z", inserts=np.array([[1.5, 2.0]]))
    with pytest.raises(ValueError, match=r"graph 'z'.*outside"):
        svc.submit("z", inserts=[[0, N + 5]])
    with pytest.raises(ValueError, match=r"graph 'z'.*\(k, 2\)"):
        svc.submit("z", inserts=[[1, 2, 3]])
    assert svc.pending("z") == 0             # nothing poisoned the queue
    # deleting a self-loop is a harmless no-op, not an error
    svc.submit("z", deletes=[[3, 3]])
    assert svc.pending("z") == 1


def test_submit_fault_rejects_before_enqueue():
    svc = ColoringService(**OPTS)
    svc.add_graph("z", _graph(2))
    with faults.inject("service.submit:times=1"):
        with pytest.raises(InjectedFault):
            svc.submit("z", inserts=[[1, 2]])
        assert svc.pending("z") == 0
        svc.submit("z", inserts=[[1, 2]])    # retry lands
    assert svc.pending("z") == 1


def test_restore_flushes_pending_and_latency_history():
    # unique tenant name: the step_ms histogram registry is process-global
    svc = ColoringService(megabatch=False, **OPTS)
    svc.add_graph("rst", _graph(0))
    snap = svc.snapshot("rst")
    r = np.random.default_rng(8)
    ins, dels = _batch(r)
    svc.submit("rst", inserts=ins, deletes=dels)
    svc.step("rst")
    assert svc.step_latency("rst")["count"] == 1
    ins2, _ = _batch(r)
    svc.submit("rst", inserts=ins2)
    v = svc.restore("rst", snap)
    assert v == 2                            # above current, never reused
    assert svc.pending("rst") == 0           # queued future abandoned
    assert svc.step_latency("rst")["count"] == 0
    assert np.array_equal(svc.colors("rst"), snap.colors)


# --------------------------------------------------------------------------
# stateful fuzz: random op interleavings keep every invariant
# --------------------------------------------------------------------------

def _fuzz_round(svc, r, tracker, names):
    """One random op; asserts properness + version monotonicity after."""
    op = r.choice(["submit", "step", "step_one", "snapshot_restore",
                   "chaos_step", "remove_add"])
    nm = str(r.choice(names))
    if op == "submit":
        ins, dels = _batch(r)
        try:
            svc.submit(nm, inserts=ins, deletes=dels)
        except QuarantinedError:
            pass
    elif op == "step":
        svc.step()
    elif op == "step_one":
        svc.step(nm)
    elif op == "snapshot_restore":
        snap = svc.snapshot(nm)
        ins, dels = _batch(r)
        try:
            svc.submit(nm, inserts=ins, deletes=dels)
            svc.step(nm)
        except QuarantinedError:
            pass
        svc.restore(nm, snap)
    elif op == "chaos_step":
        with faults.inject("service.step:times=1:seed=%d"
                           % r.integers(0, 1000)):
            svc.step()
        with faults.suppress():
            for qn in list(svc.quarantined()):
                svc.heal(qn)
    elif op == "remove_add":
        svc.remove_graph(nm)
        tracker.pop(nm, None)
        svc.add_graph(nm, _graph(int(r.integers(0, 100))))
    for name in svc.graphs():
        if svc.quarantined(name) is None:
            assert col.is_proper(svc.graph(name), svc.colors(name)), name
        v = svc.version(name)
        assert v >= tracker.get(name, 0), name
        tracker[name] = v


@pytest.mark.parametrize("megabatch", [False, True])
def test_stateful_fuzz(megabatch):
    names = ["f0", "f1", "f2"]
    r = np.random.default_rng(123 + megabatch)
    svc = ColoringService(megabatch=megabatch, megabatch_min=2,
                          quarantine_after=2, **OPTS)
    for i, nm in enumerate(names):
        svc.add_graph(nm, _graph(i))
    tracker = {nm: 0 for nm in names}
    for _ in range(30):
        _fuzz_round(svc, r, tracker, names)


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as hst

    @given(seed=hst.integers(min_value=0, max_value=2**16),
           megabatch=hst.booleans())
    @settings(max_examples=10, deadline=None,
              suppress_health_check=list(HealthCheck))
    def test_stateful_fuzz_hypothesis(seed, megabatch):
        names = ["h0", "h1"]
        r = np.random.default_rng(seed)
        svc = ColoringService(megabatch=megabatch, megabatch_min=2,
                              quarantine_after=2, **OPTS)
        for i, nm in enumerate(names):
            svc.add_graph(nm, _graph(i))
        tracker = {nm: 0 for nm in names}
        for _ in range(8):
            _fuzz_round(svc, r, tracker, names)
except ImportError:      # hypothesis not in the image: numpy fuzz covers it
    pass
