"""The one-front-door API (DESIGN.md §11): ``repro.api.color`` + spec +
registry.

Covers the acceptance criteria of the redesign:
  * every spec combo in the support matrix is exercised by a differential
    test proving ``api.color(spec)`` is bit-identical to the pre-redesign
    entry point it replaces;
  * unsupported combos raise ValueError naming the nearest supported spec;
  * every legacy ``color_*`` shim emits DeprecationWarning exactly once and
    returns bit-identical colors to the equivalent spec call;
  * every engine populates the ColoringResult invariant fields
    (final_C / retries / distance) and echoes the resolved spec.
"""
import warnings

import numpy as np
import pytest

from repro import api, registry
from repro.core import coloring as col
from repro.core import distance2 as d2
from repro.core import frontier as fr
from repro.core import distributed as dist
from repro.core.context import PassContext
from repro.dynamic import dynamic_state
from repro.graphs import generators as gen


GRAPH = gen.mesh2d(14, 14)
RMAT = gen.rmat_b(8, edge_factor=6)
BIPARTITE = gen.bipartite_random(80, 50, 3.0, seed=7)
N_LEFT = 80


def _mesh1():
    import jax
    return jax.make_mesh((1,), ("data",))


def _assert_identical(a, b, what):
    np.testing.assert_array_equal(a.colors, b.colors, err_msg=what)
    assert a.summary() == b.summary(), what


# --------------------------------------------------------------------------
# support-matrix differential: api.color(spec) == the entry point it replaces
# --------------------------------------------------------------------------

# (name, legacy call, equivalent spec overrides, graph) — one row per
# registered combo in the support matrix (see api.supported_specs())
MATRIX = {
    "rsoc/1/static/local": (
        lambda g: col.color_rsoc(g, seed=3),
        dict(algorithm="rsoc", seed=3), GRAPH),
    "cat/1/static/local": (
        lambda g: col.color_cat(g, seed=3),
        dict(algorithm="cat", seed=3), GRAPH),
    "gm/1/static/local": (
        lambda g: col.color_gm(g, seed=3),
        dict(algorithm="gm", seed=3), GRAPH),
    "jp/1/static/local": (
        lambda g: col.color_jp(g, seed=3),
        dict(algorithm="jp", seed=3, max_rounds=10000), GRAPH),
    "rsoc_compact/1/static/local": (
        lambda g: fr.color_rsoc_compact(g, seed=3),
        dict(algorithm="rsoc_compact", seed=3), GRAPH),
    "rsoc/2/static/local": (
        lambda g: d2.color_distance2(g, seed=3),
        dict(algorithm="rsoc", distance=2, seed=3), GRAPH),
    "rsoc/2/partial/local": (
        lambda g: d2.color_bipartite_partial(g, N_LEFT, seed=3),
        dict(algorithm="rsoc", distance=2, mode="partial", n_left=N_LEFT,
             seed=3), BIPARTITE),
    "rsoc/1/incremental/local": (
        lambda g: dynamic_state(g, seed=3),
        dict(algorithm="rsoc", mode="incremental", seed=3), GRAPH),
}


@pytest.mark.parametrize("combo", sorted(MATRIX))
def test_matrix_differential_vs_legacy(combo):
    legacy_fn, overrides, g = MATRIX[combo]
    legacy = legacy_fn(g)
    res = api.color(g, **overrides)
    if combo == "rsoc/1/incremental/local":
        # legacy entry returns the state itself, not a ColoringResult
        np.testing.assert_array_equal(res.colors, legacy.colors,
                                      err_msg=combo)
        assert res.final_C == legacy.C and res.retries == legacy.retries
    else:
        _assert_identical(res, legacy, combo)
    a, d_, m, b = combo.split("/")
    assert res.spec.algorithm == a and res.spec.distance == int(d_)
    assert res.spec.mode == m and res.spec.backend == b


@pytest.mark.parametrize("algo", ["rsoc", "cat"])
def test_matrix_differential_distributed(algo):
    """backend='distributed' rows of the matrix (1-device mesh: the engine
    path is identical, only the collective payload is trivial)."""
    mesh = _mesh1()
    legacy = dist.color_distributed(GRAPH, mesh, axis="data", algorithm=algo,
                                    seed=3, n_chunks=2)
    res = api.color(GRAPH, algorithm=algo, backend="distributed", mesh=mesh,
                    axis="data", seed=3, n_chunks=2, max_rounds=64)
    _assert_identical(res, legacy, f"{algo}/distributed")
    assert col.is_proper(GRAPH, res.colors)


def test_matrix_is_exhaustive():
    """Every registered combo is exercised by the differential suite above —
    a new engine registration must add a matrix row here."""
    covered = set(MATRIX) | {"rsoc/1/static/distributed",
                             "cat/1/static/distributed",
                             # exercised by tests/test_sharded.py (needs a
                             # multi-device subprocess, so not a MATRIX row)
                             "rsoc/1/incremental/distributed"}
    registered = {f"{a}/{d}/{m}/{b}"
                  for (a, d, m, b) in registry.engine_keys()}
    assert registered == covered, registered ^ covered


# --------------------------------------------------------------------------
# ColoringResult invariant: final_C / retries / distance set by every engine
# --------------------------------------------------------------------------

@pytest.mark.parametrize("combo", sorted(MATRIX))
def test_result_invariant_fields(combo):
    _, overrides, g = MATRIX[combo]
    res = api.color(g, **overrides)
    assert res.final_C > 0, combo
    assert res.retries >= 0, combo
    assert res.distance == res.spec.distance, combo
    assert res.n_colors <= res.final_C, combo
    assert res.spec == api.ColoringSpec(**overrides).resolved(), combo
    if res.spec.mode == "incremental":
        assert res.state is not None and res.state.C == res.final_C
    else:
        assert res.state is None


def test_result_invariant_distributed():
    res = api.color(GRAPH, backend="distributed", mesh=_mesh1(), seed=1,
                    n_chunks=2, max_rounds=64)
    assert res.final_C > 0 and res.retries == 0 and res.distance == 1


# --------------------------------------------------------------------------
# deprecation shims: one warning each, bit-identical to the spec call
# --------------------------------------------------------------------------

SHIMS = [
    ("color_rsoc", lambda g: col.color_rsoc(g, seed=5),
     dict(algorithm="rsoc", seed=5), GRAPH),
    ("color_cat", lambda g: col.color_cat(g, seed=5),
     dict(algorithm="cat", seed=5), GRAPH),
    ("color_gm", lambda g: col.color_gm(g, seed=5),
     dict(algorithm="gm", seed=5), GRAPH),
    ("color_jp", lambda g: col.color_jp(g, seed=5),
     dict(algorithm="jp", seed=5, max_rounds=10000), GRAPH),
    ("color_rsoc_compact", lambda g: fr.color_rsoc_compact(g, seed=5),
     dict(algorithm="rsoc_compact", seed=5), GRAPH),
    ("color_distance2", lambda g: d2.color_distance2(g, seed=5),
     dict(algorithm="rsoc", distance=2, seed=5), GRAPH),
    ("color_bipartite_partial",
     lambda g: d2.color_bipartite_partial(g, N_LEFT, seed=5),
     dict(algorithm="rsoc", distance=2, mode="partial", n_left=N_LEFT,
          seed=5), BIPARTITE),
]


@pytest.mark.parametrize("name,legacy_fn,overrides,g",
                         SHIMS, ids=[s[0] for s in SHIMS])
def test_shim_warns_exactly_once_and_matches(name, legacy_fn, overrides, g):
    registry.reset_legacy_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        first = legacy_fn(g)
        second = legacy_fn(g)
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)
           and name in str(x.message)]
    assert len(dep) == 1, f"{name}: expected exactly one warning, got {dep}"
    assert "repro.api.color" in str(dep[0].message)
    res = api.color(g, **overrides)
    _assert_identical(first, res, name)
    _assert_identical(second, res, name + " (second call)")


def test_algorithms_view_is_registry_backed_and_warning_free():
    assert sorted(col.ALGORITHMS) == api.algorithms()
    assert len(col.ALGORITHMS) == len(api.algorithms())
    registry.reset_legacy_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        res = col.ALGORITHMS["rsoc"](GRAPH, seed=5)
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]
    _assert_identical(res, api.color(GRAPH, algorithm="rsoc", seed=5),
                      "ALGORITHMS view")
    with pytest.raises(KeyError):
        col.ALGORITHMS["nope"]


# --------------------------------------------------------------------------
# spec validation: unsupported combos name the nearest supported spec
# --------------------------------------------------------------------------

@pytest.mark.parametrize("overrides,nearest", [
    # distance-2 CAT is unsupported; the distance-2 task is served by rsoc
    (dict(algorithm="cat", distance=2),
     "algorithm='rsoc', distance=2, mode='static', backend='local'"),
    # incremental mode exists — under rsoc
    (dict(algorithm="gm", mode="incremental"),
     "algorithm='rsoc', distance=1, mode='incremental', backend='local'"),
    # the distributed backend exists — under rsoc/cat
    (dict(algorithm="jp", backend="distributed"),
     "distance=1, mode='static', backend='distributed'"),
    # partial coloring is a distance-2 task
    (dict(algorithm="rsoc", mode="partial", distance=1, n_left=4),
     "algorithm='rsoc', distance=2, mode='partial', backend='local'"),
    # sharded incremental exists — under rsoc
    (dict(algorithm="cat", mode="incremental", backend="distributed"),
     "algorithm='rsoc', distance=1, mode='incremental', "
     "backend='distributed'"),
])
def test_unsupported_combo_names_nearest(overrides, nearest):
    with pytest.raises(ValueError, match="nearest supported spec") as ei:
        api.color(GRAPH, **overrides)
    assert nearest in str(ei.value)


@pytest.mark.parametrize("overrides", [
    dict(mode="weird"),
    dict(backend="tpu_pod"),
    dict(forbidden_impl="packed"),
    dict(n_chunks=0),
    dict(max_rounds=0),
    dict(C=-1),
    dict(frontier_frac=0.0),
    dict(n_left=10),                       # n_left without mode='partial'
    dict(mode="partial", distance=2),      # partial without n_left
])
def test_malformed_spec_rejected(overrides):
    with pytest.raises(ValueError):
        api.color(GRAPH, **overrides)


def test_unknown_override_and_bad_spec_type():
    with pytest.raises(TypeError, match="unknown ColoringSpec override"):
        api.color(GRAPH, algorithmn="rsoc")
    with pytest.raises(TypeError, match="ColoringSpec"):
        api.color(GRAPH, {"algorithm": "rsoc"})


def test_mesh_only_for_distributed():
    with pytest.raises(ValueError, match="distributed"):
        api.color(GRAPH, mesh=object())
    with pytest.raises(ValueError, match="mesh"):
        api.color(GRAPH, backend="distributed")   # mesh missing


# --------------------------------------------------------------------------
# reproducibility: the echoed spec replays the run
# --------------------------------------------------------------------------

def test_spec_echo_replays_bit_identically():
    res = api.color(RMAT, algorithm="rsoc", seed=9, n_chunks=8)
    replay = api.color(RMAT, res.spec)
    _assert_identical(res, replay, "spec replay")
    assert replay.spec == res.spec
    assert res.spec.spec_key() == replay.spec.spec_key()


def test_spec_key_is_stable_and_resolved():
    a = api.ColoringSpec(seed=1).spec_key()
    b = api.ColoringSpec(seed=1).spec_key()
    assert a == b
    # key reflects the RESOLVED spec: impl default is pinned
    assert "forbidden_impl=bitset" in a
    assert api.ColoringSpec(seed=2).spec_key() != a


# --------------------------------------------------------------------------
# PassContext: the typed replacement for the p_static tuple
# --------------------------------------------------------------------------

def test_pass_context_builders_and_validation():
    ctx = PassContext(n=10, n_pad=16, C=32, n_chunks=4)
    assert ctx.unpack() == (10, 16, 32, 4, "bitset")
    assert ctx.with_C(64).C == 64 and ctx.C == 32
    assert hash(ctx) == hash(PassContext(10, 16, 32, 4))   # jit-cache key
    with pytest.raises(ValueError):
        PassContext(n=10, n_pad=16, C=32, n_chunks=0)
    with pytest.raises(ValueError):
        PassContext(n=10, n_pad=4, C=32, n_chunks=2)
    with pytest.raises(ValueError):
        PassContext(n=10, n_pad=16, C=32, n_chunks=2, forbidden_impl="nope")


def test_service_spec_precedence():
    """ColoringService.add_graph: per-call opts > explicit spec > service
    defaults — construction defaults must not stomp an explicit spec, and a
    conflicting mode is rejected, not TypeErrored."""
    from repro.dynamic import ColoringService
    g = gen.mesh2d(10, 10)
    svc = ColoringService(seed=7, delta_cap=128)
    svc.add_graph("a", g, spec=api.ColoringSpec(seed=3, delta_cap=128))
    want = api.color(g, mode="incremental", seed=3, delta_cap=128)
    np.testing.assert_array_equal(svc.colors("a"), want.colors)
    svc.add_graph("b", g, mode="incremental")   # harmless explicit mode
    with pytest.raises(ValueError, match="incremental"):
        svc.add_graph("c", g, mode="static")


def test_pass_context_for_problem():
    prob = col.prepare(GRAPH, seed=0, n_chunks=4)
    ctx = PassContext.for_problem(prob, n_chunks=4)
    assert ctx.n == prob.n and ctx.n_pad == prob.n_pad and ctx.C == prob.C
    assert ctx.forbidden_impl == "bitset"
    assert PassContext.for_problem(prob, n_chunks=4, C=64).C == 64
