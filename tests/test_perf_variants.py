"""Numerical parity of the §Perf optimized variants against their
paper-faithful/autodiff oracles (EXPERIMENTS.md §Perf)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as TF
from repro.models.layers import chunked_attention


@pytest.mark.parametrize("causal", [True, False])
def test_flash_bwd_matches_autodiff(causal):
    rng = np.random.default_rng(0)
    B, H, L, D = 2, 4, 128, 32
    q = jnp.asarray(rng.standard_normal((B, H, L, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, H, L, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, H, L, D)).astype(np.float32))

    def loss(flash):
        return lambda q, k, v: (chunked_attention(
            q, k, v, causal=causal, chunk_q=32, chunk_k=32,
            flash_bwd=flash) ** 2).sum()

    o_ad = chunked_attention(q, k, v, causal=causal, chunk_q=32, chunk_k=32)
    o_fl = chunked_attention(q, k, v, causal=causal, chunk_q=32, chunk_k=32,
                             flash_bwd=True)
    np.testing.assert_allclose(np.asarray(o_ad), np.asarray(o_fl),
                               atol=1e-5)
    g_ad = jax.grad(loss(False), argnums=(0, 1, 2))(q, k, v)
    g_fl = jax.grad(loss(True), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ad, g_fl):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_flash_bwd_decode_offset():
    """Lk > Lq case (chunked prefill continuation)."""
    rng = np.random.default_rng(1)
    B, H, Lq, Lk, D = 1, 2, 32, 128, 16
    q = jnp.asarray(rng.standard_normal((B, H, Lq, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, H, Lk, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, H, Lk, D)).astype(np.float32))
    kw = dict(causal=True, q_offset=Lk - Lq, chunk_q=32, chunk_k=32)
    f = lambda fb: lambda *a: (chunked_attention(*a, flash_bwd=fb, **kw) ** 2).sum()
    ga = jax.grad(f(False), argnums=(0, 1, 2))(q, k, v)
    gf = jax.grad(f(True), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(ga, gf):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "minicpm3-4b",
                                  "qwen2-moe-a2.7b"])
def test_write_then_attend_decode_matches_oracle(arch):
    """The §Perf C decode restructuring is numerically exact."""
    cfg = configs.get(arch).make_smoke()
    cfg = dataclasses.replace(cfg, decode_write_then_attend=True)
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, L = 2, 16
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (B, L)), jnp.int32)
    logits, cache = TF.prefill(params, cfg, toks)
    full = TF.make_empty_cache(cfg, B, 32)
    for k, v in cache.items():
        if cfg.attn_type == "mla":
            full[k] = full[k].at[:, :, :L].set(v.astype(full[k].dtype))
        else:
            full[k] = full[k].at[:, :, :, :L].set(v.astype(full[k].dtype))
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    length = jnp.full((B,), L, jnp.int32)
    logits2, new_cache = TF.decode_step(params, cfg, nxt, full, length)
    ext = jnp.concatenate([toks, nxt[:, None]], axis=1)
    logits_full, _ = TF.forward(params, cfg, ext)
    np.testing.assert_allclose(np.asarray(logits2),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-2, atol=2e-2)
    # the step's K/V really landed in the cache at position L
    key = "k" if cfg.attn_type == "gqa" else "c_kv"
    if cfg.attn_type == "mla":
        written = np.asarray(new_cache[key][:, :, L])
    else:
        written = np.asarray(new_cache[key][:, :, :, L])
    assert np.abs(written).max() > 0


def test_hlo_walker_scan_exactness():
    """The roofline walker's core guarantee: scanned == unrolled flops."""
    from repro.launch.hlo_cost import analyze_text

    def f_scan(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, None, length=8)[0]

    def f_unroll(x, w):
        for _ in range(8):
            x = jnp.tanh(x @ w)
        return x

    xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    exp = 8 * 2 * 64 * 128 * 128
    for f in (f_scan, f_unroll):
        t = analyze_text(jax.jit(f).lower(xs, ws).compile().as_text())
        assert t["flops"] == exp
