"""Direct property tests for the partition/halo planners (DESIGN.md §15).

These are host-only (pure numpy planning, no mesh dispatch): disjoint
cover, ghost closure, seed determinism, 1-shard degeneracy, and the
``partition_stats`` boundary accounting the sharded service reports.
"""
import numpy as np
import pytest

from repro.core import coloring as col
from repro.core.partition import (block_partition, build_halo,
                                  build_halo_mutable, partition_stats)
from repro.graphs import generators as gen
from repro.graphs.csr import FILL, to_edge_list


@pytest.fixture(scope="module")
def g():
    return gen.mesh2d(16, 16)


# -- block_partition --------------------------------------------------------

def test_partition_disjoint_cover(g):
    """The relabel is a bijection and block-preserving: every vertex lands
    in exactly one shard, and its shard never changes under the shuffle."""
    D = 4
    part = block_partition(g, D, seed=3)
    assert np.array_equal(np.sort(part.perm), np.arange(g.n_vertices))
    shard_of = lambda v: np.minimum(v // part.n_loc, D - 1)
    assert np.array_equal(shard_of(np.arange(g.n_vertices)),
                          shard_of(part.perm))
    # relabeled graph is the same graph up to the bijection
    e = to_edge_list(g).astype(np.int64)
    e2 = to_edge_list(part.graph).astype(np.int64)
    want = {(int(a), int(b)) for a, b in part.perm[e]}
    assert {(int(a), int(b)) for a, b in e2} == want


def test_partition_seed_determinism(g):
    p1 = block_partition(g, 4, seed=9)
    p2 = block_partition(g, 4, seed=9)
    assert np.array_equal(p1.perm, p2.perm)
    # an explicit generator seeded the same way replays the seed path —
    # the sharded encoder relies on this to share one stream with its
    # priority draw
    p3 = block_partition(g, 4, rng=np.random.default_rng(9))
    assert np.array_equal(p1.perm, p3.perm)
    assert not np.array_equal(p1.perm, block_partition(g, 4, seed=10).perm)


# -- ghost closure ----------------------------------------------------------

def test_halo_ghost_closure(g):
    """Every ghost slot a shard's ELL references resolves, through the
    owner's boundary list, back to the global vertex it stands for."""
    D = 4
    part = block_partition(g, D, seed=1)
    plan = build_halo(part)
    n_loc = part.n_loc
    for d in range(D):
        ghosts = np.unique(plan.ell_local[d][plan.ell_local[d] >= n_loc])
        for s in ghosts:
            gi = int(s) - n_loc
            owner = int(plan.ghost_owner[d, gi])
            slot = int(plan.ghost_slot[d, gi])
            assert owner != FILL and owner != d
            v = int(plan.boundary[owner, slot]) + owner * n_loc
            # v is a cross neighbor of some row in shard d
            assert n_loc * owner <= v < n_loc * (owner + 1)


def test_halo_mutable_ghost_closure(g):
    D = 4
    part = block_partition(g, D, seed=1)
    plan = build_halo_mutable(part)
    blk = part.n_loc
    for d in range(D):
        ng = int(plan.n_ghost[d])
        for gi in range(ng):
            v = int(plan.ghost_ids[d, gi])
            flat = int(plan.ghost_flat[d, gi])
            owner, slot = divmod(flat, plan.max_b_cap)
            assert owner == min(v // blk, D - 1) and owner != d
            assert int(plan.boundary[owner, slot]) + owner * blk == v
        # dead tail stays FILL so a stale pointer can never alias
        assert (plan.ghost_flat[d, ng:] == FILL).all()
    # every cross edge's remote endpoint is in the referencing shard's
    # ghost set (the closure property the repair exchange depends on)
    e = to_edge_list(part.graph).astype(np.int64)
    s = np.minimum(e // blk, D - 1)
    for (u, v), (du, dv) in zip(e, s):
        if du != dv:
            assert v in plan.ghost_ids[du, :plan.n_ghost[du]]


def test_halo_mutable_min_caps(g):
    part = block_partition(g, 4, seed=1)
    plan = build_halo_mutable(part, min_b_cap=333, min_g_cap=444)
    assert plan.max_b_cap >= 333 and plan.max_g_cap >= 444


# -- 1-shard degeneracy -----------------------------------------------------

def test_one_shard_matches_prepare(g):
    """On a 1-shard partition the mutable halo plan IS the single-device
    mutable encode: same relabel, same ELL, same overflow spill, and no
    halo at all — the base of the sharded engine's bit-identity bar."""
    rng = np.random.default_rng(5)
    part = block_partition(g, 1, rng=rng)
    prob = col.prepare(g, 5, 4, 64, C=None)
    assert np.array_equal(part.perm, prob.perm)
    plan = build_halo_mutable(part, n_loc=prob.n_pad, ell_cap=64,
                              ell_slack=0)
    assert int(plan.n_boundary[0]) == 0 and int(plan.n_ghost[0]) == 0
    assert np.array_equal(plan.ell_local[0], np.asarray(prob.ell))
    n_ovf = int(np.asarray(prob.ovf_src).shape[0])
    assert np.array_equal(plan.ovf_src[0, :n_ovf], np.asarray(prob.ovf_src))
    assert (plan.ovf_src[0, n_ovf:] == FILL).all()


# -- partition_stats --------------------------------------------------------

def test_partition_stats_boundary(g):
    s1 = partition_stats(block_partition(g, 1, seed=0))
    s8 = partition_stats(block_partition(g, 8, seed=0))
    assert s1["boundary_frac"] == 0.0 and s1["cross_edge_frac"] == 0.0
    assert 0.0 < s8["boundary_frac"] <= 1.0
    assert s8["halo_bytes_per_round"] > s1["halo_bytes_per_round"]
    # bytes/round is O(boundary): bounded by the boundary vertex count
    # (per-shard max x shards), far below an O(n) all-gather payload
    assert s8["halo_bytes_per_round"] < s8["n_shards"] * 4 * (g.n_vertices + 1)
