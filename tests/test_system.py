"""End-to-end system tests: training loop + checkpoint restart determinism,
elastic restore, serving engine, data pipeline restorability, optimizer
behaviour, and gradient-compression exactness-on-average."""
import functools
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import pipeline as DP
from repro.models import transformer as TF
from repro.serving.serve_loop import Request, ServeEngine
from repro.training import checkpoint as CK
from repro.training import train_loop as TL
from repro.training.optimizer import (OptimizerConfig, adamw_update,
                                      compress_int8, decompress_int8,
                                      init_error_state, init_opt_state, lr_at)


def tiny_cfg():
    return TF.TransformerConfig(
        name="tiny", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
        head_dim=16, d_ff=64, vocab=128, qk_norm=True, dtype="float32",
        remat=False, chunk_q=32, chunk_k=32)


def _run(steps, ckpt_dir, seed=0):
    cfg = tiny_cfg()
    params = TF.init_params(jax.random.PRNGKey(seed), cfg)
    stream = DP.TokenStream(batch=4, seq_len=16, vocab=cfg.vocab, seed=seed)
    lcfg = TL.TrainLoopConfig(total_steps=steps, microbatches=2,
                              ckpt_every=4, ckpt_dir=ckpt_dir, log_every=1)
    # NOTE: the schedule horizon stays fixed (8) so a restarted run optimizes
    # under the same LR schedule as the uninterrupted one.
    return TL.run(lambda p, b: TF.train_step_loss(p, cfg, b), params, stream,
                  OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=8),
                  lcfg, to_device=lambda b: jax.tree.map(jnp.asarray, b))


def test_train_restart_bitwise_identical():
    """Kill-and-restart from LATEST reproduces the uninterrupted run."""
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        p_full, _, _ = _run(8, d1)                   # uninterrupted
        _run(4, d2)                                  # "crashes" after 4
        p_resumed, _, _ = _run(8, d2)                # restart, same command
        for a, b in zip(jax.tree_util.tree_leaves(p_full),
                        jax.tree_util.tree_leaves(p_resumed)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_and_gc():
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": jnp.arange(8.0), "b": {"x": jnp.ones((2, 2))}}
        for s in (1, 2, 3, 4, 5):
            CK.save(d, s, tree, keep=2)
        steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert len(steps) == 2                       # GC keeps 2
        got = CK.restore(d, tree)
        assert got is not None and got[1] == 5


def test_elastic_restore_changes_nothing_logical():
    """Restore works regardless of saving topology (full logical arrays)."""
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        CK.save(d, 7, tree)
        like = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
        restored, step, _ = CK.restore(d, like)
        assert step == 7
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))


def test_stream_state_roundtrip():
    s1 = DP.TokenStream(batch=2, seq_len=8, vocab=64, seed=3)
    for _ in range(5):
        next(s1)
    state = s1.state()
    b_next = next(s1)
    s2 = DP.TokenStream(batch=2, seq_len=8, vocab=64, seed=3)
    s2.restore(state)
    b_resumed = next(s2)
    np.testing.assert_array_equal(b_next["tokens"], b_resumed["tokens"])


def test_serving_continuous_batching():
    cfg = tiny_cfg()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, batch=3, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, 5 + i),
                    max_new_tokens=4 + (i % 3)) for i in range(7)]
    eng.run(reqs)
    assert all(r.done for r in reqs)
    for r in reqs:
        assert len(r.out_tokens) == r.max_new_tokens


def test_serving_matches_forward_oracle():
    """Engine greedy output == argmax rollout of the full forward pass."""
    cfg = tiny_cfg()
    params = TF.init_params(jax.random.PRNGKey(1), cfg)
    prompt = np.asarray([3, 5, 7, 11, 13])
    eng = ServeEngine(params, cfg, batch=2, max_len=64)
    req = Request(prompt=prompt, max_new_tokens=5)
    eng.run([req])
    toks = list(prompt)
    for _ in range(5):
        logits, _ = TF.forward(params, cfg,
                               jnp.asarray([toks], jnp.int32))
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert req.out_tokens == toks[len(prompt):]


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    lrs = [float(lr_at(cfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0                     # warmup
    assert abs(lrs[10] - 1.0) < 0.05                  # peak
    assert lrs[-1] < 0.15                             # decays to min
    assert all(l >= 0.09 for l in lrs)                # floor


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = init_opt_state(params)
    cfg = OptimizerConfig(lr=0.3, warmup_steps=0, total_steps=200,
                          weight_decay=0.0)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(cfg, params, g, opt)
    assert float(loss(params)) < 1e-3


def test_int8_compression_error_feedback():
    """Error feedback makes repeated compression exact on average."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(512).astype(np.float32)) * 0.01
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        q, s, err = compress_int8(g, err)
        acc = acc + decompress_int8(q, s)
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g),
                               atol=5e-5)


def test_sampler_union_invariants():
    """dst-prefix invariant + sink isolation of the minibatch substrate."""
    from repro.graphs.generators import erdos_renyi
    from repro.graphs.sampler import NeighborSampler, union_caps, union_pad
    g = erdos_renyi(500, 6.0, seed=1)
    fanouts = (5, 3)
    s = NeighborSampler(g, fanouts, seed=0)
    seeds = np.random.default_rng(0).choice(500, 64, replace=False)
    batch = s.sample(seeds)
    # prefix invariant chains
    np.testing.assert_array_equal(batch.blocks[-1].dst_nodes, seeds)
    for k in range(len(batch.blocks) - 1):
        outer, inner = batch.blocks[k], batch.blocks[k + 1]
        np.testing.assert_array_equal(
            outer.src_nodes[:len(inner.src_nodes)], inner.src_nodes)
    out = union_pad(batch, 64, fanouts, pad_edges_to=1024)
    caps = union_caps(64, fanouts)
    sink = caps[-1]
    assert out["nodes"].shape == (caps[-1] + 1,)
    assert out["src"].shape == out["dst"].shape
    assert out["src"].shape[0] % 1024 == 0
    # padding edges are sink self-loops; real edges stay in-range
    pad_mask = out["src"] == sink
    np.testing.assert_array_equal(out["dst"][pad_mask], sink)
    assert (out["dst"][~pad_mask] < caps[-2]).all()
    assert (out["src"] <= sink).all() and (out["src"] >= 0).all()
