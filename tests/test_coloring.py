"""The paper's algorithms: correctness, termination, quality, and the
claimed RSOC-vs-CAT behaviour (fewer gather passes, same color quality).
Includes property tests over random graphs — via hypothesis when it is
installed, via seeded numpy sampling otherwise (the container has no
network; hard-requiring hypothesis made the whole module uncollectable)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro import api
from repro.core import coloring as col
from repro.core.distance2 import color_distance_d, is_distance_d_proper
from repro.graphs import generators as gen
from repro.graphs.csr import CSRGraph, from_edges, power_graph


GRAPHS = {
    "mesh2d": gen.mesh2d(32, 32),
    "mesh3d": gen.mesh3d(8, 8, 8),
    "rmat_b": gen.rmat_b(10, edge_factor=8),
    "er": gen.erdos_renyi(2000, 8.0),
}
ALGOS = ["gm", "cat", "rsoc"]


# --------------------------------------------------------------------------
# correctness: proper colorings, all algorithms, all graph classes
# --------------------------------------------------------------------------

@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("algo", ALGOS + ["jp"])
def test_proper_coloring(gname, algo):
    g = GRAPHS[gname]
    res = col.ALGORITHMS[algo](g, seed=1)
    assert col.is_proper(g, res.colors), f"{algo} defective on {gname}"
    assert res.n_colors <= g.max_degree + 1      # greedy bound


@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_serial_oracle_proper(gname):
    g = GRAPHS[gname]
    colors = col.greedy_sequential(g)
    assert col.is_proper(g, colors)
    assert col.n_colors_used(colors) <= g.max_degree + 1


# --------------------------------------------------------------------------
# paper claims
# --------------------------------------------------------------------------

@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_rsoc_quality_matches_cat(gname):
    """Paper: both algorithms produce colorings with about the same number
    of colors, near the serial greedy level (<= +20% tolerance band)."""
    g = GRAPHS[gname]
    serial = col.n_colors_used(col.greedy_sequential(g))
    r = api.color(g, algorithm="rsoc", seed=2).n_colors
    c = api.color(g, algorithm="cat", seed=2).n_colors
    assert r <= max(serial * 1.25 + 2, c * 1.25 + 2)
    assert c <= serial * 1.25 + 2


@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_rsoc_fewer_gather_passes(gname):
    """The structural speedup: RSOC does ~half the neighbor-gather sweeps
    (1/round vs CAT's 2/round) and never more rounds (paper Figs 5-6)."""
    g = GRAPHS[gname]
    r = api.color(g, algorithm="rsoc", seed=3)
    c = api.color(g, algorithm="cat", seed=3)
    assert r.gather_passes < c.gather_passes
    assert r.n_rounds <= c.n_rounds + 1


def test_lockstep_termination():
    """Paper §5: fully-lockstep execution (n_chunks=1, every vertex in one
    simultaneous wave) livelocks WITHOUT asymmetric tie-breaking; our hashed
    priority guarantees termination.  The 2-vertex example of Fig. 7."""
    g = from_edges(2, np.array([[0, 1]]))
    res = api.color(g, algorithm="rsoc", seed=0, n_chunks=1, max_rounds=50)
    assert col.is_proper(g, res.colors)
    assert res.n_rounds < 10
    # and a dense lockstep case
    g2 = gen.erdos_renyi(256, 16.0, seed=5)
    res2 = api.color(g2, algorithm="rsoc", seed=0, n_chunks=1, max_rounds=200)
    assert col.is_proper(g2, res2.colors)


def test_conflicts_decrease_with_chunks():
    """More sequential chunks = fresher data = fewer conflicts (the paper's
    freshness argument, recovered deterministically)."""
    g = GRAPHS["rmat_b"]
    lockstep = api.color(g, algorithm="rsoc", seed=4, n_chunks=1)
    chunked = api.color(g, algorithm="rsoc", seed=4, n_chunks=32)
    assert chunked.total_conflicts <= lockstep.total_conflicts


# --------------------------------------------------------------------------
# frontier compaction + distance-2
# --------------------------------------------------------------------------

@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_frontier_compact_proper(gname):
    g = GRAPHS[gname]
    res = api.color(g, algorithm="rsoc_compact", seed=5)
    assert col.is_proper(g, res.colors)


def test_distance2_coloring():
    g = gen.mesh2d(16, 16)
    res, gd = color_distance_d(g, d=2, algorithm="rsoc", seed=0)
    assert is_distance_d_proper(g, res.colors, 2)
    # G^2 is denser; needs at least as many colors as G
    res1 = api.color(g, algorithm="rsoc", seed=0)
    assert res.n_colors >= res1.n_colors


# --------------------------------------------------------------------------
# regressions
# --------------------------------------------------------------------------

def test_gm_repair_includes_overflow_edges():
    """Regression: with ell_cap small enough to spill hub rows into the COO
    overflow side-channel, GM's serial repair used to rebuild forbidden sets
    from the ELL rows only, producing improper colorings."""
    g = gen.rmat_b(9, edge_factor=16)
    assert g.max_degree > 8  # the cap below really forces overflow
    res = api.color(g, algorithm="gm", seed=1, ell_cap=8)
    assert col.is_proper(g, res.colors)


def test_cap_doubling_recorded():
    """K_48 under C=32 must double the cap and report it in the result."""
    n = 48
    ii, jj = np.meshgrid(np.arange(n), np.arange(n))
    g = from_edges(n, np.stack([ii[ii != jj], jj[ii != jj]], axis=1))
    res = api.color(g, algorithm="rsoc", seed=0, C=32)
    assert col.is_proper(g, res.colors) and res.n_colors == n
    assert res.retries >= 1 and res.overflow and res.final_C >= n
    s = res.summary()
    assert s["final_C"] == res.final_C and s["retries"] == res.retries
    # no doubling needed -> retries 0 and final_C is the requested cap
    res2 = api.color(g, algorithm="rsoc", seed=0, C=64)
    assert res2.retries == 0 and not res2.overflow and res2.final_C == 64


# --------------------------------------------------------------------------
# property tests (hypothesis when available, seeded numpy otherwise)
# --------------------------------------------------------------------------

def _np_random_graph(rng):
    n = int(rng.integers(2, 120))
    m = int(rng.integers(0, 4 * n))
    edges = rng.integers(0, n, size=(m, 2))
    return from_edges(n, edges.astype(np.int64))


if HAVE_HYPOTHESIS:
    @st.composite
    def random_graph(draw):
        n = draw(st.integers(2, 120))
        m = draw(st.integers(0, 4 * n))
        edges = draw(st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=m, max_size=m))
        return from_edges(n, np.array(edges, dtype=np.int64).reshape(-1, 2))

    @given(random_graph(), st.sampled_from(ALGOS), st.integers(0, 3),
           st.sampled_from([1, 2, 16]))
    @settings(max_examples=40, deadline=None)
    def test_property_proper_and_bounded(g, algo, seed, n_chunks):
        """Invariant: any algorithm, any seed, any chunking -> proper
        coloring with <= max_degree+1 colors, terminating."""
        kw = {} if algo == "jp" else {"n_chunks": n_chunks}
        res = col.ALGORITHMS[algo](g, seed=seed, **kw)
        assert col.is_proper(g, res.colors)
        assert res.n_colors <= g.max_degree + 1

    @given(random_graph(), st.integers(0, 2))
    @settings(max_examples=20, deadline=None)
    def test_property_power_graph_contains_base(g, seed):
        """G^2 proper coloring is also proper on G (power graph ⊇ G)."""
        gd = power_graph(g, 2)
        res = api.color(gd, algorithm="rsoc", seed=seed)
        assert col.is_proper(g, res.colors)

    @given(st.integers(2, 40), st.integers(0, 3))
    @settings(max_examples=20, deadline=None)
    def test_property_complete_graph_needs_n_colors(n, seed):
        """K_n requires exactly n colors — tests the mex/overflow retry."""
        ii, jj = np.meshgrid(np.arange(n), np.arange(n))
        edges = np.stack([ii[ii != jj], jj[ii != jj]], axis=1)
        g = from_edges(n, edges)
        res = api.color(g, algorithm="rsoc", seed=seed, C=32)
        assert col.is_proper(g, res.colors)
        assert res.n_colors == n
else:
    @pytest.mark.parametrize("case", range(12))
    def test_property_proper_and_bounded(case):
        rng = np.random.default_rng(1000 + case)
        g = _np_random_graph(rng)
        algo = ALGOS[case % len(ALGOS)]
        n_chunks = [1, 2, 16][case % 3]
        kw = {} if algo == "jp" else {"n_chunks": n_chunks}
        res = col.ALGORITHMS[algo](g, seed=case, **kw)
        assert col.is_proper(g, res.colors)
        assert res.n_colors <= g.max_degree + 1

    @pytest.mark.parametrize("case", range(6))
    def test_property_power_graph_contains_base(case):
        rng = np.random.default_rng(2000 + case)
        g = _np_random_graph(rng)
        gd = power_graph(g, 2)
        res = api.color(gd, algorithm="rsoc", seed=case)
        assert col.is_proper(g, res.colors)

    @pytest.mark.parametrize("n,seed", [(2, 0), (17, 1), (33, 2), (40, 3)])
    def test_property_complete_graph_needs_n_colors(n, seed):
        ii, jj = np.meshgrid(np.arange(n), np.arange(n))
        edges = np.stack([ii[ii != jj], jj[ii != jj]], axis=1)
        g = from_edges(n, edges)
        res = api.color(g, algorithm="rsoc", seed=seed, C=32)
        assert col.is_proper(g, res.colors)
        assert res.n_colors == n
