"""Dynamic subsystem: exact encode/mutate/decode round-trips, properness
under random update streams, delta-proportional repair cost, and the
ColoringService engine."""
import numpy as np
import pytest

from repro.core import coloring as col
from repro.dynamic import (ColoringService, dynamic_state,
                           recolor_incremental, state_to_csr)
from repro.graphs import generators as gen
from repro.graphs.csr import from_edges, to_edge_list


def edge_set(g):
    e = to_edge_list(g)
    e = e[e[:, 0] != e[:, 1]]
    return set(map(tuple, np.sort(e, axis=1).tolist()))


def random_batch(rng, n, ref_edges, n_ins, n_del):
    ins = rng.integers(0, n, size=(n_ins, 2))
    ins = ins[ins[:, 0] != ins[:, 1]]
    cur = sorted(ref_edges)
    n_del = min(n_del, len(cur))
    dels = np.array([cur[i] for i in
                     rng.choice(len(cur), size=n_del, replace=False)]) \
        if n_del else np.zeros((0, 2), np.int64)
    return ins, dels


# --------------------------------------------------------------------------
# delta encoding: mutations are exact (decode == reference edge set)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("opts", [
    {},                                               # all-ELL regime
    {"ell_cap": 6, "ell_slack": 0, "ovf_cap": 8},     # heavy spill regime
])
def test_delta_roundtrip_exact(opts):
    g = gen.erdos_renyi(400, 10.0, seed=7)
    st = dynamic_state(g, seed=1, delta_cap=128, **opts)
    ref = edge_set(g)
    assert edge_set(state_to_csr(st)) == ref
    rng = np.random.default_rng(11)
    for _ in range(4):
        ins, dels = random_batch(rng, 400, ref, 90, 60)
        st = recolor_incremental(st, inserts=ins, deletes=dels)
        ref -= set(map(tuple, np.sort(dels, axis=1).tolist()))
        ref |= set(map(tuple, np.sort(ins, axis=1).tolist()))
        assert edge_set(state_to_csr(st)) == ref


def test_delta_noop_and_duplicates():
    g = gen.mesh2d(12, 12)
    st = dynamic_state(g, seed=0, delta_cap=64)
    ref = edge_set(g)
    e0 = to_edge_list(g)[0]
    # re-inserting existing edges, deleting absent ones, duplicate inserts
    st2 = recolor_incremental(
        st, inserts=np.array([e0, e0, [0, 5], [0, 5]]),
        deletes=np.array([[1, 100]]) if (1, 100) not in ref else None)
    got = edge_set(state_to_csr(st2))
    assert got == ref | {(0, 5)}
    assert col.is_proper(state_to_csr(st2), st2.colors)
    # empty batch: state returned unchanged
    assert recolor_incremental(st2) is st2


# --------------------------------------------------------------------------
# property: any update stream keeps the coloring proper
# --------------------------------------------------------------------------

@pytest.mark.parametrize("gname,seed", [
    ("er", 0), ("er", 1), ("rmat_b", 2), ("mesh", 3),
])
def test_property_stream_stays_proper(gname, seed):
    g = {"er": gen.erdos_renyi(600, 8.0, seed=5),
         "rmat_b": gen.rmat_b(9, edge_factor=8),
         "mesh": gen.mesh2d(24, 24)}[gname]
    st = dynamic_state(g, seed=seed, delta_cap=256)
    ref = edge_set(g)
    rng = np.random.default_rng(seed)
    for it in range(6):
        n_ins = int(rng.integers(0, 120))
        n_del = int(rng.integers(0, 120))
        ins, dels = random_batch(rng, g.n_vertices, ref, n_ins, n_del)
        st = recolor_incremental(st, inserts=ins, deletes=dels)
        dec = state_to_csr(st)
        assert col.is_proper(dec, st.colors), f"improper after batch {it}"
        ref -= set(map(tuple, np.sort(dels, axis=1).tolist()))
        ref |= set(map(tuple, np.sort(ins, axis=1).tolist()))
    assert edge_set(state_to_csr(st)) == ref


def test_property_spill_stream_stays_proper():
    """Hub rows overflow into COO; stream mutates through the spill path."""
    g = gen.rmat_b(9, edge_factor=16)
    st = dynamic_state(g, seed=2, ell_cap=8, ell_slack=1, ovf_cap=64,
                       delta_cap=128)
    rng = np.random.default_rng(9)
    ref = edge_set(g)
    for it in range(4):
        ins, dels = random_batch(rng, g.n_vertices, ref, 100, 50)
        st = recolor_incremental(st, inserts=ins, deletes=dels)
        dec = state_to_csr(st)
        assert col.is_proper(dec, st.colors), f"improper after batch {it}"
        ref -= set(map(tuple, np.sort(dels, axis=1).tolist()))
        ref |= set(map(tuple, np.sort(ins, axis=1).tolist()))
        assert edge_set(dec) == ref


def test_color_cap_doubling_on_clique_injection():
    """Injecting K_40 into a 32-cap state exercises the C-doubling retry."""
    st = dynamic_state(gen.mesh2d(8, 8), seed=0, C=32, delta_cap=128)
    n = 40
    ii, jj = np.meshgrid(np.arange(n), np.arange(n))
    st = recolor_incremental(st, inserts=np.stack([ii[ii < jj],
                                                   jj[ii < jj]], 1))
    assert col.is_proper(state_to_csr(st), st.colors)
    assert st.n_colors == n
    assert st.retries >= 1 and st.C >= n
    assert st.ovf_grows >= 1  # clique rows spilled past the initial buffer


# --------------------------------------------------------------------------
# the point of the subsystem: repair cost ~ delta, not graph size
# --------------------------------------------------------------------------

def test_small_delta_far_fewer_passes_than_scratch():
    g = gen.rmat_g(12)
    scratch = col.color_rsoc(g, seed=1)
    st = dynamic_state(g, seed=1)
    rng = np.random.default_rng(4)
    ins = rng.integers(0, g.n_vertices, size=(40, 2))
    ins = ins[ins[:, 0] != ins[:, 1]]
    st = recolor_incremental(st, inserts=ins)
    assert col.is_proper(state_to_csr(st), st.colors)
    assert st.last_gather_passes < scratch.gather_passes
    # and each incremental pass touches <= frontier_cap rows, not n_pad
    assert st.frontier_cap < st.n_pad


def test_deletes_only_single_verify_pass():
    g = gen.mesh2d(24, 24)
    st = dynamic_state(g, seed=0)
    dels = to_edge_list(g)[:50]
    st2 = recolor_incremental(st, deletes=dels)
    # deletions cannot create defects: one verify pass, zero conflicts
    assert st2.last_gather_passes == 1
    assert st2.last_conflicts == 0
    assert np.array_equal(st2.colors, st.colors)


def test_uncolored_seed_repair_is_verified():
    """Regression: adjacent uncolored seeds force-colored from one snapshot
    can pick the same color; the repair loop must keep going until a pass
    verifies them (lockstep n_chunks=1 is the adversarial case)."""
    import jax.numpy as jnp
    from repro.core import frontier
    from repro.graphs.csr import from_edges

    g = from_edges(4, np.array([[0, 1], [1, 2], [2, 3], [3, 0], [0, 2]]))
    prob = col.prepare(g, seed=0, n_chunks=1, relabel=False)
    n_pad = prob.n_pad
    colors0 = jnp.full((n_pad,), -1, jnp.int32)
    U0 = jnp.arange(n_pad) < prob.n
    # typed PassContext builder, not a hand-rolled positional tuple — the
    # tuple shape drifted once (PR 3) and must not silently drift again
    ctx = col.PassContext.for_problem(prob, n_chunks=1)
    for loop, extra in ((col._rsoc_repair_loop, ()),
                        (frontier._repair_compact_loop, (n_pad,))):
        out = loop(prob.ell, prob.ovf_src, prob.ovf_dst, prob.pri,
                   colors0, U0, ctx, *extra, 50)
        colors = np.asarray(out[0])[:prob.n]
        assert col.is_proper(g, colors), loop.__name__


def test_upsert_stream_does_not_grow_overflow():
    """Regression: re-inserting an overflow-resident edge must be a no-op,
    not a duplicate overflow slot per batch."""
    from repro.dynamic.delta import overflow_load

    g = gen.rmat_b(9, edge_factor=16)
    st = dynamic_state(g, seed=2, ell_cap=8, ell_slack=0, delta_cap=64)
    assert overflow_load(st.ovf_src) > 0
    # pick edges that live in overflow: decode and re-insert everything
    und = to_edge_list(state_to_csr(st))
    und = und[und[:, 0] < und[:, 1]][:200]
    load0 = overflow_load(st.ovf_src)
    for _ in range(3):
        st = recolor_incremental(st, inserts=und)
    assert overflow_load(st.ovf_src) == load0
    assert edge_set(state_to_csr(st)) == edge_set(g)


# --------------------------------------------------------------------------
# ColoringService
# --------------------------------------------------------------------------

def test_service_multi_graph_smoke():
    svc = ColoringService(delta_cap=128)
    svc.add_graph("mesh", gen.mesh2d(16, 16))
    svc.add_graph("rmat", gen.rmat_g(10))
    assert svc.graphs() == ["mesh", "rmat"]
    rng = np.random.default_rng(0)

    # queries before any update
    for name in svc.graphs():
        assert col.is_proper(svc.graph(name), svc.colors(name))

    # schedule artifacts are memoized by version and invalidated on mutation
    sched0 = svc.vertex_schedule("mesh")
    assert svc.vertex_schedule("mesh") is sched0
    v0 = svc.version("mesh")
    mesh_ins = rng.integers(0, 256, (30, 2))
    mesh_ins = mesh_ins[mesh_ins[:, 0] != mesh_ins[:, 1]]
    assert svc.submit("mesh", inserts=mesh_ins) == 1
    assert svc.submit("rmat", deletes=to_edge_list(gen.rmat_g(10))[:40]) == 1
    stats = svc.step()
    assert svc.version("mesh") == v0 + 1 and svc.pending("mesh") == 0
    assert set(stats) == {"mesh", "rmat"}
    sched1 = svc.vertex_schedule("mesh")
    assert sched1 is not sched0            # memo invalidated by version bump
    assert svc.vertex_schedule("mesh") is sched1

    # color classes really are independent sets of the current graph
    for name in svc.graphs():
        g = svc.graph(name)
        colors = svc.colors(name)
        assert col.is_proper(g, colors)
        for cls in svc.vertex_schedule(name):
            cset = set(cls.tolist())
            for v in cls:
                assert cset.isdisjoint(g.neighbors(v).tolist())

    # dst-bucket edge coloring artifact
    e, ec, k = svc.edge_colors("mesh")
    for c in range(k):
        d = e[ec == c][:, 1]
        assert len(np.unique(d)) == len(d)  # conflict-free scatter class

    svc.remove_graph("rmat")
    assert svc.graphs() == ["mesh"]
    with pytest.raises(KeyError):
        svc.colors("rmat")
    with pytest.raises(ValueError):
        svc.add_graph("mesh", gen.mesh2d(4, 4))


def test_service_rejects_bad_batch_at_submit():
    """Regression: a malformed batch must bounce at submit(), not poison
    the pending queue and livelock step()."""
    svc = ColoringService(delta_cap=64)
    svc.add_graph("a", gen.mesh2d(8, 8))
    with pytest.raises(ValueError):
        svc.submit("a", inserts=np.array([[0, 10 ** 9]]))
    assert svc.pending("a") == 0
    svc.submit("a", inserts=np.array([[0, 10]]))
    svc.step()                      # queue is healthy; this must not raise
    assert svc.version("a") == 1


def test_service_step_single_graph():
    svc = ColoringService(delta_cap=64)
    svc.add_graph("a", gen.mesh2d(8, 8))
    svc.add_graph("b", gen.mesh2d(8, 8))
    svc.submit("a", inserts=np.array([[0, 10]]))
    svc.submit("b", inserts=np.array([[0, 10]]))
    svc.step("a")
    assert svc.version("a") == 1 and svc.version("b") == 0
    assert svc.pending("b") == 1
