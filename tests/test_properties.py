"""Differential / property harness: every registered algorithm
(``coloring.ALGORITHMS``) plus the native distance-2 paths, cross-checked
against the serial oracles (``greedy_sequential`` on G and on the
materialized ``power_graph``) over RMAT, mesh, and bipartite families.

Invariants swept: properness, the greedy color bound, determinism under a
fixed seed, vertex-relabel invariance (properness always; color counts stay
in the same quality band — exact counts may shift because the engines
compose their own internal relabel with the external one), and the native
distance-2 engine never materializing G².

Hypothesis-optional with a seeded-numpy fallback, like tests/test_coloring.py
(the container has no network; hard-requiring hypothesis would make the
module uncollectable)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro import api
from repro.core import coloring as col
from repro.core import distance2 as d2
from repro.graphs import generators as gen
from repro.graphs.csr import from_edges, power_graph, shuffle_vertices


GRAPHS = {
    "rmat_b": gen.rmat_b(9, edge_factor=8),
    "mesh2d": gen.mesh2d(20, 20),
    "mesh3d": gen.mesh3d(6, 6, 6),
    "bipartite": gen.bipartite_random(300, 200, 4.0, seed=7),
}
ALGOS = sorted(col.ALGORITHMS)


def _star(n):
    return from_edges(n, np.stack(
        [np.zeros(n - 1, np.int64), np.arange(1, n, dtype=np.int64)], 1))


# --------------------------------------------------------------------------
# distance-1: every algorithm vs the serial oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("algo", ALGOS)
def test_differential_proper_vs_oracle(gname, algo):
    g = GRAPHS[gname]
    res = col.ALGORITHMS[algo](g, seed=7)
    assert col.is_proper(g, res.colors), f"{algo} defective on {gname}"
    assert res.n_colors <= g.max_degree + 1
    serial = col.n_colors_used(col.greedy_sequential(g))
    # same quality band as serial; the absolute floor covers low-chromatic
    # families (bipartite: serial greedy finds 2, speculative coloring ~6)
    assert res.n_colors <= max(serial * 1.5 + 2, 8)


@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("algo", ALGOS)
def test_determinism_under_fixed_seed(gname, algo):
    g = GRAPHS[gname]
    a = col.ALGORITHMS[algo](g, seed=3)
    b = col.ALGORITHMS[algo](g, seed=3)
    np.testing.assert_array_equal(a.colors, b.colors)
    assert a.summary() == b.summary()


@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("algo", ALGOS)
def test_forbidden_impl_parity(gname, algo):
    """The packed-bitset forbidden path (DESIGN.md §10) is bit-identical to
    the dense oracle on every engine: same colors, rounds, conflicts,
    retries — so gather-pass counts cannot regress by construction."""
    g = GRAPHS[gname]
    rb = col.ALGORITHMS[algo](g, seed=7, forbidden_impl="bitset")
    rd = col.ALGORITHMS[algo](g, seed=7, forbidden_impl="dense")
    np.testing.assert_array_equal(rb.colors, rd.colors)
    assert rb.summary() == rd.summary()


@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("algo", ALGOS)
def test_relabel_invariance(gname, algo):
    g = GRAPHS[gname]
    gs = shuffle_vertices(g, seed=11)
    r0 = col.ALGORITHMS[algo](g, seed=5)
    r1 = col.ALGORITHMS[algo](gs, seed=5)
    assert col.is_proper(gs, r1.colors)
    assert r1.n_colors <= g.max_degree + 1
    assert abs(r1.n_colors - r0.n_colors) <= max(3, 0.5 * r0.n_colors)


# --------------------------------------------------------------------------
# native distance-2 vs the materialized power_graph oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_native_d2_proper_on_power_graph(gname):
    g = GRAPHS[gname]
    res = api.color(g, distance=2, seed=1)
    assert d2.is_distance_d_proper(g, res.colors, 2)
    assert res.distance == 2
    gd = power_graph(g, 2)
    serial = col.n_colors_used(col.greedy_sequential(gd))
    assert res.n_colors <= gd.max_degree + 1
    assert res.n_colors <= serial * 1.5 + 2


def test_native_d2_matches_materialized_band():
    """Native and materialized paths are the same algorithm on the same
    conflict graph: identical seed must land in the same quality band."""
    g = GRAPHS["mesh3d"]
    nat = api.color(g, distance=2, seed=2)
    mat, gd = d2.color_distance_d(g, d=2, algorithm="rsoc", seed=2)
    assert nat.distance == 2 and mat.distance == 2
    assert col.is_proper(gd, nat.colors) and col.is_proper(gd, mat.colors)
    assert abs(nat.n_colors - mat.n_colors) <= max(3, 0.5 * mat.n_colors)


def test_native_d2_determinism():
    g = GRAPHS["mesh2d"]
    a = api.color(g, distance=2, seed=4)
    b = api.color(g, distance=2, seed=4)
    np.testing.assert_array_equal(a.colors, b.colors)


def test_native_d2_never_materializes(monkeypatch):
    """The acceptance property: the native path must not construct G² —
    any call into power_graph during coloring is a failure."""
    g = gen.mesh2d(12, 12)

    def boom(*a, **k):
        raise AssertionError("native path materialized G^2")

    monkeypatch.setattr(d2, "power_graph", boom)
    res = api.color(g, distance=2, seed=0)
    monkeypatch.undo()
    assert d2.is_distance_d_proper(g, res.colors, 2)


def test_native_d2_rejects_overflow_graphs():
    """Hubs wider than ell_cap would silently lose two-hop constraints in
    the COO side-channel — the native path must refuse, not miscolor."""
    g = _star(40)
    with pytest.raises(ValueError):
        api.color(g, distance=2, ell_cap=8)
    # the materialized oracle still handles it
    res, gd = d2.color_distance_d(g, d=2, algorithm="rsoc", ell_cap=8)
    assert col.is_proper(gd, res.colors)


def test_star_graph_d2_needs_n_colors():
    """Star S_n has diameter 2: every vertex is within two hops of every
    other, so the distance-2 chromatic number is exactly n."""
    g = _star(40)
    res = api.color(g, distance=2, seed=1)
    assert res.n_colors == 40
    assert d2.is_distance_d_proper(g, res.colors, 2)


# --------------------------------------------------------------------------
# bipartite partial coloring (one-sided distance-2)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("maker,n_left", [
    (lambda: gen.bipartite_random(300, 200, 4.0, seed=7), 300),
    (lambda: gen.bipartite_banded(200, 100, band=2), 200),
])
def test_bipartite_partial_proper_and_bounded(maker, n_left):
    g = maker()
    res = api.color(g, distance=2, mode="partial", n_left=n_left, seed=1)
    assert len(res.colors) == n_left
    assert d2.is_bipartite_partial_proper(g, n_left, res.colors)
    oracle = d2.bipartite_partial_oracle(g, n_left)
    assert d2.is_bipartite_partial_proper(g, n_left, oracle)
    assert res.n_colors <= col.n_colors_used(oracle) * 1.5 + 2


def test_bipartite_partial_determinism():
    g = GRAPHS["bipartite"]
    a = api.color(g, distance=2, mode="partial", n_left=300, seed=6)
    b = api.color(g, distance=2, mode="partial", n_left=300, seed=6)
    np.testing.assert_array_equal(a.colors, b.colors)


def test_complete_bipartite_left_needs_n_left_colors():
    """K_{a,b}: every pair of left vertices shares every right neighbor, so
    the one-sided distance-2 coloring needs exactly a colors."""
    a_n, b_n = 20, 5
    ii, jj = np.meshgrid(np.arange(a_n), np.arange(b_n), indexing="ij")
    g = from_edges(a_n + b_n,
                   np.stack([ii.ravel(), a_n + jj.ravel()], 1))
    res = api.color(g, distance=2, mode="partial", n_left=a_n, seed=0)
    assert res.n_colors == a_n
    assert d2.is_bipartite_partial_proper(g, a_n, res.colors)


# --------------------------------------------------------------------------
# randomized property sweeps (hypothesis when available, numpy otherwise)
# --------------------------------------------------------------------------

def _np_random_graph(rng):
    n = int(rng.integers(2, 100))
    m = int(rng.integers(0, 4 * n))
    edges = rng.integers(0, n, size=(m, 2))
    return from_edges(n, edges.astype(np.int64))


def _np_random_bipartite(rng):
    nl = int(rng.integers(2, 60))
    nr = int(rng.integers(1, 40))
    m = int(rng.integers(0, 4 * nl))
    src = rng.integers(0, nl, size=m)
    dst = nl + rng.integers(0, nr, size=m)
    return from_edges(nl + nr, np.stack([src, dst], 1).astype(np.int64)), nl


def _check_native_d2(g, seed):
    res = api.color(g, distance=2, seed=seed)
    assert d2.is_distance_d_proper(g, res.colors, 2)
    gd = power_graph(g, 2)
    assert res.n_colors <= gd.max_degree + 1


def _check_bipartite_partial(g, nl, seed):
    res = api.color(g, distance=2, mode="partial", n_left=nl, seed=seed)
    assert d2.is_bipartite_partial_proper(g, nl, res.colors)


if HAVE_HYPOTHESIS:
    @st.composite
    def random_graph(draw):
        n = draw(st.integers(2, 100))
        m = draw(st.integers(0, 4 * n))
        edges = draw(st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=m, max_size=m))
        return from_edges(n, np.array(edges, dtype=np.int64).reshape(-1, 2))

    @given(random_graph(), st.integers(0, 2))
    @settings(max_examples=15, deadline=None)
    def test_property_native_d2_proper(g, seed):
        _check_native_d2(g, seed)

    @given(st.integers(2, 60), st.integers(1, 40), st.integers(0, 2))
    @settings(max_examples=15, deadline=None)
    def test_property_bipartite_partial_proper(nl, nr, seed):
        rng = np.random.default_rng(nl * 100 + nr)
        m = int(rng.integers(0, 4 * nl))
        src = rng.integers(0, nl, size=m)
        dst = nl + rng.integers(0, nr, size=m)
        g = from_edges(nl + nr, np.stack([src, dst], 1).astype(np.int64))
        _check_bipartite_partial(g, nl, seed)
else:
    @pytest.mark.parametrize("case", range(6))
    def test_property_native_d2_proper(case):
        rng = np.random.default_rng(3000 + case)
        _check_native_d2(_np_random_graph(rng), case)

    @pytest.mark.parametrize("case", range(6))
    def test_property_bipartite_partial_proper(case):
        rng = np.random.default_rng(4000 + case)
        g, nl = _np_random_bipartite(rng)
        _check_bipartite_partial(g, nl, case)
