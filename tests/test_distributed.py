"""Multi-device coloring (shard_map engines) on host CPU devices.

Uses a subprocess-free trick: these tests run in their own pytest process
where conftest leaves device count at 1 — so we spawn a dedicated
subprocess with XLA_FLAGS for the multi-device cases."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
from repro import api
from repro.core import coloring as col
from repro.graphs import generators as gen

mesh = jax.make_mesh((8,), ("data",))
out = {}
for gname, g in [("mesh2d", gen.mesh2d(24, 24)),
                 ("rmat", gen.rmat_b(9, 8))]:
    for algo in ("rsoc", "cat"):
        res = api.color(g, algorithm=algo, backend="distributed",
                        mesh=mesh, axis="data", seed=1, n_chunks=2,
                        max_rounds=64)
        out[f"{gname}.{algo}"] = {
            "proper": bool(col.is_proper(g, res.colors)),
            "colors": int(res.n_colors),
            "rounds": int(res.n_rounds),
            "gather_passes": int(res.gather_passes),
            "bound": int(g.max_degree + 1),
        }

# halo-exchange GNN == replicated GNN (EXPERIMENTS.md §Perf B).
# Ring graph: every vertex has degree 2, so per-shard edge counts are
# exactly equal -> no padding needed and the comparison is exact.
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.core.partition import block_partition, build_halo
from repro.graphs.csr import from_edges, to_edge_list
from repro.models import gnn as GNN

n = 256
ring = from_edges(n, np.stack([np.arange(n), (np.arange(n) + 1) % n], 1))
D = 8
part = block_partition(ring, D, seed=0)
plan = build_halo(part)
cfg = GNN.GatedGCNConfig(n_layers=3, d_hidden=8, d_in=6, d_out=3)
params = GNN.gatedgcn_init(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
n_loc = part.n_loc
feats_g = rng.standard_normal((part.n_pad, 6)).astype(np.float32)
labels_g = rng.integers(0, 3, part.n_pad).astype(np.int32)
mask_g = np.ones(part.n_pad, np.float32)
W = plan.ell_local.shape[-1]
src_l, dst_l = [], []
for d in range(D):
    ell = plan.ell_local[d]
    srcs = ell.reshape(-1)
    dsts = np.repeat(np.arange(n_loc, dtype=np.int32), W)
    keep = srcs >= 0
    src_l.append(srcs[keep])
    dst_l.append(dsts[keep])
counts = [len(x) for x in src_l]
assert len(set(counts)) == 1, counts
batch = {
    "feats": feats_g,
    "src": np.stack(src_l).reshape(-1).astype(np.int32),
    "dst": np.stack(dst_l).reshape(-1).astype(np.int32),
    "boundary": plan.boundary.reshape(-1).astype(np.int32),
    "ghost_flat": np.where(
        plan.ghost_owner >= 0,
        plan.ghost_owner * plan.max_b + plan.ghost_slot, -1
    ).reshape(-1).astype(np.int32),
    "labels": labels_g,
    "train_mask": mask_g,
}
batch = {k: jnp.asarray(v) for k, v in batch.items()}
shard = P("data")
halo_loss = shard_map(
    lambda p, b: GNN.gatedgcn_halo_loss(p, cfg, b, ("data",), D),
    mesh=mesh, in_specs=(P(), {k: shard for k in batch}),
    out_specs=P(), check_rep=False)
lv = float(halo_loss(params, batch))
e = to_edge_list(part.graph)
logits = GNN.gatedgcn_apply(params, cfg, jnp.asarray(feats_g),
                            jnp.asarray(e[:, 0].astype(np.int32)),
                            jnp.asarray(e[:, 1].astype(np.int32)),
                            part.n_pad)
lo = float(GNN.node_classification_loss(logits, jnp.asarray(labels_g),
                                        jnp.asarray(mask_g)))
out["halo_gnn"] = {"halo_loss": lv, "oracle_loss": lo,
                   "rel_err": abs(lv - lo) / max(abs(lo), 1e-9)}
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=500)
    assert p.returncode == 0, p.stderr[-2000:]
    return json.loads(p.stdout.strip().splitlines()[-1])


def test_distributed_proper(dist_results):
    for key, r in dist_results.items():
        if "." not in key:
            continue
        assert r["proper"], key
        assert r["colors"] <= r["bound"], key


def test_distributed_rsoc_fewer_collectives(dist_results):
    """DESIGN §2: RSOC-JAX runs 1 collective/round vs CAT's 2 — with rounds
    comparable, total gather passes must be lower."""
    for gname in ("mesh2d", "rmat"):
        r = dist_results[f"{gname}.rsoc"]
        c = dist_results[f"{gname}.cat"]
        assert r["gather_passes"] < c["gather_passes"], gname


def test_halo_gnn_matches_replicated(dist_results):
    """§Perf B: the halo-exchange GatedGCN equals the replicated oracle."""
    r = dist_results["halo_gnn"]
    assert r["rel_err"] < 1e-5, r
