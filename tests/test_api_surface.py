"""Public-API snapshot: the surface of ``repro.api`` (exported names, spec
fields + defaults, PassContext fields, and the engine support matrix) is
diffed against the checked-in snapshot ``tests/api_surface.json``.

An intentional API change must update the snapshot in the same commit —
regenerate with:

    PYTHONPATH=src python tests/test_api_surface.py --update

An *unintentional* diff (a renamed spec field, a dropped export, an engine
silently falling out of the registry) fails here before it ships.  Runs
under ``make test`` with the rest of the tier-1 suite.
"""
import dataclasses
import json
import os
import sys

from repro import api, registry

SNAPSHOT_PATH = os.path.join(os.path.dirname(__file__), "api_surface.json")


def current_surface() -> dict:
    return {
        "api_all": sorted(api.__all__),
        "spec_fields": {
            f.name: repr(f.default)
            for f in dataclasses.fields(api.ColoringSpec)},
        "pass_context_fields": [
            f.name for f in dataclasses.fields(api.PassContext)],
        "engines": [
            {"algorithm": a, "distance": d, "mode": m, "backend": b,
             "replaces": fn.replaces}
            for (a, d, m, b), fn in registry.engine_items()],
        "modes": list(api.MODES),
        "backends": list(api.BACKENDS),
    }


def test_api_surface_matches_snapshot():
    with open(SNAPSHOT_PATH) as f:
        want = json.load(f)
    got = current_surface()
    assert got == want, (
        "repro.api surface drifted from tests/api_surface.json — if the "
        "change is intentional, regenerate the snapshot with "
        "`PYTHONPATH=src python tests/test_api_surface.py --update` and "
        "commit it; diff keys: "
        + str([k for k in want if want.get(k) != got.get(k)]
              + [k for k in got if k not in want]))


def test_every_exported_name_exists():
    for name in api.__all__:
        assert hasattr(api, name), name


if __name__ == "__main__":
    if "--update" in sys.argv:
        with open(SNAPSHOT_PATH, "w") as f:
            json.dump(current_surface(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {SNAPSHOT_PATH}")
    else:
        print(json.dumps(current_surface(), indent=1, sort_keys=True))
