"""Per-kernel parity tests: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes.

The coloring refs carry a forbidden-set ``impl`` switch ("bitset" packed
words vs "dense" one-hot, DESIGN.md §10); parity is asserted against BOTH,
so each test cross-checks three corners (kernel, bitset ref, dense ref).
``REPRO_KERNEL_BACKEND`` selects the ops-dispatch backend the agreement
tests pit against jnp — CI runs the module once per backend.
"""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref, ops
from repro.kernels.firstfit import firstfit
from repro.kernels.detect_recolor import detect_recolor
from repro.kernels.ell_spmm import ell_spmm
from repro.kernels.flash_attention import flash_attention
from repro.kernels.twohop import twohop_detect_recolor


# ops-dispatch backend under test (CI runs both: pallas_interpret and jnp)
DISPATCH_BACKEND = os.environ.get("REPRO_KERNEL_BACKEND", "pallas_interpret")
REF_IMPLS = ("bitset", "dense")


def _rand_ell(rng, R, W, n, frac_fill=0.3):
    ell = rng.integers(0, n, size=(R, W)).astype(np.int32)
    ell[rng.random((R, W)) < frac_fill] = -1
    return ell


@pytest.mark.parametrize("impl", REF_IMPLS)
@pytest.mark.parametrize("R,W,n,C", [
    (256, 8, 1024, 32), (512, 32, 512, 64), (256, 1, 64, 32), (1024, 16, 4096, 128),
])
def test_firstfit_matches_ref(R, W, n, C, impl):
    rng = np.random.default_rng(R + W)
    ell = _rand_ell(rng, R, W, n)
    colors = rng.integers(-1, C - 1, size=(n,)).astype(np.int32)
    got_mex, got_ovf = firstfit(jnp.asarray(ell), jnp.asarray(colors), C=C,
                                interpret=True)
    want_mex, want_ovf = ref.firstfit_ref(jnp.asarray(ell),
                                          jnp.asarray(colors), C, impl=impl)
    np.testing.assert_array_equal(got_mex, want_mex)
    np.testing.assert_array_equal(got_ovf, want_ovf)


@pytest.mark.parametrize("impl", REF_IMPLS)
@pytest.mark.parametrize("R,W,n,C,row_start", [
    (256, 8, 1024, 32, 0), (256, 16, 1024, 64, 256), (512, 4, 2048, 32, 1024),
])
def test_detect_recolor_matches_ref(R, W, n, C, row_start, impl):
    rng = np.random.default_rng(R * W)
    ell = _rand_ell(rng, R, W, n)
    colors = rng.integers(0, C // 2, size=(n,)).astype(np.int32)
    pri = rng.permutation(n).astype(np.int32)
    U = rng.random(R) < 0.7
    args = (jnp.asarray(ell), jnp.asarray(colors), jnp.asarray(pri),
            jnp.asarray(U))
    got = detect_recolor(*args, row_start=row_start, C=C, interpret=True)
    want = ref.detect_recolor_ref(args[0], args[1], args[2], row_start,
                                  args[3], C, impl=impl)
    for g, w, name in zip(got, want, ("newc", "recolored", "ovf")):
        np.testing.assert_array_equal(g, w, err_msg=name)


@pytest.mark.parametrize("impl", REF_IMPLS)
@pytest.mark.parametrize("R,W,n,C,row_start", [
    (128, 4, 512, 32, 0), (128, 8, 512, 64, 128), (256, 2, 1024, 32, 256),
    (128, 6, 128, 32, 0),        # rows == whole table (self-heavy)
])
def test_twohop_matches_ref(R, W, n, C, row_start, impl):
    """Fused two-hop kernel vs jnp oracle, bit-for-bit."""
    rng = np.random.default_rng(R * W + C)
    ell_all = _rand_ell(rng, n, W, n)
    ell_rows = ell_all[row_start:row_start + R]
    colors = rng.integers(0, C // 2, size=(n,)).astype(np.int32)
    pri = rng.permutation(n).astype(np.int32)
    U = rng.random(R) < 0.7
    args = (jnp.asarray(ell_rows), jnp.asarray(ell_all), jnp.asarray(colors),
            jnp.asarray(pri), jnp.asarray(U))
    got = twohop_detect_recolor(*args, row_start=row_start, C=C,
                                interpret=True)
    want = ref.twohop_ref(args[0], args[1], args[2], args[3], row_start,
                          args[4], C, impl=impl)
    for g, w, name in zip(got, want, ("newc", "recolored", "ovf")):
        np.testing.assert_array_equal(g, w, err_msg=name)


@pytest.mark.parametrize("impl", REF_IMPLS)
@pytest.mark.parametrize("kernel", ["firstfit", "detect_recolor", "twohop"])
def test_kernel_backends_agree_under_saturation(kernel, impl):
    """The env-selected dispatch backend vs the jnp oracle (in both
    forbidden impls) agree bit-for-bit through the ops dispatch layer, on
    inputs dense enough that the forbidden set saturates C on some rows —
    the overflow (ovf) flags must match too, and fire.  Note C=4 is NOT a
    multiple of 32: the packed path's tail-masking is load-bearing here."""
    if DISPATCH_BACKEND == "jnp" and impl == "bitset":
        pytest.skip("backend=jnp with impl=bitset is the identical "
                    "invocation on both sides — nothing to compare")
    rng = np.random.default_rng(
        {"firstfit": 11, "detect_recolor": 22, "twohop": 33}[kernel])
    n, W, R, C = 512, 16, 256, 4
    ell_all = _rand_ell(rng, n, W, n, frac_fill=0.05)
    colors = rng.integers(0, C, size=(n,)).astype(np.int32)
    pri = rng.permutation(n).astype(np.int32)
    U = np.ones(R, bool)
    if kernel == "firstfit":
        a = ops.firstfit(jnp.asarray(ell_all[:R]), jnp.asarray(colors), C=C,
                         backend="jnp", impl=impl)
        b = ops.firstfit(jnp.asarray(ell_all[:R]), jnp.asarray(colors), C=C,
                         backend=DISPATCH_BACKEND)
        ovf = a[1]
    elif kernel == "detect_recolor":
        args = (jnp.asarray(ell_all[:R]), jnp.asarray(colors),
                jnp.asarray(pri), jnp.asarray(U))
        a = ops.detect_recolor(*args, row_start=0, C=C, backend="jnp",
                               impl=impl)
        b = ops.detect_recolor(*args, row_start=0, C=C,
                               backend=DISPATCH_BACKEND)
        ovf = a[2]
    else:
        args = (jnp.asarray(ell_all[:R]), jnp.asarray(ell_all),
                jnp.asarray(colors), jnp.asarray(pri), jnp.asarray(U))
        a = ops.twohop(*args, row_start=0, C=C, backend="jnp", impl=impl)
        b = ops.twohop(*args, row_start=0, C=C, backend=DISPATCH_BACKEND)
        ovf = a[2]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert np.asarray(ovf).any(), "saturation case must trip ovf flags"


@pytest.mark.parametrize("op", ["sum", "mean", "max"])
@pytest.mark.parametrize("R,W,n,d,dtype", [
    (128, 8, 256, 128, np.float32),
    (256, 16, 1024, 256, np.float32),
    (128, 4, 512, 128, jnp.bfloat16),
])
def test_ell_spmm_matches_ref(op, R, W, n, d, dtype):
    rng = np.random.default_rng(R + d)
    ell = _rand_ell(rng, R, W, n)
    feats = rng.standard_normal((n, d)).astype(np.float32)
    feats = jnp.asarray(feats).astype(dtype)
    got = ell_spmm(jnp.asarray(ell), feats, op=op, interpret=True)
    want = ref.ell_spmm_ref(jnp.asarray(ell), feats, op)
    rtol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=rtol,
                               atol=1e-5 if dtype == np.float32 else 1e-1)


def test_ell_spmm_isolated_vertex():
    """All-FILL rows aggregate to zero (no NaN from empty max)."""
    ell = jnp.full((128, 4), -1, jnp.int32)
    feats = jnp.ones((64, 128), jnp.float32)
    for op in ("sum", "mean", "max"):
        out = ell_spmm(ell, feats, op=op, interpret=True)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(np.asarray(out), 0.0)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,Hq,Hkv,Lq,Lk,D", [
    (1, 4, 4, 128, 128, 64),
    (2, 8, 2, 128, 256, 64),    # GQA + decode-style Lk > Lq
    (1, 2, 1, 256, 256, 128),   # MQA
])
def test_flash_attention_matches_ref(causal, B, Hq, Hkv, Lq, Lk, D):
    rng = np.random.default_rng(Lq + D)
    q = jnp.asarray(rng.standard_normal((B, Hq, Lq, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, Hkv, Lk, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, Hkv, Lk, D)).astype(np.float32))
    got = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_ops_dispatch_jnp_cpu():
    """On CPU auto-dispatch uses the jnp path and agrees with pallas_interpret."""
    rng = np.random.default_rng(0)
    ell = jnp.asarray(_rand_ell(rng, 256, 8, 512))
    colors = jnp.asarray(rng.integers(-1, 16, size=(512,)).astype(np.int32))
    a = ops.firstfit(ell, colors, C=32, backend="auto")
    b = ops.firstfit(ell, colors, C=32, backend="pallas_interpret")
    np.testing.assert_array_equal(a[0], b[0])


# --------------------------------------------------------------------------
# VMEM paging boundary (DESIGN.md §8.3): table sizes straddling the old 8 MB
# residency bound stay on the Pallas path — zero kernels.fallback — and match
# the oracle bit-for-bit.  Before paging, the 'above' shape silently fell
# back to jnp.
# --------------------------------------------------------------------------

from repro.obs import metrics as obs_metrics

_W16_8MB_ROWS = ops.VMEM_BUDGET_BYTES // (16 * 4)   # table rows at the bound


@pytest.mark.parametrize("n_all", [_W16_8MB_ROWS - 1024, _W16_8MB_ROWS,
                                   _W16_8MB_ROWS + 1024])
def test_twohop_paged_parity_at_vmem_boundary(n_all):
    W, R, C = 16, 256, 32
    table_mb = n_all * W * 4 / 2**20
    rng = np.random.default_rng(n_all)
    ell_all = _rand_ell(rng, n_all, W, n_all)
    colors = rng.integers(0, C // 2, size=(n_all,)).astype(np.int32)
    pri = rng.permutation(n_all).astype(np.int32)
    U = rng.random(R) < 0.7
    args = (jnp.asarray(ell_all[:R]), jnp.asarray(ell_all),
            jnp.asarray(colors), jnp.asarray(pri), jnp.asarray(U))
    fb0 = obs_metrics.total_matching("kernels.fallback")
    got = ops.twohop(*args, row_start=0, C=C, backend="pallas_interpret")
    assert obs_metrics.total_matching("kernels.fallback") == fb0, \
        f"{table_mb:.2f}MB table is pageable and must not fall back"
    want = ref.twohop_ref(args[0], args[1], args[2], args[3], 0, args[4], C)
    for g, w, name in zip(got, want, ("newc", "recolored", "ovf")):
        np.testing.assert_array_equal(g, w, err_msg=name)


@pytest.mark.parametrize("page_rows,row_start", [(96, 0), (100, 128),
                                                 (256, 256)])
def test_twohop_ragged_pages_parity(page_rows, row_start):
    """Explicit page sizes that do NOT divide the table (ragged last page,
    -1-padded) and offset row windows, vs the oracle."""
    n, W, R, C = 1000, 8, 128, 32
    rng = np.random.default_rng(page_rows + row_start)
    ell_all = _rand_ell(rng, n, W, n)
    colors = rng.integers(0, C // 2, size=(n,)).astype(np.int32)
    pri = rng.permutation(n).astype(np.int32)
    U = rng.random(R) < 0.7
    args = (jnp.asarray(ell_all[row_start:row_start + R]),
            jnp.asarray(ell_all), jnp.asarray(colors), jnp.asarray(pri),
            jnp.asarray(U))
    got = twohop_detect_recolor(*args, row_start=row_start, C=C,
                                page_rows=page_rows, interpret=True)
    want = ref.twohop_ref(args[0], args[1], args[2], args[3], row_start,
                          args[4], C)
    for g, w, name in zip(got, want, ("newc", "recolored", "ovf")):
        np.testing.assert_array_equal(g, w, err_msg=name)


@pytest.mark.parametrize("kernel,n,expect_fallback", [
    # firstfit resident set ≈ n*4 + 69 KB: 2.0M is just under the 8 MB
    # budget (Pallas path), 2.2M just over (counted jnp fallback)
    ("firstfit", 2_000_000, False),
    ("firstfit", 2_200_000, True),
    # detect_recolor carries colors AND priorities (2n*4 + ~75 KB)
    ("detect_recolor", 1_000_000, False),
    ("detect_recolor", 1_100_000, True),
])
def test_vector_bound_dispatch_and_parity(kernel, n, expect_fallback):
    """The un-pageable (n,) vectors are the only remaining size cliff: just
    under the budget dispatches Pallas, just over counts a vmem fallback —
    and both sides stay bit-identical to the oracle."""
    R, W, C = 512, 32, 32
    rng = np.random.default_rng(n % 9973)
    ell = _rand_ell(rng, R, W, n)
    colors = rng.integers(0, C // 2, size=(n,)).astype(np.int32)
    fb0 = obs_metrics.total_matching("kernels.fallback")
    if kernel == "firstfit":
        args = (jnp.asarray(ell), jnp.asarray(colors))
        got = ops.firstfit(*args, C=C, backend="pallas_interpret")
        want = ref.firstfit_ref(*args, C)
    else:
        pri = rng.permutation(n).astype(np.int32)
        U = rng.random(R) < 0.7
        args = (jnp.asarray(ell), jnp.asarray(colors), jnp.asarray(pri),
                jnp.asarray(U))
        got = ops.detect_recolor(*args, row_start=0, C=C,
                                 backend="pallas_interpret")
        want = ref.detect_recolor_ref(args[0], args[1], args[2], 0, args[3],
                                      C)
    fb = obs_metrics.total_matching("kernels.fallback") - fb0
    assert fb == (1 if expect_fallback else 0)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_ell_aggregate_real_width_no_false_fallback():
    """Narrow features stay on the Pallas path: the honest estimator charges
    the real min(block_feats, d) panel width, where the old hardcoded
    128-lane estimate (n*128*4 = 16 MB here) forced a silent jnp fallback."""
    R, W, n, d = 256, 4, 32768, 16
    assert n * 128 * 4 > ops.VMEM_BUDGET_BYTES          # the old estimate
    assert ops.vmem_bytes("ell_aggregate", R=R, W=W, n=n,
                          d=d) < ops.VMEM_BUDGET_BYTES  # the honest one
    rng = np.random.default_rng(5)
    ell = jnp.asarray(_rand_ell(rng, R, W, n))
    feats = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    fb0 = obs_metrics.total_matching("kernels.fallback")
    got = ops.ell_aggregate(ell, feats, backend="pallas_interpret")
    assert obs_metrics.total_matching("kernels.fallback") == fb0
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.ell_spmm_ref(ell, feats,
                                                           "sum")),
                               rtol=1e-5, atol=1e-5)


def test_ell_aggregate_wide_panel_falls_back():
    """A genuinely over-budget double-buffered panel (d > block_feats) is
    caught BEFORE any compile and counted as a vmem fallback."""
    R, W, n, d = 128, 4, 16384, 256
    assert ops.vmem_bytes("ell_aggregate", R=R, W=W, n=n,
                          d=d) > ops.VMEM_BUDGET_BYTES
    rng = np.random.default_rng(6)
    ell = jnp.asarray(_rand_ell(rng, R, W, n))
    feats = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    fb0 = obs_metrics.total_matching("kernels.fallback")
    got = ops.ell_aggregate(ell, feats, backend="pallas")   # safe: falls back
    assert obs_metrics.total_matching("kernels.fallback") == fb0 + 1
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.ell_spmm_ref(ell, feats,
                                                           "sum")),
                               rtol=1e-5, atol=1e-5)


def test_vmem_bytes_accounting_pinned():
    """Pin the estimators term-by-term so a silent accounting change (the
    bug class this PR fixes) breaks a unit test, not a benchmark."""
    from repro.core import bitset

    # firstfit, BV capped by block_rows=256: 2×ELL tile + colors + packed
    # forbidden (C=32 -> 1 word) + 2×(mex+ovf)
    assert ops.vmem_bytes("firstfit", R=1024, W=8, n=4096, C=32) == (
        2 * 256 * 8 * 4 + 4096 * 4 + 256 * 4 + 2 * 256 * (4 + 1))
    # BV capped by R when the tile is short
    assert ops.vmem_bytes("firstfit", R=64, W=8, n=256, C=32) == (
        2 * 64 * 8 * 4 + 256 * 4 + 64 * 4 + 2 * 64 * (4 + 1))
    # detect_recolor adds priorities + U/rowc/rowp + defect + rec outputs
    assert ops.vmem_bytes("detect_recolor", R=512, W=16, n=2048, C=64) == (
        2 * 256 * 16 * 4 + 2 * 2048 * 4 + 2 * 256 * (1 + 4 + 4)
        + 256 * bitset.n_words(64) * 4 + 256 * 4 + 2 * 256 * (4 + 1 + 1))
    # twohop with an explicit page size: 2 pages resident, never the table
    assert ops.vmem_bytes("twohop", R=256, W=8, n=10_000, C=32,
                          block_rows=128, page_rows=512) == (
        2 * 128 * 8 * 4 + 2 * 512 * 8 * 4 + 2 * 10_000 * 4
        + 2 * 128 * (1 + 4 + 4 + 4) + 128 * 8 * 4 + 128 * 4 + 128 * 4
        + 2 * 128 * (4 + 1 + 1))
    # the twohop estimate is page_rows-resident, not n_all-resident: growing
    # the table 100x must not change the estimate
    small = ops.vmem_bytes("twohop", R=256, W=8, n=10_000, C=32,
                           page_rows=512, n_all=10_000)
    big = ops.vmem_bytes("twohop", R=256, W=8, n=10_000, C=32,
                         page_rows=512, n_all=1_000_000)
    assert small == big
    # ell_aggregate: single-buffered panel at the REAL width when d fits
    assert ops.vmem_bytes("ell_aggregate", R=256, W=4, n=1024, d=16) == (
        2 * 128 * 4 * 4 + 1 * 1024 * 16 * 4 + 128 * 16 * 4
        + 2 * 128 * 16 * 4)
    # ...double-buffered at block_feats when the feature axis pages
    assert ops.vmem_bytes("ell_aggregate", R=256, W=4, n=1024, d=256) == (
        2 * 128 * 4 * 4 + 2 * 1024 * 128 * 4 + 128 * 128 * 4
        + 2 * 128 * 128 * 4)
    with pytest.raises(ValueError, match="unknown kernel"):
        ops.vmem_bytes("attention", R=1, W=1, n=1, C=1)


def test_ref_impls_agree_cross():
    """bitset ref == dense ref on identical inputs (the unit-level corner
    of the differential square; the engine level lives in test_bitset.py)."""
    rng = np.random.default_rng(42)
    for C in (32, 64, 96, 256):
        ell = jnp.asarray(_rand_ell(rng, 128, 12, 256))
        colors = jnp.asarray(
            rng.integers(-1, C + 8, size=(256,)).astype(np.int32))
        a = ref.firstfit_ref(ell, colors, C, impl="bitset")
        b = ref.firstfit_ref(ell, colors, C, impl="dense")
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
