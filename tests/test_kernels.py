"""Per-kernel parity tests: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes.

The coloring refs carry a forbidden-set ``impl`` switch ("bitset" packed
words vs "dense" one-hot, DESIGN.md §10); parity is asserted against BOTH,
so each test cross-checks three corners (kernel, bitset ref, dense ref).
``REPRO_KERNEL_BACKEND`` selects the ops-dispatch backend the agreement
tests pit against jnp — CI runs the module once per backend.
"""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref, ops
from repro.kernels.firstfit import firstfit
from repro.kernels.detect_recolor import detect_recolor
from repro.kernels.ell_spmm import ell_spmm
from repro.kernels.flash_attention import flash_attention
from repro.kernels.twohop import twohop_detect_recolor


# ops-dispatch backend under test (CI runs both: pallas_interpret and jnp)
DISPATCH_BACKEND = os.environ.get("REPRO_KERNEL_BACKEND", "pallas_interpret")
REF_IMPLS = ("bitset", "dense")


def _rand_ell(rng, R, W, n, frac_fill=0.3):
    ell = rng.integers(0, n, size=(R, W)).astype(np.int32)
    ell[rng.random((R, W)) < frac_fill] = -1
    return ell


@pytest.mark.parametrize("impl", REF_IMPLS)
@pytest.mark.parametrize("R,W,n,C", [
    (256, 8, 1024, 32), (512, 32, 512, 64), (256, 1, 64, 32), (1024, 16, 4096, 128),
])
def test_firstfit_matches_ref(R, W, n, C, impl):
    rng = np.random.default_rng(R + W)
    ell = _rand_ell(rng, R, W, n)
    colors = rng.integers(-1, C - 1, size=(n,)).astype(np.int32)
    got_mex, got_ovf = firstfit(jnp.asarray(ell), jnp.asarray(colors), C=C,
                                interpret=True)
    want_mex, want_ovf = ref.firstfit_ref(jnp.asarray(ell),
                                          jnp.asarray(colors), C, impl=impl)
    np.testing.assert_array_equal(got_mex, want_mex)
    np.testing.assert_array_equal(got_ovf, want_ovf)


@pytest.mark.parametrize("impl", REF_IMPLS)
@pytest.mark.parametrize("R,W,n,C,row_start", [
    (256, 8, 1024, 32, 0), (256, 16, 1024, 64, 256), (512, 4, 2048, 32, 1024),
])
def test_detect_recolor_matches_ref(R, W, n, C, row_start, impl):
    rng = np.random.default_rng(R * W)
    ell = _rand_ell(rng, R, W, n)
    colors = rng.integers(0, C // 2, size=(n,)).astype(np.int32)
    pri = rng.permutation(n).astype(np.int32)
    U = rng.random(R) < 0.7
    args = (jnp.asarray(ell), jnp.asarray(colors), jnp.asarray(pri),
            jnp.asarray(U))
    got = detect_recolor(*args, row_start=row_start, C=C, interpret=True)
    want = ref.detect_recolor_ref(args[0], args[1], args[2], row_start,
                                  args[3], C, impl=impl)
    for g, w, name in zip(got, want, ("newc", "recolored", "ovf")):
        np.testing.assert_array_equal(g, w, err_msg=name)


@pytest.mark.parametrize("impl", REF_IMPLS)
@pytest.mark.parametrize("R,W,n,C,row_start", [
    (128, 4, 512, 32, 0), (128, 8, 512, 64, 128), (256, 2, 1024, 32, 256),
    (128, 6, 128, 32, 0),        # rows == whole table (self-heavy)
])
def test_twohop_matches_ref(R, W, n, C, row_start, impl):
    """Fused two-hop kernel vs jnp oracle, bit-for-bit."""
    rng = np.random.default_rng(R * W + C)
    ell_all = _rand_ell(rng, n, W, n)
    ell_rows = ell_all[row_start:row_start + R]
    colors = rng.integers(0, C // 2, size=(n,)).astype(np.int32)
    pri = rng.permutation(n).astype(np.int32)
    U = rng.random(R) < 0.7
    args = (jnp.asarray(ell_rows), jnp.asarray(ell_all), jnp.asarray(colors),
            jnp.asarray(pri), jnp.asarray(U))
    got = twohop_detect_recolor(*args, row_start=row_start, C=C,
                                interpret=True)
    want = ref.twohop_ref(args[0], args[1], args[2], args[3], row_start,
                          args[4], C, impl=impl)
    for g, w, name in zip(got, want, ("newc", "recolored", "ovf")):
        np.testing.assert_array_equal(g, w, err_msg=name)


@pytest.mark.parametrize("impl", REF_IMPLS)
@pytest.mark.parametrize("kernel", ["firstfit", "detect_recolor", "twohop"])
def test_kernel_backends_agree_under_saturation(kernel, impl):
    """The env-selected dispatch backend vs the jnp oracle (in both
    forbidden impls) agree bit-for-bit through the ops dispatch layer, on
    inputs dense enough that the forbidden set saturates C on some rows —
    the overflow (ovf) flags must match too, and fire.  Note C=4 is NOT a
    multiple of 32: the packed path's tail-masking is load-bearing here."""
    if DISPATCH_BACKEND == "jnp" and impl == "bitset":
        pytest.skip("backend=jnp with impl=bitset is the identical "
                    "invocation on both sides — nothing to compare")
    rng = np.random.default_rng(
        {"firstfit": 11, "detect_recolor": 22, "twohop": 33}[kernel])
    n, W, R, C = 512, 16, 256, 4
    ell_all = _rand_ell(rng, n, W, n, frac_fill=0.05)
    colors = rng.integers(0, C, size=(n,)).astype(np.int32)
    pri = rng.permutation(n).astype(np.int32)
    U = np.ones(R, bool)
    if kernel == "firstfit":
        a = ops.firstfit(jnp.asarray(ell_all[:R]), jnp.asarray(colors), C=C,
                         backend="jnp", impl=impl)
        b = ops.firstfit(jnp.asarray(ell_all[:R]), jnp.asarray(colors), C=C,
                         backend=DISPATCH_BACKEND)
        ovf = a[1]
    elif kernel == "detect_recolor":
        args = (jnp.asarray(ell_all[:R]), jnp.asarray(colors),
                jnp.asarray(pri), jnp.asarray(U))
        a = ops.detect_recolor(*args, row_start=0, C=C, backend="jnp",
                               impl=impl)
        b = ops.detect_recolor(*args, row_start=0, C=C,
                               backend=DISPATCH_BACKEND)
        ovf = a[2]
    else:
        args = (jnp.asarray(ell_all[:R]), jnp.asarray(ell_all),
                jnp.asarray(colors), jnp.asarray(pri), jnp.asarray(U))
        a = ops.twohop(*args, row_start=0, C=C, backend="jnp", impl=impl)
        b = ops.twohop(*args, row_start=0, C=C, backend=DISPATCH_BACKEND)
        ovf = a[2]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert np.asarray(ovf).any(), "saturation case must trip ovf flags"


@pytest.mark.parametrize("op", ["sum", "mean", "max"])
@pytest.mark.parametrize("R,W,n,d,dtype", [
    (128, 8, 256, 128, np.float32),
    (256, 16, 1024, 256, np.float32),
    (128, 4, 512, 128, jnp.bfloat16),
])
def test_ell_spmm_matches_ref(op, R, W, n, d, dtype):
    rng = np.random.default_rng(R + d)
    ell = _rand_ell(rng, R, W, n)
    feats = rng.standard_normal((n, d)).astype(np.float32)
    feats = jnp.asarray(feats).astype(dtype)
    got = ell_spmm(jnp.asarray(ell), feats, op=op, interpret=True)
    want = ref.ell_spmm_ref(jnp.asarray(ell), feats, op)
    rtol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=rtol,
                               atol=1e-5 if dtype == np.float32 else 1e-1)


def test_ell_spmm_isolated_vertex():
    """All-FILL rows aggregate to zero (no NaN from empty max)."""
    ell = jnp.full((128, 4), -1, jnp.int32)
    feats = jnp.ones((64, 128), jnp.float32)
    for op in ("sum", "mean", "max"):
        out = ell_spmm(ell, feats, op=op, interpret=True)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(np.asarray(out), 0.0)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,Hq,Hkv,Lq,Lk,D", [
    (1, 4, 4, 128, 128, 64),
    (2, 8, 2, 128, 256, 64),    # GQA + decode-style Lk > Lq
    (1, 2, 1, 256, 256, 128),   # MQA
])
def test_flash_attention_matches_ref(causal, B, Hq, Hkv, Lq, Lk, D):
    rng = np.random.default_rng(Lq + D)
    q = jnp.asarray(rng.standard_normal((B, Hq, Lq, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, Hkv, Lk, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, Hkv, Lk, D)).astype(np.float32))
    got = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_ops_dispatch_jnp_cpu():
    """On CPU auto-dispatch uses the jnp path and agrees with pallas_interpret."""
    rng = np.random.default_rng(0)
    ell = jnp.asarray(_rand_ell(rng, 256, 8, 512))
    colors = jnp.asarray(rng.integers(-1, 16, size=(512,)).astype(np.int32))
    a = ops.firstfit(ell, colors, C=32, backend="auto")
    b = ops.firstfit(ell, colors, C=32, backend="pallas_interpret")
    np.testing.assert_array_equal(a[0], b[0])


def test_ref_impls_agree_cross():
    """bitset ref == dense ref on identical inputs (the unit-level corner
    of the differential square; the engine level lives in test_bitset.py)."""
    rng = np.random.default_rng(42)
    for C in (32, 64, 96, 256):
        ell = jnp.asarray(_rand_ell(rng, 128, 12, 256))
        colors = jnp.asarray(
            rng.integers(-1, C + 8, size=(256,)).astype(np.int32))
        a = ref.firstfit_ref(ell, colors, C, impl="bitset")
        b = ref.firstfit_ref(ell, colors, C, impl="dense")
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
