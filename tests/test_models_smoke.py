"""Per-arch smoke tests: REDUCED config of the same family, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment
requirement).  Full configs are exercised only via the dry-run."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import pipeline as DP
from repro.models import equivariant as EQ
from repro.models import gnn as GNN
from repro.models import recsys as RS
from repro.models import transformer as TF
from repro.training.optimizer import OptimizerConfig, adamw_update, init_opt_state

LM_ARCHS = ["qwen3-1.7b", "minicpm3-4b", "qwen3-32b",
            "phi3.5-moe-42b-a6.6b", "qwen2-moe-a2.7b"]
GNN_ARCHS = ["gat-cora", "meshgraphnet", "gatedgcn", "nequip"]


def _finite(tree):
    return all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree_util.tree_leaves(tree))


def _one_train_step(loss_fn, params, batch):
    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
    opt = init_opt_state(params)
    p2, _, m = adamw_update(OptimizerConfig(), params, grads, opt)
    assert np.isfinite(float(loss))
    assert _finite(grads) and _finite(p2)
    return float(loss)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_forward_and_train(arch):
    cfg = configs.get(arch).make_smoke()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    B, L = 2, 32
    batch = next(DP.TokenStream(batch=B, seq_len=L, vocab=cfg.vocab))
    batch = jax.tree.map(jnp.asarray, batch)
    logits, aux = TF.forward(params, cfg, batch["tokens"])
    assert logits.shape == (B, L, cfg.vocab)
    assert _finite(logits)
    _one_train_step(lambda p, b: TF.train_step_loss(p, cfg, b), params, batch)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_prefill_decode_consistency(arch):
    """Greedy decode after prefill == teacher-forced argmax continuation."""
    cfg = configs.get(arch).make_smoke()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, L = 2, 16
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (B, L)), jnp.int32)
    logits, cache = TF.prefill(params, cfg, toks)
    # re-home prefill cache into fixed-capacity buffers
    S = 32
    full = TF.make_empty_cache(cfg, B, S)
    for k, v in cache.items():
        if cfg.attn_type == "mla":
            full[k] = full[k].at[:, :, :L].set(v.astype(full[k].dtype))
        else:
            full[k] = full[k].at[:, :, :, :L].set(v.astype(full[k].dtype))
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    length = jnp.full((B,), L, jnp.int32)
    logits2, _ = TF.decode_step(params, cfg, nxt, full, length)
    # oracle: full forward over the extended sequence
    ext = jnp.concatenate([toks, nxt[:, None]], axis=1)
    logits_full, _ = TF.forward(params, cfg, ext)
    np.testing.assert_allclose(np.asarray(logits2),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_forward_and_train(arch):
    arch_def = configs.get(arch)
    model = arch_def.extras["model"]
    cfg = arch_def.make_smoke()
    from repro.graphs.generators import mesh2d
    g = mesh2d(12, 12)
    if model == "nequip":
        stream = DP.MoleculeStream(n_nodes=8, n_edges=16, batch=4,
                                   n_species=cfg.n_species, d_feat=0)
        batch = jax.tree.map(jnp.asarray, next(stream))
        params = EQ.nequip_init(jax.random.PRNGKey(0), cfg)
        e = EQ.nequip_apply(params, cfg, batch["species"], batch["positions"],
                            batch["src"], batch["dst"],
                            batch["species"].shape[0])
        assert e.shape == (batch["species"].shape[0],)
        assert _finite(e)
        _one_train_step(lambda p, b: EQ.energy_loss(p, cfg, b), params, batch)
        return
    d_in = cfg.d_in
    n_classes = getattr(cfg, "n_classes", None) or getattr(cfg, "d_out", 3)
    stream = DP.FullGraphStream(g, d_feat=d_in, n_classes=n_classes,
                                pad_edges_to=1024)
    batch = jax.tree.map(jnp.asarray, next(stream))
    N = g.n_vertices + 1
    if model == "gat":
        params = GNN.gat_init(jax.random.PRNGKey(0), cfg)
        out = GNN.gat_apply(params, cfg, batch["feats"], batch["src"],
                            batch["dst"], N)
    elif model == "mgn":
        params = GNN.mgn_init(jax.random.PRNGKey(0), cfg)
        ef = jnp.zeros((batch["src"].shape[0], cfg.d_edge_in), jnp.float32)
        out = GNN.mgn_apply(params, cfg, batch["feats"], ef, batch["src"],
                            batch["dst"], N)
    else:
        params = GNN.gatedgcn_init(jax.random.PRNGKey(0), cfg)
        out = GNN.gatedgcn_apply(params, cfg, batch["feats"], batch["src"],
                                 batch["dst"], N)
    assert out.shape == (N, n_classes)
    assert _finite(out)

    def loss_fn(p, b):
        if model == "gat":
            o = GNN.gat_apply(p, cfg, b["feats"], b["src"], b["dst"], N)
        elif model == "mgn":
            e = jnp.zeros((b["src"].shape[0], cfg.d_edge_in), jnp.float32)
            o = GNN.mgn_apply(p, cfg, b["feats"], e, b["src"], b["dst"], N)
        else:
            o = GNN.gatedgcn_apply(p, cfg, b["feats"], b["src"], b["dst"], N)
        return GNN.node_classification_loss(o, b["labels"], b["train_mask"])

    _one_train_step(loss_fn, params, batch)


def test_recsys_smoke_forward_train_retrieval():
    cfg = configs.get("dcn-v2").make_smoke()
    params = RS.dcnv2_init(jax.random.PRNGKey(0), cfg)
    stream = DP.RecsysStream(batch=16, n_dense=cfg.n_dense,
                             n_sparse=cfg.n_sparse, vocabs=cfg.vocabs,
                             max_hots=cfg.max_hots)
    batch = jax.tree.map(jnp.asarray, next(stream))
    p = RS.predict(params, cfg, batch)
    assert p.shape == (16,) and _finite(p)
    assert (np.asarray(p) >= 0).all() and (np.asarray(p) <= 1).all()
    _one_train_step(lambda pp, b: RS.ctr_loss(pp, cfg, b), params, batch)
    cand = RS.make_candidate_tower(params, cfg, batch["dense"], batch["sparse"])
    scores, tv, ti = RS.retrieval_scores(params, cfg, batch["dense"][:1],
                                         batch["sparse"][:1], cand, top_k=4)
    assert scores.shape == (16,) and tv.shape == (4,)
    # top-k really is the max
    assert np.isclose(float(tv[0]), float(np.asarray(scores).max()))


def test_nequip_equivariance():
    """E(3) invariance of energies / equivariance of forces under a random
    rotation + translation (the model's defining property)."""
    cfg = EQ.NequIPConfig(n_layers=2, channels=8, l_max=2, n_rbf=4,
                          cutoff=5.0, n_species=4)
    params = EQ.nequip_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    N = 10
    pos = jnp.asarray(rng.uniform(0, 3, (N, 3)).astype(np.float32))
    species = jnp.asarray(rng.integers(0, 4, N).astype(np.int32))
    src = jnp.asarray(rng.integers(0, N, 40), jnp.int32)
    dst = jnp.asarray((np.asarray(src) + 1 + rng.integers(0, N - 1, 40)) % N,
                      jnp.int32)
    a, b, c = 0.3, 1.1, -0.7
    Rz = np.array([[np.cos(a), -np.sin(a), 0], [np.sin(a), np.cos(a), 0],
                   [0, 0, 1]])
    Ry = np.array([[np.cos(b), 0, np.sin(b)], [0, 1, 0],
                   [-np.sin(b), 0, np.cos(b)]])
    Rx = np.array([[1, 0, 0], [0, np.cos(c), -np.sin(c)],
                   [0, np.sin(c), np.cos(c)]])
    R = jnp.asarray((Rz @ Ry @ Rx).astype(np.float32))
    pos2 = pos @ R.T + jnp.asarray([1.0, -2.0, 0.5])
    e1, f1 = EQ.energy_and_forces(params, cfg, species, pos, src, dst, N)
    e2, f2 = EQ.energy_and_forces(params, cfg, species, pos2, src, dst, N)
    np.testing.assert_allclose(float(e1), float(e2), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(f1 @ R.T), np.asarray(f2),
                               rtol=1e-3, atol=1e-5)


def test_moe_routing_mass_conservation():
    """Top-k gates renormalize to 1; dropped tokens contribute zero."""
    from repro.models.moe import MoEConfig, moe_init, moe_apply
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16,
                    capacity_factor=10.0)
    params = moe_init(jax.random.PRNGKey(0), 32, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    out, aux = moe_apply(params, cfg, x)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all() and np.isfinite(float(aux))
    # capacity 0.01 -> nearly everything dropped -> tiny output norm
    cfg2 = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16,
                     capacity_factor=0.01)
    out2, _ = moe_apply(params, cfg2, x)
    assert float(jnp.abs(out2).sum()) <= float(jnp.abs(out).sum())
