"""Observability layer (DESIGN.md §12): ``repro.obs`` tracing + metrics.

Covers the acceptance criteria of the obs PR:
  * ``trace=False`` produces bit-identical colors to ``trace=True`` for
    every registered engine, and attaches no trace artifact — the untraced
    loop still returns the pre-obs 5-tuple (no new device outputs);
  * ``trace=True`` returns a ``RunTrace`` whose per-round conflict counts
    exactly match ``ColoringResult.conflicts_per_round`` for every
    registered engine;
  * trace truncation past MAX_ROUNDS_TRACE is explicit (flag + warn-once),
    never silent;
  * the twohop VMEM fallback warns once per process naming the overflowing
    shape and counts every occurrence;
  * ``ColoringService`` memo semantics (hit/miss across versions,
    invalidation on mutation, queries never observing a half-applied
    batch) are asserted through the new memo counters.
"""
import json
import warnings

import numpy as np
import pytest

from repro import api, obs, registry
from repro.core import coloring as col
from repro.core import frontier as fr
from repro.core.context import PassContext
from repro.dynamic.service import ColoringService
from repro.graphs import generators as gen
from repro.kernels import ops
from repro.obs import export, metrics

MESH = gen.mesh2d(12, 12)
BIP = gen.bipartite_random(40, 30, 3.0, seed=7)
N_LEFT = 40

# one row per registered local combo (the distributed slice is covered by
# test_trace_parity_distributed); a new engine registration must add a row
# here or test_trace_cases_are_exhaustive fails
CASES = {
    "rsoc/1/static/local": (MESH, dict(algorithm="rsoc")),
    "cat/1/static/local": (MESH, dict(algorithm="cat")),
    "gm/1/static/local": (MESH, dict(algorithm="gm")),
    "jp/1/static/local": (MESH, dict(algorithm="jp", max_rounds=10000)),
    "rsoc_compact/1/static/local": (MESH, dict(algorithm="rsoc_compact")),
    "rsoc/2/static/local": (MESH, dict(algorithm="rsoc", distance=2)),
    "rsoc/2/partial/local": (BIP, dict(algorithm="rsoc", distance=2,
                                       mode="partial", n_left=N_LEFT)),
    "rsoc/1/incremental/local": (MESH, dict(algorithm="rsoc",
                                            mode="incremental")),
}


def _no_env_trace(monkeypatch):
    # CI forces REPRO_TRACE=1 through the whole suite; tests that assert
    # *untraced* behavior must clear it
    monkeypatch.delenv("REPRO_TRACE", raising=False)


def test_trace_cases_are_exhaustive():
    covered = set(CASES) | {"rsoc/1/static/distributed",
                            "cat/1/static/distributed",
                            # multi-device subprocess combo, exercised by
                            # tests/test_sharded.py
                            "rsoc/1/incremental/distributed"}
    registered = {f"{a}/{d}/{m}/{b}"
                  for (a, d, m, b) in registry.engine_keys()}
    assert registered == covered, registered ^ covered


@pytest.mark.parametrize("combo", sorted(CASES))
def test_trace_on_off_parity(combo, monkeypatch):
    """trace=False is bit-identical to trace=True and carries no artifact;
    trace=True attaches a RunTrace whose conflicts match the result's."""
    _no_env_trace(monkeypatch)
    g, kw = CASES[combo]
    off = api.color(g, seed=3, **kw)
    on = api.color(g, seed=3, trace=True, **kw)
    assert off.trace is None
    np.testing.assert_array_equal(off.colors, on.colors, err_msg=combo)
    t = on.trace
    assert t is not None
    np.testing.assert_array_equal(
        t.conflicts_per_round,
        np.asarray(on.conflicts_per_round).reshape(-1), err_msg=combo)
    assert t.n_rounds == on.n_rounds
    assert t.retries == on.retries and t.final_C == on.final_C
    assert t.n_colors == on.n_colors and not t.truncated
    assert t.spec_key == on.spec.spec_key()
    assert f"algorithm={kw['algorithm']!r}" in t.engine
    names = {p.name for p in t.phases}
    assert "solve" in names, (combo, names)
    assert all(p.wall_s >= 0 for p in t.phases)


@pytest.mark.parametrize("algo", ["rsoc", "cat"])
def test_trace_parity_distributed(algo, monkeypatch):
    _no_env_trace(monkeypatch)
    import jax
    mesh = jax.make_mesh((1,), ("data",))
    kw = dict(algorithm=algo, backend="distributed", mesh=mesh, axis="data",
              seed=3, n_chunks=2, max_rounds=64)
    off = api.color(MESH, **kw)
    on = api.color(MESH, trace=True, **kw)
    assert off.trace is None
    np.testing.assert_array_equal(off.colors, on.colors)
    np.testing.assert_array_equal(
        on.trace.conflicts_per_round,
        np.asarray(on.conflicts_per_round).reshape(-1))
    assert {"prepare", "solve"} <= {p.name for p in on.trace.phases}


def test_frontier_trace_rsoc_compact(monkeypatch):
    """The compacted engine's RunTrace carries per-round frontier sizes and
    the compacted-vs-full decision per round."""
    _no_env_trace(monkeypatch)
    res = api.color(MESH, algorithm="rsoc_compact", seed=3, trace=True)
    rounds = res.trace.rounds
    assert len(rounds) == res.n_rounds
    for ev in rounds:
        assert ev.frontier >= 0          # collected, not the -1 sentinel
        assert ev.compacted is not None  # cap known -> decision recorded


def test_untraced_loop_is_pre_obs_program():
    """The untraced loops return the original 5-tuple — the static
    ctx.trace=False program has no extra outputs (and hence none of the
    frontier-trace allocations); traced loops splice the frontier trace
    before the trailing (total, overflow) pair."""
    prob = col.prepare(MESH, 3, 4)
    off = col._prob_runner(col._rsoc_loop, prob, 4, 100, "bitset",
                           trace=False)(prob.C)
    on = col._prob_runner(col._rsoc_loop, prob, 4, 100, "bitset",
                          trace=True)(prob.C)
    assert len(off) == 5 and len(on) == 6
    np.testing.assert_array_equal(np.asarray(off[0]), np.asarray(on[0]))
    # same contract for the frontier-compacted loop
    cap = fr.frontier_cap(prob.n_pad, 4)
    mk = lambda tr: PassContext.for_problem(prob, n_chunks=4, C=prob.C,
                                            forbidden_impl="bitset",
                                            trace=tr)
    off = fr._rsoc_compact_loop(prob.ell, prob.ovf_src, prob.ovf_dst,
                                prob.pri, mk(False), cap, 100)
    on = fr._rsoc_compact_loop(prob.ell, prob.ovf_src, prob.ovf_dst,
                               prob.pri, mk(True), cap, 100)
    assert len(off) == 5 and len(on) == 6
    np.testing.assert_array_equal(np.asarray(off[0]), np.asarray(on[0]))


# --------------------------------------------------------------------------
# satellite 1: explicit trace truncation
# --------------------------------------------------------------------------

def test_trim_trace_truncation_flag_and_warn_once(monkeypatch):
    monkeypatch.setattr(col, "_trace_truncation_warned", False)
    buf = np.arange(col.MAX_ROUNDS_TRACE, dtype=np.int32)
    with pytest.warns(RuntimeWarning, match="MAX_ROUNDS_TRACE"):
        trimmed, truncated = col._trim_trace(buf, col.MAX_ROUNDS_TRACE + 9)
    assert truncated and len(trimmed) == col.MAX_ROUNDS_TRACE
    with warnings.catch_warnings():
        warnings.simplefilter("error")   # second overrun: silent by design
        trimmed, truncated = col._trim_trace(buf, col.MAX_ROUNDS_TRACE + 1)
    assert truncated


def test_trim_trace_no_truncation():
    buf = np.arange(col.MAX_ROUNDS_TRACE, dtype=np.int32)
    trimmed, truncated = col._trim_trace(buf, 3)
    assert not truncated
    np.testing.assert_array_equal(trimmed, [0, 1, 2])


def test_result_trace_truncated_default():
    res = api.color(MESH, seed=3)
    assert res.trace_truncated is False


# --------------------------------------------------------------------------
# satellite 2: loud twohop VMEM fallback
# --------------------------------------------------------------------------

def _twohop_inputs():
    # 4-cycle adjacency on a vertex count whose (n,) color/priority vectors
    # alone bust the VMEM budget — the degenerate shape that STILL falls
    # back after paging (the table itself no longer matters: it is paged)
    n_all = 2**20 + 1
    ell_all = np.full((n_all, 2), -1, np.int32)
    for i in range(4):
        ell_all[i] = [(i + 1) % 4, (i - 1) % 4]
    colors = np.full((n_all,), -1, np.int32)
    pri = np.arange(n_all, dtype=np.int32)
    U = np.ones((4,), bool)
    return ell_all[:4], ell_all, colors, pri, U


def test_twohop_vmem_fallback_warns_once_and_counts():
    ell_rows, ell_all, colors, pri, U = _twohop_inputs()
    assert 2 * colors.size * 4 > ops.VMEM_BUDGET_BYTES
    ops._fallback_warned.discard("twohop")
    before = metrics.counter_value("kernels.fallback", kernel="twohop",
                                   reason="vmem")
    with pytest.warns(RuntimeWarning,
                      match=r"twohop: .*n=1048577.*not pageable"):
        out_pallas = ops.twohop(ell_rows, ell_all, colors, pri, U, 0, C=8,
                                backend="pallas")
    with warnings.catch_warnings():
        warnings.simplefilter("error")   # once per process per kernel
        out_again = ops.twohop(ell_rows, ell_all, colors, pri, U, 0, C=8,
                               backend="pallas")
    # every occurrence is counted even after the warning goes quiet
    after = metrics.counter_value("kernels.fallback", kernel="twohop",
                                  reason="vmem")
    assert after == before + 2
    # the fallback output is the jnp reference, bit-for-bit
    out_jnp = ops.twohop(ell_rows, ell_all, colors, pri, U, 0, C=8,
                         backend="jnp")
    for a, b, c in zip(out_pallas, out_again, out_jnp):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
        np.testing.assert_array_equal(np.asarray(b), np.asarray(c))


def test_dispatch_counter():
    ell = np.array([[1, -1], [0, -1]], np.int32)
    colors = np.array([-1, -1], np.int32)
    before = metrics.counter_value("kernels.dispatch", kernel="firstfit",
                                   backend="jnp")
    ops.firstfit(ell, colors, C=8, backend="jnp")
    after = metrics.counter_value("kernels.dispatch", kernel="firstfit",
                                  backend="jnp")
    assert after == before + 1


def test_cap_retry_counter():
    # C=1 on a mesh must overflow and double at least once
    before = metrics.counter_value("engine.cap_retry", engine="rsoc")
    res = api.color(MESH, algorithm="rsoc", seed=3, C=1)
    after = metrics.counter_value("engine.cap_retry", engine="rsoc")
    assert res.retries >= 1 and after == before + res.retries


# --------------------------------------------------------------------------
# satellite 3: ColoringService memo semantics via the memo counters
# --------------------------------------------------------------------------

def _memo_counts(kind):
    return (metrics.counter_value("service.memo", kind=kind, outcome="hit"),
            metrics.counter_value("service.memo", kind=kind, outcome="miss"))


def test_service_memo_hit_miss_and_invalidation():
    svc = ColoringService(seed=3)
    svc.add_graph("g", gen.mesh2d(8, 8))
    h0, m0 = _memo_counts("vertex_schedule")

    sched = svc.vertex_schedule("g")             # cold -> miss
    assert _memo_counts("vertex_schedule") == (h0, m0 + 1)
    again = svc.vertex_schedule("g")             # same version -> hit
    assert _memo_counts("vertex_schedule") == (h0 + 1, m0 + 1)
    assert all(np.array_equal(a, b) for a, b in zip(sched, again))

    # mutation invalidates: version bump -> next query rebuilds
    v = svc.version("g")
    svc.submit("g", inserts=[[0, 9]])
    svc.step("g")
    assert svc.version("g") == v + 1
    svc.vertex_schedule("g")
    assert _memo_counts("vertex_schedule") == (h0 + 1, m0 + 2)


def test_service_queries_never_observe_half_applied_batch():
    svc = ColoringService(seed=3)
    svc.add_graph("g", gen.mesh2d(8, 8))
    colors0 = svc.colors("g").copy()
    v0 = svc.version("g")
    svc.vertex_schedule("g")                     # populate the memo
    h0, m0 = _memo_counts("vertex_schedule")

    svc.submit("g", inserts=[[0, 9], [3, 17]])   # queued, NOT applied
    assert svc.version("g") == v0
    np.testing.assert_array_equal(svc.colors("g"), colors0)
    svc.vertex_schedule("g")                     # memo still valid -> hit
    assert _memo_counts("vertex_schedule") == (h0 + 1, m0)

    svc.step("g")                                # now it applies atomically
    assert svc.version("g") == v0 + 1
    svc.vertex_schedule("g")
    assert _memo_counts("vertex_schedule") == (h0 + 1, m0 + 1)


def test_service_step_latency_histogram():
    svc = ColoringService(seed=3)
    svc.add_graph("g", gen.mesh2d(8, 8))
    n0 = svc.step_latency("g")["count"]
    svc.step("g")                                # zero batches: not observed
    assert svc.step_latency("g")["count"] == n0
    svc.submit("g", inserts=[[1, 40]])
    svc.step("g")
    s = svc.step_latency("g")
    assert s["count"] == n0 + 1
    assert s["p50"] is not None and s["p99"] >= s["p50"] >= 0
    with pytest.raises(KeyError):
        svc.step_latency("nope")


# --------------------------------------------------------------------------
# collector scope, export, metrics primitives
# --------------------------------------------------------------------------

def test_trace_collector_scope(monkeypatch):
    _no_env_trace(monkeypatch)
    with obs.trace() as tc:
        r1 = api.color(MESH, algorithm="cat", seed=3)
        r2 = api.color(MESH, algorithm="rsoc", seed=3)
    assert len(tc) == 2
    assert r1.trace is tc.traces[0] and r2.trace is tc.traces[1]
    # scope over: back to untraced
    assert api.color(MESH, seed=3).trace is None
    assert obs.active_collector() is None


def test_env_forced_tracing(monkeypatch):
    monkeypatch.setenv("REPRO_TRACE", "1")
    res = api.color(MESH, seed=3)
    assert res.trace is not None
    monkeypatch.setenv("REPRO_TRACE", "0")
    assert not obs.tracing_enabled(False)


def test_export_jsonl_roundtrip(tmp_path, monkeypatch):
    _no_env_trace(monkeypatch)
    with obs.trace() as tc:
        api.color(MESH, algorithm="rsoc", seed=3)
        api.color(MESH, algorithm="cat", seed=3)
    path = tmp_path / "traces.jsonl"
    assert export.write_jsonl(tc.traces, str(path)) == 2
    back = export.read_jsonl(str(path))
    assert len(back) == 2
    for t, d in zip(tc.traces, back):
        assert d["spec_key"] == t.spec_key
        assert d["n_rounds"] == t.n_rounds
        assert [r["conflicts"] for r in d["rounds"]] == \
            t.conflicts_per_round.tolist()
    json.dumps(export.metrics_snapshot())        # snapshot is JSON-ready


def test_summary_line(monkeypatch):
    _no_env_trace(monkeypatch)
    res = api.color(MESH, algorithm="rsoc", seed=3, trace=True)
    line = res.trace.summary_line()
    assert line.startswith("trace[") and "rounds=" in line
    assert f"colors={res.n_colors}" in line and "TRUNCATED" not in line


def test_metrics_qualified_and_counters():
    assert metrics.qualified("a.b") == "a.b"
    assert metrics.qualified("a.b", z=1, a="x") == "a.b{a=x,z=1}"
    c = metrics.counter("test.obs_unit", case="q")
    v0 = c.value
    c.inc()
    c.inc(3)
    assert metrics.counter_value("test.obs_unit", case="q") == v0 + 4
    assert metrics.counter_value("test.obs_unit", case="absent") == 0
    assert metrics.total_matching("test.obs_unit") >= v0 + 4
    assert "test.obs_unit{case=q}" in metrics.counters_matching("test.obs_")


def test_metrics_histogram_percentiles():
    h = metrics.histogram("test.obs_hist")
    for v in range(1, 101):
        h.observe(float(v))
    assert h.count >= 100
    s = h.summary()
    assert s["max"] >= 100 and s["p99"] <= s["max"]
    assert s["p50"] <= s["p99"]
    assert metrics.histogram("test.obs_empty").percentile(50) is None
