"""Batched serving with continuous batching: submit a stream of requests
against fixed-capacity KV-cache slots and drain them.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.models import transformer as TF
from repro.serving.serve_loop import Request, ServeEngine

cfg = TF.TransformerConfig(
    name="serve-demo", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
    head_dim=32, d_ff=256, vocab=1024, qk_norm=True, dtype="float32",
    remat=False, chunk_q=64, chunk_k=64)
params = TF.init_params(jax.random.PRNGKey(0), cfg)
engine = ServeEngine(params, cfg, batch=4, max_len=128)

rng = np.random.default_rng(0)
requests = [Request(prompt=rng.integers(1, cfg.vocab, rng.integers(4, 24)),
                    max_new_tokens=int(rng.integers(8, 24)))
            for _ in range(12)]

t0 = time.perf_counter()
engine.run(requests)
dt = time.perf_counter() - t0
tokens = sum(len(r.out_tokens) for r in requests)
print(f"{len(requests)} requests, {tokens} tokens, {dt:.2f}s "
      f"({tokens / dt:.1f} tok/s with batch=4 continuous batching)")
for r in requests[:3]:
    print(f"  prompt[{len(r.prompt)}] -> {r.out_tokens}")
