"""End-to-end driver: train a ~100M-param GQA transformer for a few hundred
steps with the full production substrate (data pipeline -> jitted step with
grad accumulation -> async checkpointing -> restart support).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]

--tiny shrinks the model so the example finishes in ~a minute on CPU; the
default ~100M config is sized for a real accelerator (it runs on CPU too,
just slowly).
"""
import argparse
import functools

import jax
import jax.numpy as jnp

from repro.data.pipeline import TokenStream
from repro.models import transformer as TF
from repro.training.optimizer import OptimizerConfig
from repro.training import train_loop as TL


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    if args.tiny:
        cfg = TF.TransformerConfig(
            name="lm-tiny", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
            head_dim=32, d_ff=512, vocab=2048, qk_norm=True, dtype="float32",
            remat=False, chunk_q=128, chunk_k=128)
        batch, seq = 8, 128
    else:
        # ~100M params: 12L x d512 (GQA 8/4) x ff2048, 32k vocab
        cfg = TF.TransformerConfig(
            name="lm-100m", n_layers=12, d_model=512, n_heads=8,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32768, qk_norm=True,
            dtype="float32", remat=False, chunk_q=256, chunk_k=256)
        batch, seq = 16, 256
    print(f"model {cfg.name}: {cfg.n_params() / 1e6:.1f}M params")

    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    stream = TokenStream(batch=batch, seq_len=seq, vocab=cfg.vocab)
    opt_cfg = OptimizerConfig(lr=3e-4, warmup_steps=20,
                              total_steps=args.steps)
    loop_cfg = TL.TrainLoopConfig(total_steps=args.steps, microbatches=2,
                                  ckpt_every=100, ckpt_dir=args.ckpt_dir,
                                  log_every=10)
    params, _, hist = TL.run(
        lambda p, b: TF.train_step_loss(p, cfg, b), params, stream, opt_cfg,
        loop_cfg, to_device=lambda b: jax.tree.map(jnp.asarray, b),
        on_metrics=lambda m: print(
            f"step {m['step']:4d}  loss {m['loss']:.4f}  "
            f"{m['sec_per_step']:.2f}s/step", flush=True))
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"over {args.steps} steps (checkpoints in {args.ckpt_dir})")


if __name__ == "__main__":
    main()
