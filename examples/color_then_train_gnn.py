"""The paper's technique inside a training pipeline: color a mesh, use the
coloring as a conflict-free scatter schedule for GNN message passing, and
train a GatedGCN on the mesh — deterministic aggregation included.

    PYTHONPATH=src python examples/color_then_train_gnn.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro import api
from repro.core import coloring as col
from repro.data.pipeline import FullGraphStream
from repro.graphs import generators as gen
from repro.models import gnn as GNN
from repro.training.optimizer import (OptimizerConfig, adamw_update,
                                      init_opt_state)

# 1. the mesh + its coloring (dependency analysis for parallel mesh kernels)
g = gen.mesh2d(48, 48)
res = api.color(g, algorithm="rsoc", seed=0)
assert col.is_proper(g, res.colors)
print(f"mesh: {g.n_vertices} vertices; RSOC: {res.n_colors} colors in "
      f"{res.n_rounds} rounds / {res.gather_passes} passes")

# 2. a GNN on the same mesh, trained full-batch
cfg = GNN.GatedGCNConfig(n_layers=4, d_hidden=32, d_in=16, d_out=4)
stream = FullGraphStream(g, d_feat=16, n_classes=4, pad_edges_to=1024)
params = GNN.gatedgcn_init(jax.random.PRNGKey(0), cfg)
opt = init_opt_state(params)
ocfg = OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=60)
N = g.n_vertices + 1


@jax.jit
def step(params, opt, batch):
    def loss_fn(p):
        out = GNN.gatedgcn_apply(p, cfg, batch["feats"], batch["src"],
                                 batch["dst"], N)
        return GNN.node_classification_loss(out, batch["labels"],
                                            batch["train_mask"])
    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt, m = adamw_update(ocfg, params, grads, opt)
    return params, opt, loss


for i in range(60):
    batch = jax.tree.map(jnp.asarray, next(stream))
    params, opt, loss = step(params, opt, batch)
    if i % 10 == 0:
        print(f"step {i:3d}  loss {float(loss):.4f}")

# 3. deterministic aggregation via the coloring-derived edge schedule
from repro.core.schedule import edge_color_by_dst
from repro.graphs.csr import to_edge_list

e = to_edge_list(g)
src, dst = e[:, 0].astype(np.int32), e[:, 1].astype(np.int32)
ranks, n_colors = edge_color_by_dst(src, dst, g.n_vertices)
msg = np.random.default_rng(0).standard_normal((len(src), 8)).astype(np.float32)
out1 = GNN.colored_segment_sum(jnp.asarray(msg), jnp.asarray(dst),
                               g.n_vertices, jnp.asarray(ranks), n_colors)
perm = np.random.default_rng(1).permutation(len(src))
out2 = GNN.colored_segment_sum(jnp.asarray(msg[perm]), jnp.asarray(dst[perm]),
                               g.n_vertices, jnp.asarray(ranks[perm]),
                               n_colors)
print("colored scatter deterministic under edge permutation:",
      bool(np.array_equal(np.asarray(out1), np.asarray(out2))))
