"""Quickstart: color a graph with RSOC and inspect the result.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import coloring as col
from repro.graphs import generators as gen

# 1. build a graph (a 3D tetrahedral mesh, the paper's high-degree regime)
g = gen.mesh3d(16, 16, 16)
print(f"graph: {g.n_vertices} vertices, {g.n_edges} directed edges, "
      f"max degree {g.max_degree}")

# 2. color it with the paper's algorithm (RSOC) and its predecessor (CAT)
for name, fn in [("CAT  (Catalyurek et al.)", col.color_cat),
                 ("RSOC (this paper)", col.color_rsoc)]:
    res = fn(g, seed=0)
    assert col.is_proper(g, res.colors)
    print(f"{name}: {res.n_colors} colors, {res.n_rounds} rounds, "
          f"{res.total_conflicts} conflicts, "
          f"{res.gather_passes} neighbor-gather passes")

# 3. compare against the serial First-Fit oracle
serial = col.greedy_sequential(g)
print(f"serial First-Fit: {col.n_colors_used(serial)} colors")

# 4. use the coloring: independent sets for safe parallel execution
res = col.color_rsoc(g, seed=0)
sizes = np.bincount(res.colors)
print(f"independent-set sizes: {sizes.tolist()}")
print("largest set =", sizes.max(), "vertices can be processed in parallel")
