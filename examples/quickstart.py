"""Quickstart: color a graph through the one front door, repro.api.color.

    PYTHONPATH=src python examples/quickstart.py

Every engine — the paper's RSOC, its predecessors, frontier compaction,
native distance-2, bipartite partial, incremental — is selected by a
``ColoringSpec`` (algorithm / distance / mode / backend), not by a separate
function (DESIGN.md §11).
"""
import numpy as np

from repro import api
from repro.core import coloring as col
from repro.graphs import generators as gen

# 1. build a graph (a 3D tetrahedral mesh, the paper's high-degree regime)
g = gen.mesh3d(16, 16, 16)
print(f"graph: {g.n_vertices} vertices, {g.n_edges} directed edges, "
      f"max degree {g.max_degree}")

# 2. color it with the paper's algorithm (RSOC) and its predecessor (CAT):
#    same entry point, different spec
for name, spec in [("CAT  (Catalyurek et al.)", api.ColoringSpec("cat")),
                   ("RSOC (this paper)", api.ColoringSpec("rsoc"))]:
    res = api.color(g, spec, seed=0)
    assert col.is_proper(g, res.colors)
    print(f"{name}: {res.n_colors} colors, {res.n_rounds} rounds, "
          f"{res.total_conflicts} conflicts, "
          f"{res.gather_passes} neighbor-gather passes")

# 3. the result echoes the resolved spec — feed it back in to replay
res = api.color(g, algorithm="rsoc", seed=0)
replay = api.color(g, res.spec)
assert np.array_equal(res.colors, replay.colors)
print(f"resolved spec key: {res.spec.spec_key()}")

# 4. the whole support matrix is one registry
print("supported specs:")
for row in api.supported_specs():
    print(f"  algorithm={row['algorithm']:<13} distance={row['distance']} "
          f"mode={row['mode']:<12} backend={row['backend']:<12} "
          f"(replaces {row['replaces']})")

# 5. other engines are just other specs: native distance-2 (G^2 colored
#    without ever materializing it)
res2 = api.color(g, distance=2, seed=0)
print(f"distance-2: {res2.n_colors} colors (distance={res2.distance})")

# 6. compare against the serial First-Fit oracle
serial = col.greedy_sequential(g)
print(f"serial First-Fit: {col.n_colors_used(serial)} colors")

# 7. use the coloring: independent sets for safe parallel execution
sizes = np.bincount(res.colors)
print(f"independent-set sizes: {sizes.tolist()}")
print("largest set =", sizes.max(), "vertices can be processed in parallel")

# 8. unsupported combos fail loudly, naming the nearest supported spec
try:
    api.color(g, algorithm="cat", distance=2)
except ValueError as e:
    print(f"unsupported combo rejected: {e}")

# 9. observability: spec.trace attaches a RunTrace (per-round conflicts,
#    per-phase wall time, cap-retries) to the result; trace=False (the
#    default) compiles the exact same device program as before the obs
#    layer existed — zero overhead when off (DESIGN.md §12)
res = api.color(g, algorithm="rsoc", seed=0, trace=True)
print(res.trace.summary_line())
for ph in res.trace.phases:
    print(f"  phase {ph.name:<8} {ph.wall_s * 1e3:8.1f}ms  {ph.meta}")

# 10. or scope a collector around existing untraced calls — every
#     api.color inside the block is traced and collected
from repro import obs
with obs.trace() as tc:
    api.color(g, algorithm="cat", seed=0)
    api.color(g, distance=2, seed=0)
print(f"collected {len(tc.traces)} traces:")
for t in tc.traces:
    print(" ", t.summary_line())

# 11. long-lived multi-tenant serving: ColoringService owns many mutating
#     graphs, applies queued edge updates on step(), and serves memoized
#     coloring artifacts. Same-shape tenants (pin ell_cap/C/ovf_cap at
#     construction) advance in ONE stacked device dispatch per step —
#     bit-identical to stepping each tenant alone (DESIGN.md §13)
from repro.dynamic import ColoringService
svc = ColoringService(seed=0, ell_cap=8, C=32, ovf_cap=256, delta_cap=64)
for i in range(4):
    svc.add_graph(f"tenant{i}", gen.erdos_renyi(64, 5.0, seed=i))
svc.submit("tenant0", inserts=[[0, 9], [3, 17]], deletes=[[0, 1]])
svc.submit("tenant1", inserts=[[2, 11]])
stats = svc.step()                     # one megabatched dispatch, all tenants
print(f"tenant0 v{svc.version('tenant0')}: "
      f"{stats['tenant0']['colors']} colors, "
      f"{len(svc.vertex_schedule('tenant0'))} schedule classes "
      f"(p50 step {svc.step_latency('tenant0')['p50']:.1f}ms)")

# 12. self-healing: steps are transactional — an error rolls the tenant
#     back bit-exactly and requeues the batch; repeated failures quarantine
#     it (last-good coloring still served, unapplied batches preserved in a
#     dead-letter queue) and heal() replays the letters once the cause is
#     gone, bit-identical to a run that never failed (DESIGN.md §14)
from repro.resilience import faults
svc.submit("tenant2", inserts=[[1, 5], [2, 8]])
with faults.inject("service.step:p=1"):   # rehearse a step-path failure
    svc.step("tenant2")                   # rollback 1: committed state untouched
    svc.step("tenant2")                   # rollback 2: tenant quarantined
q = svc.quarantined("tenant2")
letters = svc.dead_letters("tenant2")
print(f"tenant2 quarantined: reason={q.reason}, "
      f"{sum(d.n_edges() for d in letters)} edges dead-lettered")
svc.heal("tenant2")                       # replay letters, verify, re-admit
assert svc.quarantined("tenant2") is None
assert col.is_proper(svc.graph("tenant2"), svc.colors("tenant2"))
print(f"tenant2 healed: v{svc.version('tenant2')}, "
      f"{stats['tenant2']['colors']} -> "
      f"{int(svc.colors('tenant2').max()) + 1} colors, proper again")

# 13. sharded incremental (DESIGN.md §15): the same mutable graph laid out
#     over a device mesh — submit/step exactly as above, repairs exchange
#     one O(boundary) collective per round.  A 1-device mesh runs anywhere
#     and replays the single-device engine bit-for-bit; pass a bigger mesh
#     (e.g. XLA_FLAGS=--xla_force_host_platform_device_count=8) to shard.
import jax
mesh = jax.make_mesh((len(jax.devices()),), ("data",))
svc.add_graph("sharded0", gen.erdos_renyi(64, 5.0, seed=9), mesh=mesh)
svc.submit("sharded0", inserts=[[0, 7], [5, 21]])
svc.step("sharded0")
st = svc.snapshot("sharded0")
assert col.is_proper(svc.graph("sharded0"), svc.colors("sharded0"))
print(f"sharded0 v{svc.version('sharded0')}: {st.n_shards} shard(s), "
      f"{st.summary()['halo_bytes_per_round']} halo bytes/round, "
      f"{st.n_colors} colors")
