# CI entry points (see ROADMAP.md "Tier-1 verify" and DESIGN.md §9),
# enforced on push/PR by .github/workflows/ci.yml.
#
#   make test         tier-1 test suite (the gate every PR must keep green;
#                     includes the public-API surface snapshot,
#                     tests/test_api_surface.py vs tests/api_surface.json)
#   make bench-smoke  SCALE-parameterized run of every benchmark section
#                     (default tiny) — catches import rot and shape bugs in
#                     minutes, not numbers; writes BENCH_<section>.json
#                     (uploaded as CI artifacts).  CI runs it twice: tiny,
#                     then SCALE=small so the paged-twohop acceptance row
#                     (table > 8 MB, kernel_fallbacks=0) is exercised on
#                     every push.
#   make bench        paper-scale benchmark run (small suite)
#   make bench-report roofline achieved-vs-peak table from the JSON dumps
#   make chaos        fault-injection sweep (DESIGN.md §14.5): runs
#                     tests/test_chaos.py once per fault class in
#                     CHAOS_FAULTS under both kernel backends; dead-letter
#                     queues are exported to deadletters/ (CI artifacts)

PYTHONPATH := src
export PYTHONPATH

SCALE ?= tiny
PEAK_GBS ?= 50
CHAOS_FAULTS ?= kernel.fallback cap.exhaust ovf.exhaust color.corrupt \
	service.step service.submit
CHAOS_BACKENDS ?= pallas_interpret jnp

.PHONY: test bench-smoke bench bench-report chaos

test:
	python -m pytest -x -q

chaos:
	@mkdir -p deadletters
	@for f in $(CHAOS_FAULTS); do \
	  for b in $(CHAOS_BACKENDS); do \
	    echo "=== chaos: $$f ($$b) ==="; \
	    REPRO_FAULTS="$$f:p=0.5:seed=7" \
	    REPRO_KERNEL_BACKEND="$$b" \
	    REPRO_DEADLETTER_DIR=deadletters \
	    python -m pytest tests/test_chaos.py -q || exit 1; \
	  done; \
	done

bench-smoke:
	python -m benchmarks.run --scale=$(SCALE) --json

bench:
	python -m benchmarks.run --scale=small

bench-report:
	python -m benchmarks.roofline_report --bench BENCH_*.json \
	  --peak-gbs $(PEAK_GBS) | tee roofline_bench.md
