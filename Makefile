# CI entry points (see ROADMAP.md "Tier-1 verify" and DESIGN.md §9),
# enforced on push/PR by .github/workflows/ci.yml.
#
#   make test         tier-1 test suite (the gate every PR must keep green;
#                     includes the public-API surface snapshot,
#                     tests/test_api_surface.py vs tests/api_surface.json)
#   make bench-smoke  SCALE-parameterized run of every benchmark section
#                     (default tiny) — catches import rot and shape bugs in
#                     minutes, not numbers; writes BENCH_<section>.json
#                     (uploaded as CI artifacts).  CI runs it twice: tiny,
#                     then SCALE=small so the paged-twohop acceptance row
#                     (table > 8 MB, kernel_fallbacks=0) is exercised on
#                     every push.
#   make bench        paper-scale benchmark run (small suite)
#   make bench-report roofline achieved-vs-peak table from the JSON dumps

PYTHONPATH := src
export PYTHONPATH

SCALE ?= tiny
PEAK_GBS ?= 50

.PHONY: test bench-smoke bench bench-report

test:
	python -m pytest -x -q

bench-smoke:
	python -m benchmarks.run --scale=$(SCALE) --json

bench:
	python -m benchmarks.run --scale=small

bench-report:
	python -m benchmarks.roofline_report --bench BENCH_*.json \
	  --peak-gbs $(PEAK_GBS) | tee roofline_bench.md
