# CI entry points (see ROADMAP.md "Tier-1 verify" and DESIGN.md §9),
# enforced on push/PR by .github/workflows/ci.yml.
#
#   make test         tier-1 test suite (the gate every PR must keep green;
#                     includes the public-API surface snapshot,
#                     tests/test_api_surface.py vs tests/api_surface.json)
#   make bench-smoke  tiny-graph run of every benchmark section — catches
#                     import rot and shape bugs in minutes, not numbers;
#                     writes BENCH_<section>.json (uploaded as CI artifacts)
#   make bench        paper-scale benchmark run (small suite)

PYTHONPATH := src
export PYTHONPATH

.PHONY: test bench-smoke bench

test:
	python -m pytest -x -q

bench-smoke:
	python -m benchmarks.run --scale=tiny --json

bench:
	python -m benchmarks.run --scale=small
