"""Paper Figs 3-4 (conflicts) and 5-6 (iterations): CAT vs RSOC as simulated
parallelism grows.

The paper sweeps OpenMP threads; the lockstep-SPMD analogue of "threads" is
the chunk width n/n_chunks — vertices colored simultaneously in one wave
(DESIGN.md §2).  Fewer chunks = wider waves = more parallelism = more
conflicts; RSOC's in-pass repair keeps both conflicts and rounds below CAT,
which is the paper's Figs 3-6 claim."""
from __future__ import annotations

from benchmarks.common import Csv, forb_ws_mb, suite
from repro import api

# Scale-aware parallelism sweep: the sweep must track graph size or the
# "wave width" n/n_chunks it simulates collapses to trivial chunks — at
# medium the interesting regime is the wide end (few chunks, huge waves),
# while a fixed 7-point sweep over every graph would dominate the section's
# wall time without adding resolution.
CHUNK_SWEEP = {
    "tiny": (1, 2, 4, 8, 16, 32, 64),
    "small": (1, 2, 4, 8, 16, 32, 64),
    "medium": (1, 4, 16, 64, 256),
}


def main(scale: str = "small") -> None:
    graphs = suite(scale)
    csv = Csv(["graph", "algo", "n_chunks", "sim_parallelism", "conflicts",
               "rounds", "colors", "ws_mb"])
    for gname, g in graphs.items():
        for n_chunks in CHUNK_SWEEP.get(scale, CHUNK_SWEEP["small"]):
            for algo in ("cat", "rsoc"):
                res = api.color(g, algorithm=algo, seed=1,
                                n_chunks=n_chunks)
                csv.row(gname, algo, n_chunks,
                        max(g.n_vertices // n_chunks, 1),
                        res.total_conflicts, res.n_rounds, res.n_colors,
                        forb_ws_mb(g.n_vertices, n_chunks, res.final_C),
                        spec=res.spec, result=res)


if __name__ == "__main__":
    main()
