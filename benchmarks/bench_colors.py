"""Color-quality table: every registered algorithm vs the serial-greedy
oracle on all six paper graphs (the paper: parallel speed does not cost
colors).  Long format — one row per (graph, algorithm) — so every row's
JSON record carries the exact resolved spec that produced it."""
from __future__ import annotations

from benchmarks.common import Csv, forb_ws_mb, suite
from repro import api
from repro.core import coloring as col

ALGOS = ("gm", "cat", "rsoc", "rsoc_compact", "jp")


def main(scale: str = "small") -> None:
    graphs = suite(scale)
    csv = Csv(["graph", "max_degree", "algo", "colors", "serial_colors",
               "vs_serial", "ws_mb"])
    for gname, g in graphs.items():
        serial = col.n_colors_used(col.greedy_sequential(g))
        for algo in ALGOS:
            res = api.color(g, algorithm=algo, seed=1)
            csv.row(gname, g.max_degree, algo, res.n_colors, serial,
                    res.n_colors / max(serial, 1),
                    forb_ws_mb(g.n_vertices, 16, res.final_C),
                    spec=res.spec, result=res)


if __name__ == "__main__":
    main()
