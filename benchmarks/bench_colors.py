"""Color-quality table: every algorithm vs the serial-greedy oracle on all
six paper graphs (the paper: parallel speed does not cost colors)."""
from __future__ import annotations

from benchmarks.common import Csv, forb_ws_mb, suite
from repro.core import coloring as col
from repro.core.frontier import color_rsoc_compact


def main(scale: str = "small") -> None:
    graphs = suite(scale)
    csv = Csv(["graph", "max_degree", "serial", "gm", "cat", "rsoc",
               "rsoc_compact", "jp", "ws_mb"])
    for gname, g in graphs.items():
        serial = col.n_colors_used(col.greedy_sequential(g))
        row = [gname, g.max_degree, serial]
        rsoc_res = None
        for algo in ("gm", "cat", "rsoc"):
            res = col.ALGORITHMS[algo](g, seed=1)
            if algo == "rsoc":
                rsoc_res = res
            row.append(res.n_colors)
        row.append(color_rsoc_compact(g, seed=1).n_colors)
        row.append(col.color_jp(g, seed=1).n_colors)
        row.append(forb_ws_mb(g.n_vertices, 16, rsoc_res.final_C))
        csv.row(*row)


if __name__ == "__main__":
    main()
