"""Color-quality table: every algorithm vs the serial-greedy oracle on all
six paper graphs (the paper: parallel speed does not cost colors)."""
from __future__ import annotations

from benchmarks.common import Csv, suite
from repro.core import coloring as col
from repro.core.frontier import color_rsoc_compact


def main(scale: str = "small") -> None:
    graphs = suite(scale)
    csv = Csv(["graph", "max_degree", "serial", "gm", "cat", "rsoc",
               "rsoc_compact", "jp"])
    for gname, g in graphs.items():
        serial = col.n_colors_used(col.greedy_sequential(g))
        row = [gname, g.max_degree, serial]
        for algo in ("gm", "cat", "rsoc"):
            row.append(col.ALGORITHMS[algo](g, seed=1).n_colors)
        row.append(color_rsoc_compact(g, seed=1).n_colors)
        row.append(col.color_jp(g, seed=1).n_colors)
        csv.row(*row)


if __name__ == "__main__":
    main()
