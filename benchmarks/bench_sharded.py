"""Sharded incremental recoloring: step latency and halo traffic vs scale.

The claim under test (DESIGN.md §15): a sharded tenant's repair pays one
collective per round whose payload is O(boundary), not O(n).  On a 2-D mesh
family the boundary of a block partition grows like √n per cut, so the
8-shard halo bytes/round must grow with n but *sublinearly* — that curve is
recorded in BENCH_sharded.json and asserted here.  The 1-shard column is
the differential bar: identical colors to the single-device
``mode="incremental"`` engine on the same update stream.

Shard counts need forced host devices, so the sweep runs in ONE dedicated
subprocess (same trick as tests/test_sharded.py) that sets XLA_FLAGS before
importing jax and reports every row as JSON on its last stdout line.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import Csv

SCALES = {"tiny": (16, 24), "small": (32, 48), "medium": (48, 96)}
SHARDS = (1, 4, 8)

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import sys
import time
import numpy as np
import jax
from repro import api
from repro.core import coloring as col
from repro.dynamic import delta, recolor_sharded
from repro.dynamic.incremental import recolor_incremental
from repro.graphs import generators as gen

sides = json.loads(sys.argv[1])
shard_counts = json.loads(sys.argv[2])
rows = []
for s in sides:
    g = gen.mesh2d(s, s)
    n = g.n_vertices

    def batches(k=5, bs=64):
        rng = np.random.default_rng(17)
        for _ in range(k + 1):
            ins = rng.integers(0, n, size=(bs, 2)).astype(np.int64)
            dels = rng.integers(0, n, size=(bs // 4, 2)).astype(np.int64)
            yield ins[ins[:, 0] != ins[:, 1]], dels

    # reference stream for the 1-shard differential
    ref = api.color(g, mode="incremental", seed=0).state
    ref_colors = []
    for ins, dels in batches():
        ref = recolor_incremental(ref, ins, dels)
        ref_colors.append(ref.colors)

    for D in shard_counts:
        mesh = jax.make_mesh((D,), ("data",))
        st = api.color(g, mode="incremental", backend="distributed",
                       mesh=mesh, seed=0).state
        times, identical = [], True
        for i, (ins, dels) in enumerate(batches()):
            t0 = time.perf_counter()
            st = recolor_sharded(st, ins, dels)
            st.colors_dev.block_until_ready()
            dt = time.perf_counter() - t0
            if i > 0:            # first batch is the jit warmup
                times.append(dt)
            if D == 1:
                identical = identical and bool(
                    np.array_equal(st.colors, ref_colors[i]))
        rows.append({
            "graph": f"mesh2d_{s}x{s}", "n": n, "shards": D,
            "p50_step_ms": float(np.median(times)) * 1e3,
            "halo_bytes_per_round": int(st.halo_bytes_per_round),
            "last_halo_bytes": int(st.last_halo_bytes),
            "colors": int(st.n_colors),
            "proper": bool(col.is_proper(delta.state_to_csr(st),
                                         st.colors)),
            "identical_1shard": bool(identical) if D == 1 else None,
            "replans": int(st.replans),
        })
print(json.dumps(rows))
"""


def main(scale: str = "small") -> None:
    if scale not in SCALES:
        raise SystemExit(f"unknown scale {scale!r}; known: {sorted(SCALES)}")
    sides = SCALES[scale]
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.setdefault("PYTHONPATH", "src")
    p = subprocess.run(
        [sys.executable, "-c", _SCRIPT, json.dumps(list(sides)),
         json.dumps(list(SHARDS))],
        capture_output=True, text=True, env=env, timeout=3000)
    if p.returncode != 0:
        raise SystemExit(f"sharded bench subprocess failed:\n"
                         f"{p.stderr[-3000:]}")
    rows = json.loads(p.stdout.strip().splitlines()[-1])
    csv = Csv(["graph", "n", "shards", "p50_step_ms", "halo_bytes_per_round",
               "last_halo_bytes", "colors", "proper", "identical_1shard",
               "replans"])
    for r in rows:
        csv.row(*[r[h] for h in csv.header])

    # acceptance: every run proper; 1-shard bit-identical; 8-shard halo
    # bytes/round grows with n but sublinearly (boundary ~ sqrt(n))
    assert all(r["proper"] for r in rows), "improper sharded coloring"
    assert all(r["identical_1shard"] for r in rows if r["shards"] == 1), \
        "1-shard sharded stream diverged from mode='incremental'"
    by_n = sorted((r["n"], r["halo_bytes_per_round"])
                  for r in rows if r["shards"] == 8)
    (n0, h0), (n1, h1) = by_n[0], by_n[-1]
    ok = h0 < h1 and (h1 / h0) < (n1 / n0)
    print(f"# acceptance: 8-shard halo bytes/round {h0} -> {h1} over "
          f"n {n0} -> {n1}: growing={h0 < h1} "
          f"sublinear={(h1 / h0):.2f}x < {(n1 / n0):.2f}x -> "
          f"{'PASS' if ok else 'FAIL'}", flush=True)
    if not ok:
        raise SystemExit("sharded halo-traffic acceptance failed")


if __name__ == "__main__":
    main()
