"""Forbidden-table micro-benchmark: packed bitset vs dense (DESIGN.md §10).

The inner structure every engine shares — gather panel -> forbidden set ->
mex — isolated from graph effects: one (rows, W) neighbor-color panel,
timed through both representations at several caps, reporting the
working-set shrink (the acceptance bar: ≥ 4× at C=128; word-aligned caps
give exactly 8×) and asserting the two mex outputs agree bit-for-bit on
the spot (``mex_match``).  The ``overflow`` sweep saturates rows so the
all-forbidden corner is timed and checked too.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, time_fn
from repro.core import bitset
from repro.core import coloring as col

CAPS = (32, 64, 128, 256)
ROWS = {"tiny": 1024, "small": 8192, "medium": 32768}
# medium additionally sweeps the C=512 cap the distance-2 engine actually
# picks on dense meshes (distance2._pick_C_d2 tops out at 512) — the shrink
# claim must hold where the working set is largest
EXTRA_CAPS = {"medium": (512,)}


@functools.partial(jax.jit, static_argnums=1)
def _dense_pass(nbrc, C):
    return col._mex(col._forbidden_from_nbrc(nbrc, C))


@functools.partial(jax.jit, static_argnums=1)
def _bitset_pass(nbrc, C):
    return bitset.mex_words(bitset.pack_from_nbrc(nbrc, C), C)


def main(scale: str = "small") -> None:
    rows = ROWS.get(scale, 8192)
    W = 32
    rng = np.random.default_rng(0)
    csv = Csv(["graph", "algo", "C", "rows", "W", "ms", "ws_mb",
               "ws_reduction_x", "mex_match"])
    caps = CAPS + EXTRA_CAPS.get(scale, ())
    for mode in ("random", "overflow"):
        for C in caps:
            if mode == "random":
                Wm = W
                panel = rng.integers(-1, 300, size=(rows, Wm)).astype(
                    np.int32)
            else:
                # saturate: Wm >= C columns cycling 0..C-1, so every row
                # holds every color < C — the all-forbidden corner must be
                # timed and parity-checked at EVERY cap, not just C <= W
                Wm = max(W, C)
                panel = np.broadcast_to(
                    np.arange(Wm, dtype=np.int32) % C, (rows, Wm)).copy()
            nbrc = jnp.asarray(panel)
            gname = f"panel_{mode}_{rows}x{Wm}"
            ws = {impl: bitset.ws_mb(rows, C, impl)
                  for impl in ("dense", "bitset")}
            red = ws["dense"] / ws["bitset"]
            d_ms, (d_mex, d_ovf) = time_fn(
                lambda: jax.block_until_ready(_dense_pass(nbrc, C)),
                repeats=5)
            b_ms, (b_mex, b_ovf) = time_fn(
                lambda: jax.block_until_ready(_bitset_pass(nbrc, C)),
                repeats=5)
            match = bool(np.array_equal(np.asarray(d_mex), np.asarray(b_mex))
                         and np.array_equal(np.asarray(d_ovf),
                                            np.asarray(b_ovf)))
            if mode == "overflow":
                assert bool(np.asarray(b_ovf).all()), \
                    f"saturated panel must trip ovf on every row (C={C})"
            csv.row(gname, "dense", C, rows, Wm, d_ms * 1e3, ws["dense"],
                    1.0, match)
            csv.row(gname, "bitset", C, rows, Wm, b_ms * 1e3, ws["bitset"],
                    red, match)
            if C == 128 and mode == "random":
                print(f"# forbidden C=128: dense {ws['dense']:.3f}MB vs "
                      f"bitset {ws['bitset']:.3f}MB ({red:.1f}x shrink), "
                      f"time {d_ms * 1e3:.2f}ms -> {b_ms * 1e3:.2f}ms, "
                      f"mex_match={match}", flush=True)
            assert match, f"bitset/dense mex diverged at C={C} ({mode})"


if __name__ == "__main__":
    main()
