"""The paper's motivating use-case, plugged into our GNN substrate: a
coloring-derived conflict-free scatter schedule.

Coloring the edge-conflict structure (edges conflict iff same dst) yields
color classes within which every destination appears once — each class is
a race-free scatter.  We verify (a) the schedule is valid, (b) accumulation
becomes bitwise deterministic under edge permutation (plain segment-sum
float accumulation is order-dependent), and (c) measure the overhead."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Csv, suite, time_fn
from repro.core.schedule import edge_color_by_dst
from repro.graphs.csr import CSRGraph, from_edges, to_edge_list
from repro.models.gnn import colored_segment_sum


def main(scale: str = "small") -> None:
    g = suite(scale)["mesh2d"]
    e = to_edge_list(g)
    src, dst = e[:, 0].astype(np.int32), e[:, 1].astype(np.int32)
    n = g.n_vertices
    rng = np.random.default_rng(0)
    msg = rng.standard_normal((len(src), 32)).astype(np.float32)

    ranks, n_colors = edge_color_by_dst(src, dst, n)
    csv = Csv(["variant", "ms", "n_colors", "deterministic_under_perm",
               "max_abs_diff_vs_plain", "ws_mb"])

    plain = jax.jit(lambda m, d: jax.ops.segment_sum(m, d, n))
    colored = jax.jit(lambda m, d, c: colored_segment_sum(m, d, n, c,
                                                          n_colors))
    t_plain, out_plain = time_fn(
        lambda: plain(jnp.asarray(msg), jnp.asarray(dst)).block_until_ready(),
        repeats=5)
    t_col, out_col = time_fn(
        lambda: colored(jnp.asarray(msg), jnp.asarray(dst),
                        jnp.asarray(ranks)).block_until_ready(), repeats=5)

    # determinism under edge permutation
    perm = rng.permutation(len(src))
    out_col_p = colored(jnp.asarray(msg[perm]), jnp.asarray(dst[perm]),
                        jnp.asarray(ranks[perm]))
    det = bool(np.array_equal(np.asarray(out_col), np.asarray(out_col_p)))
    diff = float(np.abs(np.asarray(out_col) - np.asarray(out_plain)).max())
    ws = (msg.nbytes + np.asarray(out_plain).nbytes) / 2**20
    csv.row("plain_segment_sum", t_plain * 1e3, 1, "n/a", 0.0, ws)
    csv.row("colored_schedule", t_col * 1e3, n_colors, str(det), diff,
            ws + ranks.nbytes / 2**20)


if __name__ == "__main__":
    main()
