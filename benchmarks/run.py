"""Benchmark orchestrator: one section per paper table/figure.

  table1           paper Table 1 + Figs 1-2 (time, speedup, passes)
  conflicts        paper Figs 3-4 + 5-6 (conflicts, rounds vs parallelism)
  colors           color-quality vs serial greedy
  forbidden        forbidden-table micro: packed bitset vs dense (§10)
  distance2        paper §6 outlook (G^2 density; native vs materialized)
  colored_scatter  the technique applied to GNN aggregation
  incremental      dynamic-graph incremental recoloring vs from-scratch
  service          multi-tenant ColoringService: megabatched vs loop step
  sharded          sharded incremental: step latency + halo bytes vs scale
  lm_step          measured smoke-scale LM train-step wall time

Usage: PYTHONPATH=src python -m benchmarks.run [--scale=NAME] [--json]
                                               [section ...]

``--json`` additionally writes BENCH_<section>.json per section (schema:
{"section", "scale", "rows": [{... every CSV column, plus the normalized
keys the section's SECTION_KEYS schema declares}]}) so the perf trajectory
is machine-trackable across PRs; CI uploads these as artifacts (tiny AND
small scale).  Normalized keys a section does not declare are omitted, not
null-backfilled — non-coloring sections (lm_step, colored_scatter) carry
no graph/algo/ms/spec keys at all.  ``spec``/``spec_key`` echo the
resolved ``repro.api.ColoringSpec`` of the row's coloring call (DESIGN.md
§11), so trajectories key on the exact task, not just the column values.

Unknown section names abort *before* anything runs — a typo must not
silently skip a benchmark after minutes of earlier sections.
"""
from __future__ import annotations

import json
import sys
import time


SECTIONS = ["table1", "conflicts", "colors", "forbidden", "distance2",
            "colored_scatter", "incremental", "service", "sharded",
            "lm_step"]
SCALES = ["tiny", "small", "medium"]
# (SECTION_KEYS below must stay exhaustive over SECTIONS — checked at
# import so a new section cannot silently ship schema-less)

# Normalized keys are declared PER SECTION: a BENCH_<section>.json row
# carries a normalized key only when the section's schema declares it (plus
# every raw CSV column it emitted).  Sections that never invoke a coloring
# engine (lm_step, colored_scatter) therefore no longer emit garbage rows
# full of null graph/algo/ms/spec keys — and lm_step's model-parameter
# footprint is its own ``params_mb`` column, never misattributed to the
# coloring sections' forbidden-working-set ``ws_mb``.
# spec/spec_key are the resolved repro.api.ColoringSpec of the row's
# coloring call; n_rounds/retries come from the row's ColoringResult and
# kernel_fallbacks is the kernels.fallback counter delta attributed to the
# row (DESIGN.md §12) — tracked for every section, kernels dispatch
# everywhere.
_COLORING_KEYS = ("graph", "algo", "ms", "ws_mb", "colors", "gather_passes",
                  "spec_key", "spec", "n_rounds", "retries",
                  "kernel_fallbacks")
SECTION_KEYS = {
    "table1": _COLORING_KEYS,
    "conflicts": ("graph", "algo", "ws_mb", "colors", "spec_key", "spec",
                  "n_rounds", "retries", "kernel_fallbacks"),
    "colors": ("graph", "algo", "ws_mb", "colors", "spec_key", "spec",
               "n_rounds", "retries", "kernel_fallbacks"),
    "forbidden": ("graph", "algo", "ms", "ws_mb", "kernel_fallbacks"),
    "distance2": _COLORING_KEYS + ("bytes_moved", "kernel"),
    "colored_scatter": ("ms", "ws_mb", "kernel_fallbacks"),
    "incremental": ("graph", "ws_mb", "spec_key", "spec", "n_rounds",
                    "retries", "kernel_fallbacks"),
    "service": ("ms", "kernel_fallbacks"),
    # sharded runs its mesh sweep in a subprocess, so no spec echo and no
    # kernel-fallback attribution land in the parent's rows
    "sharded": ("graph", "colors", "kernel_fallbacks"),
    "lm_step": ("params_mb", "kernel_fallbacks"),
}
assert set(SECTION_KEYS) == set(SECTIONS), \
    (sorted(set(SECTION_KEYS) ^ set(SECTIONS)))


def lm_step(scale: str = "small") -> None:
    """Wall-time of the real jitted train step at smoke scale (sanity that
    the training path is healthy; full-scale numbers live in §Roofline).
    ``scale='tiny'`` drops to a single architecture so bench-smoke stays
    fast; the smoke model configs themselves are already minimal."""
    import functools
    import jax
    import jax.numpy as jnp
    from benchmarks.common import Csv, time_fn
    from repro import configs
    from repro.data.pipeline import TokenStream
    from repro.models import transformer as TF
    from repro.training.optimizer import OptimizerConfig, init_opt_state
    from repro.training.train_loop import make_train_step

    archs = ("qwen3-1.7b",) if scale == "tiny" else \
        ("qwen3-1.7b", "phi3.5-moe-42b-a6.6b")
    # params_mb, NOT ws_mb: this is the model-parameter footprint, a
    # different quantity from the coloring sections' forbidden-table
    # working set — the shared name used to misattribute it in the JSON
    csv = Csv(["arch", "ms_per_step", "tokens_per_s", "loss0", "loss_end",
               "params_mb"])
    for arch in archs:
        cfg = configs.get(arch).make_smoke()
        params = TF.init_params(jax.random.PRNGKey(0), cfg)
        params_mb = sum(x.size * x.dtype.itemsize
                        for x in jax.tree.leaves(params)) / 2**20
        stream = TokenStream(batch=8, seq_len=64, vocab=cfg.vocab)
        step = make_train_step(lambda p, b: TF.train_step_loss(p, cfg, b),
                               OptimizerConfig(warmup_steps=2,
                                               total_steps=20), 1,
                               donate=False)
        opt = init_opt_state(params)
        batch = jax.tree.map(jnp.asarray, next(stream))
        params, opt, m0 = step(params, opt, batch)      # compile + step
        t0 = time.perf_counter()
        n = 5
        for _ in range(n):
            batch = jax.tree.map(jnp.asarray, next(stream))
            params, opt, m = step(params, opt, batch)
        jax.block_until_ready(params)
        dt = (time.perf_counter() - t0) / n
        csv.row(arch, dt * 1e3, 8 * 64 / dt, float(m0["loss"]),
                float(m["loss"]), params_mb)


def _section(name: str):
    if name == "table1":
        from benchmarks import bench_table1 as b
    elif name == "forbidden":
        from benchmarks import bench_forbidden as b
    elif name == "conflicts":
        from benchmarks import bench_conflicts as b
    elif name == "colors":
        from benchmarks import bench_colors as b
    elif name == "distance2":
        from benchmarks import bench_distance2 as b
    elif name == "colored_scatter":
        from benchmarks import bench_colored_scatter as b
    elif name == "incremental":
        from benchmarks import bench_incremental as b
    elif name == "service":
        from benchmarks import bench_service as b
    elif name == "sharded":
        from benchmarks import bench_sharded as b
    elif name == "lm_step":
        return lm_step
    else:
        raise AssertionError(name)
    return b.main


def _write_json(name: str, scale: str, rows: list, elapsed_s: float) -> str:
    keys = SECTION_KEYS[name]
    # declared-but-absent keys surface as explicit nulls (within-section row
    # variance, e.g. distance2's engine vs kernel rows); undeclared keys are
    # OMITTED, never null-backfilled — consumers key on presence
    out = {"section": name, "scale": scale, "elapsed_s": elapsed_s,
           "rows": [{**{k: r.get(k) for k in keys}, **r} for r in rows]}
    path = f"BENCH_{name}.json"
    with open(path, "w") as f:
        json.dump(out, f, indent=1, default=str)
    return path


def main(argv=None) -> None:
    args = list(argv if argv is not None else sys.argv[1:])
    scale = "small"
    emit_json = False
    names = []
    for a in args:
        if a.startswith("--scale="):
            scale = a.split("=", 1)[1]
        elif a == "--scale":
            raise SystemExit("use --scale=NAME")
        elif a == "--json":
            emit_json = True
        else:
            names.append(a)
    names = names or SECTIONS
    # validate everything up front: fail loudly before running any section
    unknown = [n for n in names if n not in SECTIONS]
    if unknown:
        raise SystemExit(f"unknown section(s) {unknown}; known: {SECTIONS}")
    if scale not in SCALES:
        raise SystemExit(f"unknown scale {scale!r}; known: {SCALES}")
    for name in names:
        print(f"\n===== bench: {name} (scale={scale}) =====", flush=True)
        t0 = time.perf_counter()
        import contextlib
        tc_ctx = contextlib.nullcontext()
        if emit_json:
            from benchmarks import common
            from repro import obs
            common.start_json_capture()
            tc_ctx = obs.trace()       # collect a RunTrace per api.color call
        try:
            with tc_ctx as tc:
                _section(name)(scale=scale)
        finally:
            elapsed = time.perf_counter() - t0
            if emit_json:
                from benchmarks import common
                from repro.obs import export
                path = _write_json(name, scale, common.end_json_capture(),
                                   elapsed)
                print(f"# wrote {path}", flush=True)
                n = export.write_jsonl(tc.traces, f"TRACE_{name}.jsonl")
                print(f"# wrote TRACE_{name}.jsonl ({n} traces)", flush=True)
        print(f"===== {name} done in {elapsed:.1f}s =====", flush=True)


if __name__ == "__main__":
    main()
