"""Paper Table 1 + Figs 1-2: execution time of CAT vs RSOC on the six graph
classes, plus the structural speedup (gather passes = collective count in
the distributed schedule).

Wall time on this CPU container reflects the serialized work of the SPMD
program; the architecture-independent signal the paper predicts — fewer
passes over the graph and fewer rounds for RSOC — is reported alongside.
Timings are per algorithm end-to-end (jit-compiled, warmup excluded).
"""
from __future__ import annotations

from benchmarks.common import Csv, forb_ws_mb, suite, time_fn
from repro import api


def main(scale: str = "small") -> None:
    graphs = suite(scale)
    csv = Csv(["graph", "n_vertices", "algo", "ms", "speedup_vs_cat",
               "rounds", "gather_passes", "conflicts", "colors", "ws_mb"])
    for gname, g in graphs.items():
        base_ms = None
        for algo in ("cat", "rsoc", "rsoc_compact"):
            spec = api.ColoringSpec(algorithm=algo, seed=1)
            sec, res = time_fn(api.color, g, spec, repeats=3)
            ms = sec * 1e3
            if algo == "cat":
                base_ms = ms
            csv.row(gname, g.n_vertices, algo, ms,
                    base_ms / ms if base_ms else 1.0,
                    res.n_rounds, res.gather_passes, res.total_conflicts,
                    res.n_colors,
                    forb_ws_mb(g.n_vertices, 16, res.final_C),
                    spec=res.spec, result=res)


if __name__ == "__main__":
    main()
