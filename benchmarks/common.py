"""Shared benchmark utilities: timing, CSV emission, graph suite cache."""
from __future__ import annotations

import functools
import time

import numpy as np

from repro.graphs import generators as gen


@functools.lru_cache(maxsize=None)
def suite(scale: str = "small"):
    return gen.paper_suite(scale)


def time_fn(fn, *args, repeats: int = 3, warmup: int = 1, **kw):
    """Median wall time of fn(*args) in seconds (jit warmup excluded)."""
    for _ in range(warmup):
        fn(*args, **kw)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


class Csv:
    def __init__(self, header):
        self.header = list(header)
        self.rows = []
        print(",".join(self.header), flush=True)

    def row(self, *vals):
        vals = [f"{v:.6g}" if isinstance(v, float) else str(v) for v in vals]
        self.rows.append(vals)
        print(",".join(vals), flush=True)
