"""Shared benchmark utilities: timing, CSV emission (with an optional JSON
sink for ``run.py --json``), graph suite cache, working-set accounting."""
from __future__ import annotations

import functools
import time

import numpy as np

from repro.core import bitset
from repro.graphs import generators as gen
from repro.obs import metrics as obs_metrics

# Active JSON row collector.  ``run.py --json`` installs a list here around
# each section; every Csv.row() then also lands as a dict keyed by the CSV
# header, and run.py writes the section's rows to BENCH_<section>.json.
_json_rows = None


def start_json_capture() -> None:
    global _json_rows
    _json_rows = []


def end_json_capture() -> list:
    global _json_rows
    rows, _json_rows = _json_rows, None
    return rows if rows is not None else []


@functools.lru_cache(maxsize=None)
def suite(scale: str = "small"):
    return gen.paper_suite(scale)


def time_fn(fn, *args, repeats: int = 3, warmup: int = 1, **kw):
    """Median wall time of fn(*args) in seconds (jit warmup excluded).

    Both warmup and timed outputs go through ``jax.block_until_ready``:
    under JAX's async dispatch a bare fn() returns at *launch*, so timing
    without blocking measures dispatch latency, not compute — and an
    unblocked warmup leaks the first run's compute into the first timed
    repeat.  Host-side outputs (numpy, dataclasses) pass through untouched.
    (Semantics change noted in DESIGN.md §9: ms columns are end-to-end
    compute, comparable across backends.)
    """
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def forb_ws_mb(n_rows: int, n_chunks: int, C: int,
               impl: str = "bitset") -> float:
    """Retained forbidden-table working set (MB) of one gather chunk:
    ceil(n_rows / n_chunks) rows at cap C under ``impl`` — the per-pass
    VMEM term the packed bitset shrinks 8× (DESIGN.md §10)."""
    rows = -(-max(int(n_rows), 1) // max(int(n_chunks), 1))
    return bitset.ws_mb(rows, C, impl)


def _jsonable(v):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (np.bool_,)):
        return bool(v)
    return v


class Csv:
    def __init__(self, header):
        self.header = list(header)
        self.rows = []
        self._fallbacks_seen = obs_metrics.total_matching("kernels.fallback")
        print(",".join(self.header), flush=True)

    def row(self, *vals, spec=None, result=None, extra=None):
        """Emit one CSV row.  ``spec`` (a ``repro.api.ColoringSpec``) is not
        printed, but under ``run.py --json`` it lands in the JSON row as the
        resolved spec dict plus its stable ``spec_key`` — every coloring row
        records exactly which task produced it.

        ``result`` (a ``ColoringResult``) contributes the obs columns
        ``n_rounds``/``retries``; ``extra`` is a dict of additional JSON-only
        keys (e.g. state-derived stats where no result is at hand).  Every
        JSON row also carries ``kernel_fallbacks`` — the process-wide
        ``kernels.fallback`` counter delta since this table's previous row.
        """
        if _json_rows is not None:
            d = {h: _jsonable(v) for h, v in zip(self.header, vals)}
            if spec is not None:
                resolved = spec.resolved()
                d["spec"] = resolved.asdict()
                d["spec_key"] = resolved.spec_key()
            if result is not None:
                d["n_rounds"] = int(result.n_rounds)
                d["retries"] = int(result.retries)
            if extra:
                d.update({k: _jsonable(v) for k, v in extra.items()})
            fb = obs_metrics.total_matching("kernels.fallback")
            d.setdefault("kernel_fallbacks", fb - self._fallbacks_seen)
            self._fallbacks_seen = fb
            _json_rows.append(d)
        vals = [f"{v:.6g}" if isinstance(v, float) else str(v) for v in vals]
        self.rows.append(vals)
        print(",".join(vals), flush=True)
