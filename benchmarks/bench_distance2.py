"""Paper §6 outlook: distance-2 coloring.  G^2 is much denser than G, and
the paper predicts RSOC's advantage (fewer conflicts/rounds/passes) grows
with density — we measure exactly that on the mesh classes."""
from __future__ import annotations

from benchmarks.common import Csv, suite, time_fn
from repro.core.distance2 import color_distance_d
from repro.graphs.csr import power_graph


def main(scale: str = "small") -> None:
    graphs = {k: v for k, v in suite(scale).items()
              if k in ("mesh2d", "bmw3_2", "pwtk")}
    csv = Csv(["graph", "d", "avg_degree_gd", "algo", "ms", "rounds",
               "gather_passes", "conflicts", "colors"])
    for gname, g in graphs.items():
        for d in (1, 2):
            gd = power_graph(g, d)
            avg_deg = gd.n_edges / gd.n_vertices
            for algo in ("cat", "rsoc"):
                sec, (res, _) = time_fn(color_distance_d, g, d=d,
                                        algorithm=algo, seed=1, repeats=2)
                csv.row(gname, d, avg_deg, algo, sec * 1e3, res.n_rounds,
                        res.gather_passes, res.total_conflicts, res.n_colors)


if __name__ == "__main__":
    main()
