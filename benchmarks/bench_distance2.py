"""Paper §6 outlook: distance-2 coloring.  G^2 is much denser than G, and
the paper predicts RSOC's advantage (fewer conflicts/rounds/passes) grows
with density — we measure exactly that on the mesh classes, and compare the
native two-hop engine (DESIGN.md §8) against the materialized power_graph
path on both time and peak working set.

The materialized rows' ``ms`` includes the G² build (paid on every call in
production); G² is built ONCE per (graph, d) here and shared between the
degree statistic and every algorithm row — it used to be rebuilt per row.

The second table exercises the VMEM-paged two-hop KERNEL directly
(DESIGN.md §8.3): a synthetic ELL table sized past the old 8 MB residency
bound that used to force the jnp fallback is paged through
``kernels.ops.twohop`` on the Pallas path, timed end-to-end (time_fn
blocks), checked bit-identical against ``ref.twohop_ref``, and asserted to
dispatch with ZERO ``kernels.fallback`` increments — the acceptance row
for the paging work.  ``bytes_moved`` is the exact HBM traffic of the
paged schedule (every row-block streams the whole padded table), which
roofline_report.py prefers over the ws_mb lower bound.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, forb_ws_mb, suite, time_fn
from repro import api
from repro.core import distance2
from repro.graphs.csr import CSRGraph, power_graph


def ws_mb_materialized(gd: CSRGraph, ell_cap: int = 512) -> float:
    """Peak working set of the materialized path: G²'s CSR plus what the
    coloring loop actually allocates — an ELL capped at ``ell_cap`` columns
    with hub rows spilling into the COO side-channel (see
    ``coloring.prepare``)."""
    W = max(min(gd.max_degree, ell_cap), 1)
    ell_bytes = gd.n_vertices * W * 4
    ovf_bytes = int(np.maximum(gd.degrees - W, 0).sum()) * 8   # src+dst int32
    csr_bytes = gd.indices.nbytes + gd.indptr.nbytes
    return (ell_bytes + ovf_bytes + csr_bytes) / 2**20


# Synthetic hop-2 tables for the paged-kernel rows: every scale's table
# exceeds the old 8 MB VMEM residency bound (n_all * W * 4 bytes), so a
# pre-paging dispatcher would have silently fallen back to jnp.  The (n,)
# color/priority vectors stay far under budget — these shapes are pageable,
# not degenerate.
KERNEL_SHAPES = {
    "tiny":   dict(n_all=144 * 1024, W=16, R=512),    # 9 MB table
    "small":  dict(n_all=160 * 1024, W=16, R=1024),   # 10 MB table
    "medium": dict(n_all=320 * 1024, W=16, R=2048),   # 20 MB table
}


def kernel_rows(scale: str) -> None:
    """Time the paged two-hop kernel on an above-the-old-bound table and
    prove (in-bench, loudly) that it ran on the Pallas path with zero
    fallbacks and bit-identical outputs to the reference."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    from repro.kernels import twohop as twohop_mod
    from repro.obs import metrics as obs_metrics

    shp = KERNEL_SHAPES[scale]
    n_all, W, R = shp["n_all"], shp["W"], shp["R"]
    C = 64
    rng = np.random.default_rng(7)
    ell_all = jnp.asarray(rng.integers(-1, n_all, size=(n_all, W)),
                          dtype=jnp.int32)
    colors = jnp.asarray(rng.integers(-1, C, size=(n_all,)), dtype=jnp.int32)
    pri = jnp.asarray(rng.permutation(n_all), dtype=jnp.int32)
    U_rows = jnp.ones((R,), dtype=bool)
    ell_rows = ell_all[:R]
    row_start = 0

    backend = "pallas" if jax.default_backend() == "tpu" else \
        "pallas_interpret"
    table_mb = n_all * W * 4 / 2**20
    page_rows = twohop_mod.default_page_rows(n_all, W)
    n_pages = -(-n_all // page_rows)
    csv = Csv(["graph", "algo", "kernel", "backend", "n_all", "W",
               "table_mb", "page_rows", "n_pages", "ms", "ws_mb",
               "bytes_moved_mb", "parity"])

    fb0 = obs_metrics.total_matching("kernels.fallback")
    ms, out = time_fn(ops.twohop, ell_rows, ell_all, colors, pri, U_rows,
                      row_start, C=C, backend=backend, repeats=2)
    fb = obs_metrics.total_matching("kernels.fallback") - fb0
    assert fb == 0, (
        f"paged twohop fell back {fb}x on a pageable {table_mb:.1f}MB table "
        f"— the paging dispatch regressed (backend={backend})")

    want = ref.twohop_ref(ell_rows, ell_all, colors, pri, row_start, U_rows,
                          C)
    parity = all(np.array_equal(np.asarray(a), np.asarray(b))
                 for a, b in zip(out, want))
    assert parity, "paged twohop kernel diverged from ref.twohop_ref"

    # exact paged-schedule traffic: each of the ceil(R/128) row-blocks
    # streams the whole padded table once, plus the row tiles, the two (n,)
    # vectors, and the three outputs
    n_blocks = -(-R // 128)
    bytes_moved = (n_blocks * n_pages * page_rows * W * 4
                   + R * W * 4 + 2 * n_all * 4 + R * (4 + 1 + 1))
    ws_mb = ops.twohop_vmem_bytes(R, W, n_all, C, n_all=n_all) / 2**20
    csv.row(f"synth_{n_all}x{W}", "twohop_paged", "twohop", backend, n_all,
            W, table_mb, page_rows, n_pages, ms * 1e3, ws_mb,
            bytes_moved / 2**20, parity,
            extra={"bytes_moved": int(bytes_moved)})
    print(f"# twohop paged kernel [{backend}]: {table_mb:.1f}MB table "
          f"(> 8MB old bound) in {n_pages} pages x {page_rows} rows, "
          f"{ms * 1e3:.1f}ms, fallbacks=0, bit-identical to ref",
          flush=True)


def main(scale: str = "small") -> None:
    graphs = {k: v for k, v in suite(scale).items()
              if k in ("mesh2d", "bmw3_2", "pwtk")}
    csv = Csv(["graph", "d", "path", "avg_degree_gd", "algo", "ms", "rounds",
               "gather_passes", "conflicts", "colors", "ws_mb",
               "forb_ws_mb"])
    for gname, g in graphs.items():
        for d in (1, 2):
            build_s, gd = time_fn(power_graph, g, d, repeats=1, warmup=0)
            avg_deg = gd.n_edges / max(gd.n_vertices, 1)
            ws_mat = ws_mb_materialized(gd)
            mat_ms = {}
            for algo in ("cat", "rsoc"):
                # materialized path: distance-1 coloring of the explicit G^d
                spec = api.ColoringSpec(algorithm=algo, seed=1)
                sec, res = time_fn(api.color, gd, spec, repeats=2)
                mat_ms[algo] = (build_s + sec) * 1e3
                csv.row(gname, d, "materialized", avg_deg, algo,
                        mat_ms[algo], res.n_rounds, res.gather_passes,
                        res.total_conflicts, res.n_colors, ws_mat,
                        forb_ws_mb(gd.n_vertices, 16, res.final_C),
                        spec=res.spec, result=res)
            if d != 2:
                continue
            spec = api.ColoringSpec(algorithm="rsoc", distance=2, seed=1)
            sec, res = time_fn(api.color, g, spec, repeats=2)
            nat_ms = sec * 1e3
            # the honest engine working set (distance2.native_ws_mb): ELL +
            # (n,) vectors + gathered color/priority panels + packed
            # forbidden rows — the old local estimate dropped all but the
            # first and half the second
            ws_nat = distance2.native_ws_mb(g, n_chunks=16, C=res.final_C)
            csv.row(gname, d, "native", avg_deg, "rsoc", nat_ms,
                    res.n_rounds, res.gather_passes, res.total_conflicts,
                    res.n_colors, ws_nat,
                    forb_ws_mb(g.n_vertices, 16, res.final_C),
                    spec=res.spec, result=res)
            print(f"# native-vs-materialized {gname} d=2: "
                  f"native {nat_ms:.1f}ms / {ws_nat:.2f}MB ws  vs  "
                  f"materialized(rsoc) {mat_ms['rsoc']:.1f}ms / "
                  f"{ws_mat:.2f}MB ws  "
                  f"(time {mat_ms['rsoc'] / max(nat_ms, 1e-9):.2f}x, "
                  f"ws {ws_mat / max(ws_nat, 1e-9):.2f}x)", flush=True)
    kernel_rows(scale)


if __name__ == "__main__":
    main()
