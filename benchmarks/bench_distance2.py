"""Paper §6 outlook: distance-2 coloring.  G^2 is much denser than G, and
the paper predicts RSOC's advantage (fewer conflicts/rounds/passes) grows
with density — we measure exactly that on the mesh classes, and compare the
native two-hop engine (DESIGN.md §8) against the materialized power_graph
path on both time and peak working set.

The materialized rows' ``ms`` includes the G² build (paid on every call in
production); G² is built ONCE per (graph, d) here and shared between the
degree statistic and every algorithm row — it used to be rebuilt per row.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, forb_ws_mb, suite, time_fn
from repro import api
from repro.graphs.csr import CSRGraph, power_graph


def ws_mb_materialized(gd: CSRGraph, ell_cap: int = 512) -> float:
    """Peak working set of the materialized path: G²'s CSR plus what the
    coloring loop actually allocates — an ELL capped at ``ell_cap`` columns
    with hub rows spilling into the COO side-channel (see
    ``coloring.prepare``)."""
    W = max(min(gd.max_degree, ell_cap), 1)
    ell_bytes = gd.n_vertices * W * 4
    ovf_bytes = int(np.maximum(gd.degrees - W, 0).sum()) * 8   # src+dst int32
    csr_bytes = gd.indices.nbytes + gd.indptr.nbytes
    return (ell_bytes + ovf_bytes + csr_bytes) / 2**20


def ws_mb_native(g: CSRGraph, n_chunks: int = 16) -> float:
    """Peak working set of the native path: G's ELL plus one chunk's
    transient two-hop gather panel (colors + priorities, W + W² wide)."""
    W = max(g.max_degree, 1)
    cs = -(-g.n_vertices // n_chunks)
    ell_bytes = g.n_vertices * W * 4
    gather_bytes = cs * (W + W * W) * 4 * 2
    return (ell_bytes + gather_bytes) / 2**20


def main(scale: str = "small") -> None:
    graphs = {k: v for k, v in suite(scale).items()
              if k in ("mesh2d", "bmw3_2", "pwtk")}
    csv = Csv(["graph", "d", "path", "avg_degree_gd", "algo", "ms", "rounds",
               "gather_passes", "conflicts", "colors", "ws_mb",
               "forb_ws_mb"])
    for gname, g in graphs.items():
        for d in (1, 2):
            build_s, gd = time_fn(power_graph, g, d, repeats=1, warmup=0)
            avg_deg = gd.n_edges / max(gd.n_vertices, 1)
            ws_mat = ws_mb_materialized(gd)
            mat_ms = {}
            for algo in ("cat", "rsoc"):
                # materialized path: distance-1 coloring of the explicit G^d
                spec = api.ColoringSpec(algorithm=algo, seed=1)
                sec, res = time_fn(api.color, gd, spec, repeats=2)
                mat_ms[algo] = (build_s + sec) * 1e3
                csv.row(gname, d, "materialized", avg_deg, algo,
                        mat_ms[algo], res.n_rounds, res.gather_passes,
                        res.total_conflicts, res.n_colors, ws_mat,
                        forb_ws_mb(gd.n_vertices, 16, res.final_C),
                        spec=res.spec, result=res)
            if d != 2:
                continue
            spec = api.ColoringSpec(algorithm="rsoc", distance=2, seed=1)
            sec, res = time_fn(api.color, g, spec, repeats=2)
            nat_ms = sec * 1e3
            ws_nat = ws_mb_native(g)
            csv.row(gname, d, "native", avg_deg, "rsoc", nat_ms,
                    res.n_rounds, res.gather_passes, res.total_conflicts,
                    res.n_colors, ws_nat,
                    forb_ws_mb(g.n_vertices, 16, res.final_C),
                    spec=res.spec, result=res)
            print(f"# native-vs-materialized {gname} d=2: "
                  f"native {nat_ms:.1f}ms / {ws_nat:.2f}MB ws  vs  "
                  f"materialized(rsoc) {mat_ms['rsoc']:.1f}ms / "
                  f"{ws_mat:.2f}MB ws  "
                  f"(time {mat_ms['rsoc'] / max(nat_ms, 1e-9):.2f}x, "
                  f"ws {ws_mat / max(ws_nat, 1e-9):.2f}x)", flush=True)


if __name__ == "__main__":
    main()
