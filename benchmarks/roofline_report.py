"""Format results/dryrun.jsonl into the EXPERIMENTS.md roofline tables.

``--bench BENCH_*.json`` additionally formats the benchmark-runner JSON
dumps (benchmarks.run --json) into a per-kernel achieved-vs-peak memory
bandwidth table: achieved bytes/s is bounded below by the forbidden-table
working set streamed once per gather pass (ws_mb * gather_passes / wall),
compared against ``--peak-gbs``.  Rows from files written before the obs
columns existed lack n_rounds/retries/kernel_fallbacks — those backfill
null-safely as "-", never KeyError.
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict


def fmt(x, unit=""):
    if x is None:
        return "-"
    for th, suf in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "k")):
        if abs(x) >= th:
            return f"{x / th:.2f}{suf}{unit}"
    return f"{x:.3g}{unit}"


def load(path):
    rows = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            rows[(r["arch"], r["shape"], r["mesh"])] = r
    return rows


def table(rows, mesh="16x16"):
    out = ["| arch | shape | t_compute | t_memory | t_collective | "
           "bottleneck | useful ratio | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for (arch, shape, m), r in sorted(rows.items()):
        if m != mesh:
            continue
        if not r.get("ok"):
            out.append(f"| {arch} | {shape} | FAIL | | | {r.get('error','')[:60]} | | |")
            continue
        rf = r["roofline"]
        ur = rf.get("useful_ratio")
        frac = rf.get("roofline_fraction")
        out.append(
            f"| {arch} | {shape} | {rf['t_compute_s']:.3e}s | "
            f"{rf['t_memory_s']:.3e}s | {rf['t_collective_s']:.3e}s | "
            f"**{rf['bottleneck']}** | "
            f"{ur:.3f}" .replace("None", "-") + " | "
            + (f"{frac:.3f}" if frac is not None else "-") + " |")
    return "\n".join(out)


def _achieved_bytes_s(r):
    """Achieved memory bandwidth of one row.  Kernel rows carry an explicit
    ``bytes_moved`` (exact bytes the kernel streamed: paged table × passes
    + gather traffic) — preferred when present.  Engine rows fall back to
    the lower bound: the forbidden working set streamed once per gather
    pass."""
    ms = r.get("ms")
    if not ms:
        return None
    bytes_moved = r.get("bytes_moved")
    if bytes_moved:
        return bytes_moved / (ms / 1e3)
    ws_mb = r.get("ws_mb")
    if not ws_mb:
        return None
    passes = r.get("gather_passes") or 1
    return ws_mb * 2**20 * max(passes, 1) / (ms / 1e3)


def bench_table(paths, peak_gbs: float):
    """Per-(section, graph, algo|kernel) achieved-vs-peak bandwidth table
    from BENCH_*.json dumps.  Rows without the timing schema (no ``ms``, or
    no algo/kernel/variant identity — e.g. every row of a non-coloring
    section like lm_step) are SKIPPED, never backfilled into garbage lines;
    only the obs columns (n_rounds / retries / kernel_fallbacks) backfill
    null-safely as "-" for pre-obs dumps."""
    out = ["| section | graph | algo | ms | rounds | retries | fallbacks | "
           "achieved B/s | peak frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    peak = peak_gbs * 1e9
    for path in paths:
        with open(path) as f:
            dump = json.load(f)
        for r in dump.get("rows", []):
            algo = r.get("kernel") or r.get("algo") or r.get("variant")
            if not isinstance(r.get("ms"), (int, float)) or algo is None:
                continue                      # row is not a timing row
            ach = _achieved_bytes_s(r)
            frac = f"{ach / peak:.4f}" if ach is not None else "-"
            nr = r.get("n_rounds")       # absent in pre-obs dumps -> "-"
            rt = r.get("retries")
            fb = r.get("kernel_fallbacks")
            out.append(
                f"| {dump.get('section', path)} | {r.get('graph', '-')} | "
                f"{algo} | "
                f"{r['ms']:.3g} | "
                f"{nr if nr is not None else '-'} | "
                f"{rt if rt is not None else '-'} | "
                f"{fb if fb is not None else '-'} | "
                f"{fmt(ach, 'B/s')} | {frac} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="results/dryrun.jsonl")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--bench", nargs="*", default=None,
                    help="BENCH_*.json dumps to format (benchmarks.run "
                         "--json); skips the dryrun table when given")
    ap.add_argument("--peak-gbs", type=float, default=50.0,
                    help="peak memory bandwidth (GB/s) for the achieved-vs-"
                         "peak fraction")
    args = ap.parse_args()
    if args.bench:
        print(bench_table(args.bench, args.peak_gbs))
        return
    rows = load(args.jsonl)
    print(table(rows, args.mesh))
    n_ok = sum(1 for r in rows.values() if r.get("ok"))
    print(f"\n{n_ok}/{len(rows)} runs ok")


if __name__ == "__main__":
    main()
