"""Format results/dryrun.jsonl into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import argparse
import json
from collections import defaultdict


def fmt(x, unit=""):
    if x is None:
        return "-"
    for th, suf in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "k")):
        if abs(x) >= th:
            return f"{x / th:.2f}{suf}{unit}"
    return f"{x:.3g}{unit}"


def load(path):
    rows = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            rows[(r["arch"], r["shape"], r["mesh"])] = r
    return rows


def table(rows, mesh="16x16"):
    out = ["| arch | shape | t_compute | t_memory | t_collective | "
           "bottleneck | useful ratio | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for (arch, shape, m), r in sorted(rows.items()):
        if m != mesh:
            continue
        if not r.get("ok"):
            out.append(f"| {arch} | {shape} | FAIL | | | {r.get('error','')[:60]} | | |")
            continue
        rf = r["roofline"]
        ur = rf.get("useful_ratio")
        frac = rf.get("roofline_fraction")
        out.append(
            f"| {arch} | {shape} | {rf['t_compute_s']:.3e}s | "
            f"{rf['t_memory_s']:.3e}s | {rf['t_collective_s']:.3e}s | "
            f"**{rf['bottleneck']}** | "
            f"{ur:.3f}" .replace("None", "-") + " | "
            + (f"{frac:.3f}" if frac is not None else "-") + " |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="results/dryrun.jsonl")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = load(args.jsonl)
    print(table(rows, args.mesh))
    n_ok = sum(1 for r in rows.values() if r.get("ok"))
    print(f"\n{n_ok}/{len(rows)} runs ok")


if __name__ == "__main__":
    main()
