"""Dynamic-graph scenario: incremental recoloring vs from-scratch RSOC.

A long-lived system holding a near-fixed-point coloring should pay per
*mutation batch*, not per graph: ``recolor_incremental`` seeds the defect
set from the endpoints of changed edges and runs the frontier-compacted
fused pass, so both the neighbor-gather pass count and the bytes moved per
pass shrink with the batch.  We sweep update-batch sizes (as a fraction of
the undirected edge count, half inserts / half deletes) on an RMAT-G and a
power-law RMAT-B graph and compare against a full from-scratch
``repro.api.color`` (RSOC) rerun.

The acceptance check of the dynamic subsystem rides here: at the default
scale (2^16-vertex RMAT) a 1%-of-edges batch must stay proper and take
strictly fewer gather passes than the from-scratch run.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Csv, forb_ws_mb, time_fn
from repro import api
from repro.core import coloring as col
from repro.dynamic import recolor_incremental, state_to_csr
from repro.graphs import generators as gen
from repro.graphs.csr import to_edge_list

SCALES = {"tiny": 10, "small": 16, "medium": 18}
BATCH_FRACS = (0.001, 0.01, 0.05)


def _undirected_edges(g) -> np.ndarray:
    e = to_edge_list(g)
    return e[e[:, 0] < e[:, 1]]


def _make_batch(rng, n, und, k):
    """k/2 random inserts + k/2 deletes drawn from the current edge set."""
    k_ins = k - k // 2
    ins = rng.integers(0, n, size=(k_ins, 2))
    ins = ins[ins[:, 0] != ins[:, 1]]
    dels = und[rng.choice(len(und), size=min(k // 2, len(und)),
                          replace=False)]
    return ins, dels


def main(scale: str = "small") -> None:
    if scale not in SCALES:
        raise SystemExit(f"unknown scale {scale!r}; known: {sorted(SCALES)}")
    log2n = SCALES[scale]
    graphs = {"rmat_g": gen.rmat_g(log2n), "rmat_b": gen.rmat_b(log2n)}
    csv = Csv(["graph", "n", "und_edges", "batch_frac", "batch_edges",
               "scratch_ms", "scratch_passes", "inc_ms", "inc_passes",
               "time_speedup", "pass_speedup", "proper", "ws_mb"])
    rng = np.random.default_rng(0)
    for gname, g in graphs.items():
        und = _undirected_edges(g)
        m = len(und)
        scratch_spec = api.ColoringSpec(algorithm="rsoc", seed=1)
        scratch_s, scratch = time_fn(api.color, g, scratch_spec, repeats=3)
        # At tiny, pin the dynamic-state shape knobs so rmat_g and rmat_b
        # land in ONE slot class (ell_cap below both max degrees, explicit
        # C/ovf_cap): the second graph then reuses every apply/repair jit
        # entry instead of recompiling the whole pipeline — bench-smoke
        # spends its tiny budget measuring, not compiling.
        inc_opts = dict(ell_cap=32, C=64, ovf_cap=16384) \
            if scale == "tiny" else {}
        res0 = api.color(g, mode="incremental", seed=1, **inc_opts)
        st0, inc_spec = res0.state, res0.spec
        for frac in BATCH_FRACS:
            k = max(2, int(m * frac))
            st = st0
            # warmup: compile apply/repair for this state's shapes
            ins, dels = _make_batch(rng, g.n_vertices, und, k)
            st = recolor_incremental(st, inserts=ins, deletes=dels)
            times, passes = [], []
            for _ in range(3):
                ins, dels = _make_batch(rng, g.n_vertices,
                                        _undirected_edges(state_to_csr(st)),
                                        k)
                t0 = time.perf_counter()
                st = recolor_incremental(st, inserts=ins, deletes=dels)
                times.append(time.perf_counter() - t0)
                passes.append(st.last_gather_passes)
            inc_s = float(np.median(times))
            inc_passes = int(np.median(passes))
            proper = col.is_proper(state_to_csr(st), st.colors)
            csv.row(gname, g.n_vertices, m, frac, k,
                    scratch_s * 1e3, scratch.gather_passes,
                    inc_s * 1e3, inc_passes,
                    scratch_s / inc_s if inc_s else float("inf"),
                    scratch.gather_passes / max(inc_passes, 1),
                    proper,
                    forb_ws_mb(st.frontier_cap, st.n_chunks, st.C),
                    spec=inc_spec,
                    extra={"n_rounds": st.last_rounds,
                           "retries": st.retries})
            if abs(frac - 0.01) < 1e-12:
                ok = proper and inc_passes < scratch.gather_passes
                print(f"# acceptance[{gname}]: 1% batch proper={proper} "
                      f"inc_passes={inc_passes} < "
                      f"scratch_passes={scratch.gather_passes} -> "
                      f"{'PASS' if ok else 'FAIL'} "
                      f"(time speedup {scratch_s / inc_s:.1f}x)",
                      flush=True)
                if not ok:
                    raise SystemExit(
                        f"incremental acceptance failed on {gname}")


if __name__ == "__main__":
    main()
