"""Multi-tenant ``ColoringService``: megabatched step vs per-tenant loop.

A service holding N same-shape tenants (DESIGN.md §13) should pay the
per-dispatch host overhead ONCE per update wave / repair round, not once
per tenant: ``megabatch.step_group`` stacks every tenant of a slot class
and advances the whole group in one fused device dispatch per round-chunk.
We build two identically-seeded services — ``megabatch=False`` (the
per-tenant Python loop) and ``megabatch=True`` — submit the SAME
precomputed update streams to both, and compare p50/p99 ``step`` wall
time at several tenant counts.

Both paths must produce bit-identical colorings per tenant (the megabatch
contract, asserted here every run), so the speedup is pure dispatch
amortization — never a quality trade.

The acceptance check of the megabatched service rides here: at ``T=16``
same-shape tenants the megabatched step must be >= 3x faster at p50 than
the per-tenant loop at equal update rate, with identical colorings.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Csv
from repro.core import coloring as col
from repro.dynamic import ColoringService, slot_key, state_to_csr
from repro.graphs import generators as gen
from repro.obs import metrics as obs_metrics

# Tenant counts per scale.  Every tenant-count is its own jit entry for the
# stacked path (the batch dim is part of the shape), so tiny keeps a single
# count — the acceptance one.
SCALES = {"tiny": (32,), "small": (8, 16, 32), "medium": (8, 16, 32, 64)}

# One slot class by construction: same generator family/size and the same
# explicit shape knobs for every tenant.  ``ell_cap=12`` sits BELOW the max
# degree of ER(256, deg 8) instances, so the ELL width lands at the padded
# cap for every seed instead of at each graph's own max degree; ``ovf_cap``
# is set above the largest observed spill so the overflow floor matches too.
N, DEG = 256, 8.0
OPTS = dict(seed=0, n_chunks=2, ell_cap=12, C=32, ovf_cap=256,
            delta_cap=64, frontier_frac=0.5)
BATCHES_PER_STEP = 4          # submit queue depth per tenant per step
K_INS, K_DEL = 16, 8          # edges per update batch
# Acceptance rides the largest common tenant count: dispatch amortization
# GROWS with tenants, so T=32 is where the contractually claimed >=3x is
# both most meaningful and most robust to machine noise.
ACCEPT_T, ACCEPT_SPEEDUP = 32, 3.0


def _service(n_tenants: int, megabatch: bool) -> ColoringService:
    svc = ColoringService(megabatch=megabatch, **OPTS)
    for i in range(n_tenants):
        svc.add_graph(f"g{i}", gen.erdos_renyi(N, DEG, seed=i))
    keys = {slot_key(svc.snapshot(f"g{i}")) for i in range(n_tenants)}
    assert len(keys) == 1, f"tenants split across slot classes: {keys}"
    return svc


def _streams(n_tenants: int, n_steps: int, seed: int = 7) -> list:
    """streams[step][tenant] = list of (inserts, deletes) batches."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_steps):
        per_t = []
        for _t in range(n_tenants):
            q = []
            for _b in range(BATCHES_PER_STEP):
                ins = rng.integers(0, N, (K_INS, 2), dtype=np.int32)
                ins = ins[ins[:, 0] != ins[:, 1]]
                dels = rng.integers(0, N, (K_DEL, 2), dtype=np.int32)
                q.append((ins, dels))
            per_t.append(q)
        out.append(per_t)
    return out


def _run_pair(n_tenants: int, n_steps: int, warmup: int):
    """Step both services through identical streams, interleaved per step
    (so machine-load drift hits both paths equally); returns the measured
    per-step wall times and the final services."""
    loop_svc = _service(n_tenants, megabatch=False)
    mega_svc = _service(n_tenants, megabatch=True)
    loop_ts, mega_ts = [], []
    for s, per_t in enumerate(_streams(n_tenants, n_steps + warmup)):
        for t in range(n_tenants):
            for ins, dels in per_t[t]:
                loop_svc.submit(f"g{t}", inserts=ins, deletes=dels)
                mega_svc.submit(f"g{t}", inserts=ins, deletes=dels)
        t0 = time.perf_counter()
        loop_svc.step()            # blocks on device sync internally
        t1 = time.perf_counter()
        mega_svc.step()
        t2 = time.perf_counter()
        if s >= warmup:
            loop_ts.append((t1 - t0) * 1e3)
            mega_ts.append((t2 - t1) * 1e3)
    return loop_ts, mega_ts, loop_svc, mega_svc


def main(scale: str = "small") -> None:
    if scale not in SCALES:
        raise SystemExit(f"unknown scale {scale!r}; known: {sorted(SCALES)}")
    # warmup must cover the wave-count shapes the measured steps hit, or a
    # multi-second jit compile lands inside a timed step and wrecks p99
    n_steps, warmup = 10, 3
    csv = Csv(["tenants", "n", "batches_per_step", "ins_per_batch",
               "dels_per_batch", "loop_p50_ms", "loop_p99_ms",
               "mega_p50_ms", "mega_p99_ms", "speedup_p50",
               "mega_batched", "mega_escaped", "mega_solo",
               "identical", "proper"])
    for n_tenants in SCALES[scale]:
        esc0 = obs_metrics.counter_value("service.mega", outcome="escaped")
        solo0 = obs_metrics.counter_value("service.mega", outcome="solo")
        bat0 = obs_metrics.counter_value("service.mega", outcome="batched")
        loop_ts, mega_ts, loop_svc, mega_svc = _run_pair(
            n_tenants, n_steps, warmup)

        # the megabatch contract: bit-identical to the per-tenant loop
        identical = all(
            np.array_equal(loop_svc.colors(f"g{i}"), mega_svc.colors(f"g{i}"))
            and loop_svc.version(f"g{i}") == mega_svc.version(f"g{i}")
            for i in range(n_tenants))
        proper = all(
            col.is_proper(state_to_csr(mega_svc.snapshot(f"g{i}")),
                          mega_svc.colors(f"g{i}"))
            for i in range(n_tenants))
        assert identical, "megabatched colorings diverged from loop path"

        loop_p50 = float(np.percentile(loop_ts, 50))
        mega_p50 = float(np.percentile(mega_ts, 50))
        speedup = loop_p50 / mega_p50 if mega_p50 else float("inf")
        csv.row(n_tenants, N, BATCHES_PER_STEP, K_INS, K_DEL,
                loop_p50, float(np.percentile(loop_ts, 99)),
                mega_p50, float(np.percentile(mega_ts, 99)),
                speedup,
                obs_metrics.counter_value("service.mega",
                                          outcome="batched") - bat0,
                obs_metrics.counter_value("service.mega",
                                          outcome="escaped") - esc0,
                obs_metrics.counter_value("service.mega",
                                          outcome="solo") - solo0,
                identical, proper,
                extra={"ms": mega_p50})
        if n_tenants == ACCEPT_T:
            ok = identical and proper and speedup >= ACCEPT_SPEEDUP
            print(f"# acceptance[T={ACCEPT_T}]: identical={identical} "
                  f"proper={proper} speedup_p50={speedup:.2f}x >= "
                  f"{ACCEPT_SPEEDUP:.0f}x -> {'PASS' if ok else 'FAIL'}",
                  flush=True)
            if not ok:
                raise SystemExit(
                    f"service megabatch acceptance failed at T={ACCEPT_T}: "
                    f"speedup {speedup:.2f}x")


if __name__ == "__main__":
    main()
