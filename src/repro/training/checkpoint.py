"""Fault-tolerant checkpointing: sharded-safe npz snapshots with atomic
rename, an async background writer, and **elastic restore** (a checkpoint
saved on one mesh restores onto any other — arrays are saved fully-replicated
logical values; the restoring launcher re-applies its own shardings).

Layout:
  <dir>/step_<N>/arrays.npz      flattened pytree leaves (key = path string)
  <dir>/step_<N>/meta.json       step, tree structure, data-iterator state, rng
  <dir>/LATEST                   text file with the newest complete step dir

Crash safety: writes go to ``step_<N>.tmp`` and are renamed only when fsynced
and complete, so a killed writer never corrupts LATEST.  Old steps are
garbage-collected keeping ``keep`` newest.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None,
         keep: int = 3) -> str:
    """Synchronous checkpoint write. Returns the final step directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _flatten_with_paths(tree)
    # device -> host; works for sharded arrays (gathers the logical value)
    host = {k: np.asarray(jax.device_get(v)) for k, v in leaves.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **host)
    meta = {"step": int(step), "keys": sorted(host.keys()),
            "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):                  # same step re-saved
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit
    _update_latest(ckpt_dir, final)
    _gc(ckpt_dir, keep)
    return final


def _update_latest(ckpt_dir: str, final: str) -> None:
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.rename(latest_tmp, os.path.join(ckpt_dir, "LATEST"))


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step_dir(ckpt_dir: str) -> Optional[str]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        name = f.read().strip()
    full = os.path.join(ckpt_dir, name)
    return full if os.path.isdir(full) else None


def restore(ckpt_dir: str, like: Any, shardings: Any = None):
    """Restore the newest checkpoint into the structure of ``like``.

    Elastic: ``shardings`` (same pytree structure, or None) re-shards each
    leaf onto the *current* mesh regardless of the saving mesh — the npz
    holds full logical arrays.  Returns (tree, step, extra) or None.
    """
    d = latest_step_dir(ckpt_dir)
    if d is None:
        return None
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    flat, tdef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(flat))
    for (path, leaf), sh in zip(flat, shard_leaves):
        key = jax.tree_util.keystr(path)
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"{key}: ckpt shape {arr.shape} != {leaf.shape}")
        if sh is not None:
            out.append(jax.device_put(arr.astype(leaf.dtype), sh))
        else:
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    tree = jax.tree_util.tree_unflatten(tdef, out)
    return tree, meta["step"], meta.get("extra", {})


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training (single in-flight snapshot).

    ``save`` blocks only for device->host transfer of the leaves (cheap,
    overlappable with the next step's compute on device) and hands the file
    I/O to a daemon thread.  A second save while one is in flight waits —
    backpressure instead of unbounded host memory growth.
    """

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        host = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten_with_paths(tree).items()}

        def _write():
            try:
                final = os.path.join(self.ckpt_dir, f"step_{step:08d}")
                tmp = final + ".tmp"
                os.makedirs(self.ckpt_dir, exist_ok=True)
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                np.savez(os.path.join(tmp, "arrays.npz"), **host)
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump({"step": int(step),
                               "keys": sorted(host.keys()),
                               "extra": extra or {}}, f)
                    f.flush()
                    os.fsync(f.fileno())
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                _update_latest(self.ckpt_dir, final)
                _gc(self.ckpt_dir, self.keep)
            except BaseException as e:   # surfaced at next save()/wait()
                self._err = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
