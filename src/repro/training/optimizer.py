"""Hand-rolled optimizers (no optax dependency): AdamW with cosine schedule,
global-norm clipping, and optional int8 error-feedback gradient compression
for the cross-``pod`` reduction (distributed-optimization trick; the
compressor is exact-on-average via error feedback, validated in tests).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: OptimizerConfig, step):
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_opt_state(params) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(cfg: OptimizerConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        update = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(state["mu"])
    flat_nu = jax.tree_util.tree_leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "step": step + 1}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}


# --------------------------------------------------------------------------
# int8 error-feedback gradient compression (cross-pod reduction trick)
# --------------------------------------------------------------------------

def compress_int8(g, err):
    """Quantize g+err to int8 with a per-tensor scale; returns
    (q, scale, new_err).  Error feedback keeps the scheme unbiased over
    steps (residual is re-added next step)."""
    x = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.abs(x).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_err = x - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, err_state, axis_name: str):
    """all-reduce int8-quantized grads over ``axis_name`` with error feedback.

    Used for the *pod* axis only (slow inter-pod links); intra-pod reductions
    stay full-precision.  Bytes on the pod links drop 4x (8 vs 32 bit).
    """
    def one(g, e):
        q, s, e2 = compress_int8(g, e)
        # sum int8 payloads in int32 (values bounded by 127 * n_pods)
        tot = jax.lax.psum(q.astype(jnp.int32), axis_name)
        smax = jax.lax.pmax(s, axis_name)  # shared scale upper bound
        return (tot.astype(jnp.float32) * smax).astype(g.dtype), e2

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_e = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return new_g, new_e
