"""Generic training loop: jit-compiled step with gradient accumulation,
periodic async checkpointing, deterministic restart, and (documented)
straggler handling for multi-host runs.

Fault-tolerance contract (DESIGN.md §4):
  * params/opt-state/data-iterator state checkpoint every ``ckpt_every``
    steps via the async writer (atomic rename; LATEST only moves when the
    snapshot is complete).
  * restart = ``run()`` with the same config: it restores LATEST, restores
    the data stream counter, and continues bitwise-identically (the stream
    is counter-based).
  * elasticity: checkpoints store full logical arrays; the restoring run
    re-shards onto whatever mesh it was launched with (training/elastic.py).
  * stragglers (real clusters): each step is a single XLA program — a slow
    host stalls the collective. The launcher wraps steps in a watchdog (see
    launch/train.py) and relaunches from LATEST on timeout; there is no
    partial-step state to lose by design (all mutation happens at the end of
    a committed step).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.training import checkpoint as ckpt
from repro.training.optimizer import (OptimizerConfig, adamw_update,
                                      init_opt_state)


@dataclasses.dataclass(frozen=True)
class TrainLoopConfig:
    total_steps: int = 200
    microbatches: int = 1            # gradient accumulation factor
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    keep_ckpts: int = 3


def make_train_step(loss_fn: Callable, opt_cfg: OptimizerConfig,
                    microbatches: int = 1, donate: bool = True):
    """loss_fn(params, batch) -> scalar.  Returns jitted
    step(params, opt_state, batch) -> (params, opt_state, metrics).

    With microbatches > 1 the batch's leading axis is split and gradients
    are accumulated in fp32 across a ``lax.scan`` (sequential microbatches —
    the standard memory/throughput trade)."""

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def micro(carry, mb):
                acc, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32),
                                   acc, g)
                return (acc, lsum + l), None

            mbs = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), _ = jax.lax.scan(micro, (zero, jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = lsum / microbatches
        params, opt_state, m = adamw_update(opt_cfg, params, grads, opt_state)
        m["loss"] = loss
        return params, opt_state, m

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


def run(loss_fn, params, stream, opt_cfg: OptimizerConfig,
        loop_cfg: TrainLoopConfig, to_device: Optional[Callable] = None,
        on_metrics: Optional[Callable] = None):
    """Drive training to ``total_steps`` with restart-from-LATEST support.

    Returns (params, opt_state, history list of metric dicts)."""
    opt_state = init_opt_state(params)
    start = 0
    writer = None
    if loop_cfg.ckpt_dir:
        writer = ckpt.AsyncCheckpointer(loop_cfg.ckpt_dir, loop_cfg.keep_ckpts)
        restored = ckpt.restore(loop_cfg.ckpt_dir,
                                {"params": params, "opt": opt_state})
        if restored is not None:
            tree, step0, extra = restored
            params, opt_state = tree["params"], tree["opt"]
            start = step0
            if "stream" in extra:
                stream.restore(extra["stream"])

    step_fn = make_train_step(loss_fn, opt_cfg, loop_cfg.microbatches)
    history = []
    t0 = time.perf_counter()
    for step in range(start, loop_cfg.total_steps):
        batch = next(stream)
        if to_device is not None:
            batch = to_device(batch)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % loop_cfg.log_every == 0 or step == start:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step + 1
            m["sec_per_step"] = (time.perf_counter() - t0) / max(step + 1 - start, 1)
            history.append(m)
            if on_metrics:
                on_metrics(m)
        if writer and (step + 1) % loop_cfg.ckpt_every == 0:
            writer.save(step + 1, {"params": params, "opt": opt_state},
                        extra={"stream": stream.state()})
    if writer:
        writer.save(loop_cfg.total_steps,
                    {"params": params, "opt": opt_state},
                    extra={"stream": stream.state()})
        writer.wait()
    return params, opt_state, history
