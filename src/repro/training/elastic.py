"""Elastic scaling: resume a run on a different mesh than it was saved from.

The checkpoint format (training/checkpoint.py) stores full logical arrays, so
elasticity reduces to re-computing shardings for the new mesh and
device_put-ing on restore.  This module provides the glue:

  * ``reshard_tree(tree, mesh, rules)`` — apply logical-axis rules
    (launch/sharding.py) to every leaf for the *current* mesh.
  * ``elastic_restore(ckpt_dir, like, mesh, rules)`` — restore + reshard in
    one call; mesh shape changes (e.g. 256 -> 128 chips after losing a pod
    slice, or 256 -> 512 after scale-up) need no checkpoint conversion.

Batch-size elasticity: global batch is ``per_device_batch * data_axis``; the
launcher recomputes per-device batch on restart, and the counter-based data
stream (data/pipeline.py) is batch-size-agnostic, so scaling the data axis
only changes throughput, not the sample sequence semantics.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.training import checkpoint as ckpt


def sharding_tree(tree: Any, mesh: Mesh, rules) -> Any:
    """NamedSharding for every leaf via ``rules(path, leaf) -> PartitionSpec``."""
    flat, tdef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        spec = rules(jax.tree_util.keystr(path), leaf)
        out.append(NamedSharding(mesh, spec if spec is not None else P()))
    return jax.tree_util.tree_unflatten(tdef, out)


def reshard_tree(tree: Any, mesh: Mesh, rules) -> Any:
    sh = sharding_tree(tree, mesh, rules)
    return jax.tree.map(jax.device_put, tree, sh)


def elastic_restore(ckpt_dir: str, like: Any, mesh: Optional[Mesh] = None,
                    rules=None):
    """Restore LATEST onto the current mesh. Returns (tree, step, extra) or
    None. With mesh/rules None, restores replicated (single-process runs)."""
    shardings = None
    if mesh is not None and rules is not None:
        shardings = sharding_tree(like, mesh, rules)
    return ckpt.restore(ckpt_dir, like, shardings)
