"""Process-local counters and histograms (DESIGN.md §12).

The decisions that used to be invisible — which kernel backend a dispatch
actually took, whether the ``twohop`` kernel silently fell back to the jnp
reference because the ELL table outgrew VMEM, how many cap-doubling retries
an engine burned, whether a ``ColoringService`` artifact query hit the
version memo — are counted here, always, because a host-side integer
increment is free next to a device dispatch.  Latency distributions
(service step time per tenant) land in fixed-reservoir histograms.

Naming convention (DESIGN.md §12): dotted ``subsystem.event`` names plus
sorted ``{key=value}`` labels, e.g.::

    kernels.dispatch{backend=jnp,kernel=twohop}
    kernels.fallback{kernel=twohop,reason=vmem}
    engine.cap_retry{algorithm=rsoc}
    service.memo{graph=mesh,kind=vertex_schedule,outcome=hit}
    service.step_ms{graph=mesh}            (histogram)

The registry is process-local and thread-safe; it is NOT a metrics *export*
system — ``snapshot()`` hands the current values to whatever sink the caller
wires up (tests assert on it directly, ``obs.export`` serializes it).
"""
from __future__ import annotations

import threading
from typing import Optional

_LOCK = threading.Lock()
_COUNTERS: dict[str, "Counter"] = {}
_HISTOGRAMS: dict[str, "Histogram"] = {}

# histograms keep at most this many observations (drop-oldest reservoir);
# service workloads observe one value per step, so this covers hours of
# traffic before any quantile degrades
HISTOGRAM_CAP = 4096


def qualified(name: str, **labels) -> str:
    """Canonical metric identity: ``name{k=v,...}`` with sorted labels."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic process-local counter."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with _LOCK:
            self._value += int(n)

    @property
    def value(self) -> int:
        return self._value

    def __repr__(self) -> str:
        return f"Counter({self.name}={self._value})"


class Histogram:
    """Bounded-reservoir histogram (drop-oldest) with exact quantiles."""

    __slots__ = ("name", "_values", "_count", "_total", "_max")

    def __init__(self, name: str):
        self.name = name
        self._values: list[float] = []
        self._count = 0
        self._total = 0.0
        self._max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        with _LOCK:
            self._count += 1
            self._total += v
            self._max = max(self._max, v)
            self._values.append(v)
            if len(self._values) > HISTOGRAM_CAP:
                del self._values[0]

    @property
    def count(self) -> int:
        return self._count

    def clear(self) -> None:
        """Forget every observation (count, total, max, reservoir) while
        keeping the instance registered — ``ColoringService.restore`` uses
        this so post-rollback latencies start a fresh distribution."""
        with _LOCK:
            self._values.clear()
            self._count = 0
            self._total = 0.0
            self._max = float("-inf")

    def percentile(self, q: float) -> Optional[float]:
        """Exact q-th percentile (0..100) over the retained reservoir."""
        with _LOCK:
            vals = sorted(self._values)
        if not vals:
            return None
        rank = (len(vals) - 1) * (q / 100.0)
        lo = int(rank)
        hi = min(lo + 1, len(vals) - 1)
        frac = rank - lo
        return vals[lo] * (1 - frac) + vals[hi] * frac

    def summary(self) -> dict:
        with _LOCK:
            n, tot, mx = self._count, self._total, self._max
        return {"count": n,
                "mean": (tot / n) if n else None,
                "max": mx if n else None,
                "p50": self.percentile(50),
                "p99": self.percentile(99)}

    def __repr__(self) -> str:
        return f"Histogram({self.name}, n={self._count})"


def counter(name: str, **labels) -> Counter:
    """The counter registered under ``qualified(name, **labels)``
    (created on first use)."""
    key = qualified(name, **labels)
    with _LOCK:
        c = _COUNTERS.get(key)
        if c is None:
            c = _COUNTERS[key] = Counter(key)
    return c


def histogram(name: str, **labels) -> Histogram:
    key = qualified(name, **labels)
    with _LOCK:
        h = _HISTOGRAMS.get(key)
        if h is None:
            h = _HISTOGRAMS[key] = Histogram(key)
    return h


def counter_value(name: str, **labels) -> int:
    """Current value of a counter, 0 if it was never incremented (reading
    must not create registry entries)."""
    c = _COUNTERS.get(qualified(name, **labels))
    return c.value if c is not None else 0


def counters_matching(prefix: str) -> dict[str, int]:
    """``{qualified_name: value}`` for every counter whose name starts with
    ``prefix`` (label-blind: matches the part before any ``{``)."""
    with _LOCK:
        items = list(_COUNTERS.items())
    return {k: c.value for k, c in items
            if k.split("{", 1)[0].startswith(prefix)}


def total_matching(prefix: str) -> int:
    """Sum of every counter under ``prefix`` — e.g.
    ``total_matching("kernels.fallback")`` is the process-wide kernel
    fallback count regardless of which kernel tripped it."""
    return sum(counters_matching(prefix).values())


def snapshot() -> dict:
    """Point-in-time view of every metric: ``{"counters": {name: int},
    "histograms": {name: summary_dict}}``."""
    with _LOCK:
        counters_ = {k: c.value for k, c in _COUNTERS.items()}
        hists = list(_HISTOGRAMS.items())
    return {"counters": counters_,
            "histograms": {k: h.summary() for k, h in hists}}


def remove(name: str, **labels) -> None:
    """Drop one metric identity (counter and/or histogram) from the
    registry.  ``ColoringService.remove_graph`` uses this so a tenant
    re-added under the same name starts with fresh latency percentiles
    instead of inheriting the departed tenant's (DESIGN.md §13); absent
    identities are a no-op."""
    key = qualified(name, **labels)
    with _LOCK:
        _COUNTERS.pop(key, None)
        _HISTOGRAMS.pop(key, None)


def reset() -> None:
    """Drop every metric (tests; a long-lived process never needs this)."""
    with _LOCK:
        _COUNTERS.clear()
        _HISTOGRAMS.clear()
