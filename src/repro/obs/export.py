"""Trace/metrics export: JSON-lines dumps + ``jax.profiler`` annotations.

``write_jsonl`` serializes ``RunTrace`` artifacts one-per-line so trajectory
dumps concatenate and stream (CI uploads ``TRACE_<section>.jsonl`` from
bench-smoke next to the ``BENCH_*.json`` rows; both come from the same
events).  ``annotate`` is the device-profile hook: a named
``jax.profiler.TraceAnnotation`` scope, so when someone captures an XLA
profile the round-0 / repair / detect phases carry the same names the
``RunTrace`` phases do — and a no-op context manager when the profiler is
unavailable, because observability must never be the thing that crashes.
"""
from __future__ import annotations

import contextlib
import json
from typing import Iterable, Union

from repro.obs.trace import RunTrace
from repro.obs import metrics as _metrics


def annotate(name: str):
    """Named ``jax.profiler`` trace-annotation scope (no-op without one)."""
    try:
        from jax.profiler import TraceAnnotation
        return TraceAnnotation(name)
    except Exception:   # profiler backend absent / interface drifted
        return contextlib.nullcontext()


def trace_to_dict(t: Union[RunTrace, dict]) -> dict:
    return t.asdict() if isinstance(t, RunTrace) else dict(t)


def write_jsonl(traces: Iterable[Union[RunTrace, dict]], path: str) -> int:
    """Write traces as JSON lines; returns the number of lines written."""
    n = 0
    with open(path, "w") as f:
        for t in traces:
            json.dump(trace_to_dict(t), f, default=str)
            f.write("\n")
            n += 1
    return n


def read_jsonl(path: str) -> list[dict]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def metrics_snapshot() -> dict:
    """The process-local metrics registry, JSON-ready (re-exported so sinks
    import one module)."""
    return _metrics.snapshot()
