"""Structured run tracing: the ``RunTrace`` artifact (DESIGN.md §12).

The paper's whole argument is a set of runtime trajectories — conflicts per
round, repair rounds, colors per iteration (Figs. 3–6) — and this module is
how an ``api.color`` call produces one without anyone editing engine
internals.  Three switches turn tracing on, any one suffices:

  * ``ColoringSpec.trace=True``     — trace this one call;
  * ``with obs.trace() as tc: ...`` — trace every call in the scope and
                                       collect the artifacts on ``tc``;
  * ``REPRO_TRACE=1`` in the env    — force-trace the whole process (CI).

Zero overhead when off, by construction rather than by measurement: the
per-round conflict counts already ride the engines' ``while_loop`` carry
(they always did — ``ColoringResult.conflicts_per_round``), host wall
timers only bracket jit boundaries, and the one genuinely new device-side
collection (per-round frontier sizes) is gated on the *static*
``PassContext.trace`` flag, so a ``trace=False`` call compiles the exact
program it compiled before this module existed — same jit cache key, same
HLO, same allocations (``tests/test_obs.py`` pins the loop output arity).

A ``RunTrace`` is assembled host-side when the engine returns: round events
from the carry-resident conflict/frontier traces, phase events from the
wall timers the engines already pass through (``prepare`` / ``solve`` per
cap-retry attempt / ``serial_repair`` …), retry and cap data from the
result.  Engines touch this module through exactly two hooks —
``current_tracer()`` (None when off) and ``RunTracer.phase`` — so a new
engine gets traced by doing nothing at all, and gets *phase-resolved*
tracing with two lines.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
import time
from typing import Optional

import numpy as np


def _env_forced() -> bool:
    return os.environ.get("REPRO_TRACE", "") not in ("", "0", "false", "off")


# --------------------------------------------------------------------------
# the artifact
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RoundEvent:
    """One repair round of the engine's while-loop."""

    round: int            # 0-based repair round index
    conflicts: int        # defects detected (== conflicts_per_round[round])
    frontier: int = -1    # |U| at round start (-1: engine does not collect)
    compacted: Optional[bool] = None   # frontier-compacted engines only:
    #                                    did this round take the small pass?


@dataclasses.dataclass(frozen=True)
class PhaseEvent:
    """One host-timed phase (the timer brackets a jit boundary: the engine
    blocks on the phase's outputs before the timer stops)."""

    name: str             # prepare | solve | serial_repair | ...
    wall_s: float
    meta: dict = dataclasses.field(default_factory=dict)   # e.g. C, attempt


@dataclasses.dataclass(frozen=True)
class RunTrace:
    """Typed trajectory of one ``api.color`` run (DESIGN.md §12 schema)."""

    spec_key: str                 # resolved ColoringSpec identity
    engine: str                   # "algorithm/distance/mode/backend"
    n_vertices: int
    n_rounds: int
    rounds: tuple                 # tuple[RoundEvent, ...]
    phases: tuple                 # tuple[PhaseEvent, ...]
    retries: int                  # cap-doubling re-runs
    final_C: int
    gather_passes: int
    total_conflicts: int
    n_colors: int
    truncated: bool               # rounds beyond MAX_ROUNDS_TRACE collapsed
    wall_s: float                 # whole engine call, host-side

    @property
    def conflicts_per_round(self) -> np.ndarray:
        """Per-round conflict counts — exactly
        ``ColoringResult.conflicts_per_round`` of the run this traced."""
        return np.asarray([e.conflicts for e in self.rounds], np.int64)

    def phase_wall_s(self, name: str) -> float:
        return sum(p.wall_s for p in self.phases if p.name == name)

    def summary_line(self) -> str:
        """One-line human summary (the quickstart prints this)."""
        conf = ">".join(str(e.conflicts) for e in self.rounds[:8])
        if len(self.rounds) > 8:
            conf += ">…"
        trunc = " TRUNCATED" if self.truncated else ""
        return (f"trace[{self.engine}] n={self.n_vertices} "
                f"rounds={self.n_rounds}{trunc} conflicts={conf or '0'} "
                f"colors={self.n_colors} C={self.final_C} "
                f"retries={self.retries} passes={self.gather_passes} "
                f"wall={self.wall_s * 1e3:.1f}ms")

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


# --------------------------------------------------------------------------
# live tracer (one per engine run) + collector (one per trace() scope)
# --------------------------------------------------------------------------

_TLS = threading.local()


class RunTracer:
    """Mutable scratchpad an engine run writes into; ``finish`` freezes it
    into a ``RunTrace``.  Engines reach it via ``current_tracer()``."""

    def __init__(self):
        self._phases: list[PhaseEvent] = []
        self._frontier: Optional[np.ndarray] = None
        self._compact_cap: Optional[int] = None
        self._t0 = time.perf_counter()

    @contextlib.contextmanager
    def phase(self, name: str, **meta):
        """Wall-time one engine phase.  The body must block on its device
        outputs (``jax.block_until_ready`` / host conversion) for the timer
        to mean anything; the standard call sites do.  Also opens a
        ``jax.profiler`` annotation scope so device profiles show the same
        phase names (``obs.export.annotate``)."""
        from repro.obs.export import annotate
        t0 = time.perf_counter()
        with annotate(f"repro.{name}"):
            yield
        self._phases.append(PhaseEvent(name=name,
                                       wall_s=time.perf_counter() - t0,
                                       meta=dict(meta)))

    def set_frontier_trace(self, frontier, cap: Optional[int] = None) -> None:
        """Per-round |U| counts from the loop carry (engines that collect
        them under the static ``ctx.trace`` flag).  ``cap``: the compacted
        frontier capacity, when the engine has one — lets the round events
        say whether the round took the compacted or the full-width pass."""
        self._frontier = np.asarray(frontier)
        self._compact_cap = cap

    def finish(self, result, spec, engine_key: str,
               n_vertices: int) -> RunTrace:
        conf = np.asarray(result.conflicts_per_round).reshape(-1)
        rounds = []
        for i, c in enumerate(conf.tolist()):
            fr_sz = -1
            compacted = None
            if self._frontier is not None and i < len(self._frontier):
                fr_sz = int(self._frontier[i])
                if self._compact_cap is not None:
                    compacted = fr_sz <= self._compact_cap
            rounds.append(RoundEvent(round=i, conflicts=int(c),
                                     frontier=fr_sz, compacted=compacted))
        return RunTrace(
            spec_key=spec.spec_key(), engine=engine_key,
            n_vertices=int(n_vertices), n_rounds=int(result.n_rounds),
            rounds=tuple(rounds), phases=tuple(self._phases),
            retries=int(result.retries), final_C=int(result.final_C),
            gather_passes=int(result.gather_passes),
            total_conflicts=int(result.total_conflicts),
            n_colors=int(result.n_colors),
            truncated=bool(result.trace_truncated),
            wall_s=time.perf_counter() - self._t0)


class TraceCollector:
    """Accumulates the ``RunTrace`` of every ``api.color`` call in a
    ``trace()`` scope."""

    def __init__(self):
        self.traces: list[RunTrace] = []

    def append(self, t: RunTrace) -> None:
        self.traces.append(t)

    def __len__(self) -> int:
        return len(self.traces)


def current_tracer() -> Optional[RunTracer]:
    """The tracer of the engine run in flight on this thread, or None —
    THE switch every engine-side hook checks (None => do nothing extra)."""
    return getattr(_TLS, "tracer", None)


def phase(name: str, **meta):
    """``current_tracer().phase(...)`` or a no-op scope — the one-line way
    for an engine to mark a phase without checking for a tracer first."""
    t = current_tracer()
    return t.phase(name, **meta) if t is not None else contextlib.nullcontext()


def active_collector() -> Optional[TraceCollector]:
    return getattr(_TLS, "collector", None)


def tracing_enabled(spec_trace: bool = False) -> bool:
    """Should the next ``api.color`` call be traced?"""
    return bool(spec_trace) or active_collector() is not None or _env_forced()


@contextlib.contextmanager
def run_tracer():
    """Install a fresh ``RunTracer`` for one engine run (``api.color``'s
    internal scope — engines never call this)."""
    prev = getattr(_TLS, "tracer", None)
    tracer = RunTracer()
    _TLS.tracer = tracer
    try:
        yield tracer
    finally:
        _TLS.tracer = prev


@contextlib.contextmanager
def trace():
    """Trace every ``api.color`` call in the scope and collect the
    artifacts::

        with obs.trace() as tc:
            api.color(g)                      # traced, spec untouched
        print(tc.traces[0].summary_line())
    """
    prev = getattr(_TLS, "collector", None)
    collector = TraceCollector()
    _TLS.collector = collector
    try:
        yield collector
    finally:
        _TLS.collector = prev


def collect(t: RunTrace) -> None:
    """Hand a finished trace to the active collector, if any."""
    c = active_collector()
    if c is not None:
        c.append(t)
