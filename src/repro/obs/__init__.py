"""``repro.obs`` — tracing + metrics with zero device overhead when off
(DESIGN.md §12).

Three pieces:

  * ``obs.trace``   — the ``RunTrace`` artifact and the ``trace()`` scope
                      (``ColoringResult.trace`` when ``ColoringSpec.trace``
                      or a ``trace()`` scope or ``REPRO_TRACE=1`` is on);
  * ``obs.metrics`` — always-on process-local counters/histograms (kernel
                      dispatch/fallback decisions, engine cap-retries,
                      service memo hit/miss and step latency);
  * ``obs.export``  — JSON-lines trace dumps + ``jax.profiler`` annotation
                      scopes.

This package imports no engine code: engines import *it*, through exactly
two hooks (``current_tracer()`` and the static ``PassContext.trace`` flag),
which is what keeps the when-off path bit-identical to a build without the
subsystem.
"""
from repro.obs import export, metrics
from repro.obs.trace import (PhaseEvent, RoundEvent, RunTrace, TraceCollector,
                             active_collector, collect, current_tracer, phase,
                             run_tracer, trace, tracing_enabled)

__all__ = [
    "PhaseEvent",
    "RoundEvent",
    "RunTrace",
    "TraceCollector",
    "active_collector",
    "collect",
    "current_tracer",
    "export",
    "metrics",
    "phase",
    "run_tracer",
    "trace",
    "tracing_enabled",
]
