"""qwen2-moe-a2.7b [moe] 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60e top-4 — 4 shared + 60 routed top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

60 experts are padded to 64 for even model-axis sharding (padding experts
are masked out of routing — they receive no tokens and no probability mass).
"""
from repro.configs.common import ArchDef
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig


def make_full():
    moe = MoEConfig(n_experts=60, top_k=4, d_ff_expert=1408, n_shared=4,
                    d_ff_shared=5632, n_experts_padded=64)
    return TransformerConfig(
        name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16,
        n_kv_heads=16, head_dim=128, d_ff=1408, vocab=151936,
        attn_type="gqa", qk_norm=False, moe=moe)


def make_smoke():
    moe = MoEConfig(n_experts=6, top_k=2, d_ff_expert=32, n_shared=2,
                    d_ff_shared=64, n_experts_padded=8,
                    capacity_factor=8.0)   # no-drop for decode-vs-forward
    return TransformerConfig(
        name="qwen2-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=32, vocab=512,
        attn_type="gqa", moe=moe, dtype="float32", remat=False,
        chunk_q=64, chunk_k=64)


ARCH = ArchDef(name="qwen2-moe-a2.7b", family="lm", make_full=make_full,
               make_smoke=make_smoke,
               notes="60-routed(top-4)+4-shared-expert MoE LM")
