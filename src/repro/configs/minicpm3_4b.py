"""minicpm3-4b [dense] 62L d_model=2560 40H d_ff=6400 vocab=73448 — MLA.
[hf:openbmb/MiniCPM3-4B; hf]"""
from repro.configs.common import ArchDef
from repro.models.mla import MLAConfig
from repro.models.transformer import TransformerConfig


def make_full():
    mla = MLAConfig(d_model=2560, n_heads=40, q_lora_rank=768,
                    kv_lora_rank=256, qk_nope_dim=64, qk_rope_dim=32,
                    v_head_dim=64, rope_theta=10_000.0)
    return TransformerConfig(
        name="minicpm3-4b", n_layers=62, d_model=2560, n_heads=40,
        n_kv_heads=40, head_dim=64, d_ff=6400, vocab=73448,
        attn_type="mla", mla=mla)


def make_smoke():
    mla = MLAConfig(d_model=64, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
                    qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8)
    return TransformerConfig(
        name="minicpm3-4b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=8, d_ff=128, vocab=512,
        attn_type="mla", mla=mla, dtype="float32", remat=False,
        chunk_q=64, chunk_k=64)


ARCH = ArchDef(name="minicpm3-4b", family="lm", make_full=make_full,
               make_smoke=make_smoke,
               notes="MLA (latent-compressed KV) dense LM")
