"""dcn-v2 [recsys] n_dense=13 n_sparse=26 embed_dim=16 n_cross_layers=3
mlp=1024-1024-512 interaction=cross.  [arXiv:2008.13535; paper]

Embedding tables default to 1M rows per field (criteo-class); the lookup is
the hot path and tables are row-sharded over the model axis."""
from repro.configs.common import ArchDef
from repro.models.recsys import DCNv2Config


def make_full():
    return DCNv2Config(n_dense=13, n_sparse=26, embed_dim=16,
                       vocab_sizes=tuple([1_000_000] * 26),
                       n_cross_layers=3, mlp_dims=(1024, 1024, 512),
                       cross_rank=0, max_hots=1)


def make_smoke():
    return DCNv2Config(n_dense=13, n_sparse=6, embed_dim=8,
                       vocab_sizes=tuple([1000] * 6),
                       n_cross_layers=2, mlp_dims=(32, 16), max_hots=2)


ARCH = ArchDef(name="dcn-v2", family="recsys", make_full=make_full,
               make_smoke=make_smoke,
               notes="deep&cross v2 CTR ranker with EmbeddingBag substrate")
