"""Architecture registry: ``--arch <id>`` -> ArchDef."""
from repro.configs import (dcn_v2, gat_cora, gatedgcn, meshgraphnet,
                           minicpm3_4b, nequip, phi35_moe, qwen2_moe,
                           qwen3_1_7b, qwen3_32b)
from repro.configs.common import (ArchDef, FAMILY_SHAPES, GNN_SHAPES,
                                  LM_SHAPES, RECSYS_SHAPES, shapes_for)

ARCHS = {m.ARCH.name: m.ARCH for m in (
    qwen3_1_7b, minicpm3_4b, qwen3_32b, phi35_moe, qwen2_moe,
    gat_cora, meshgraphnet, gatedgcn, nequip, dcn_v2)}


def get(name: str) -> ArchDef:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def all_cells():
    """Every assigned (arch, shape) pair — 40 cells."""
    return [(a.name, s) for a in ARCHS.values()
            for s in shapes_for(a.family)]
