"""nequip [gnn] n_layers=5 d_hidden=32 l_max=2 n_rbf=8 cutoff=5
equivariance=E(3)-tensor-product.  [arXiv:2101.03164; paper]

Non-molecular shapes (cora-like / ogb) feed node features as l=0 scalars via
``d_scalar_in``; positions are synthesized (DESIGN.md §6)."""
from repro.configs.common import ArchDef
from repro.models.equivariant import NequIPConfig


def make_full(d_in: int = 0, n_classes: int = 0):
    return NequIPConfig(n_layers=5, channels=32, l_max=2, n_rbf=8,
                        cutoff=5.0, n_species=16, d_scalar_in=d_in)


def make_smoke():
    return NequIPConfig(n_layers=2, channels=8, l_max=2, n_rbf=4, cutoff=5.0,
                        n_species=4)


ARCH = ArchDef(name="nequip", family="gnn", make_full=make_full,
               make_smoke=make_smoke,
               notes="E(3)-equivariant tensor-product potential",
               extras={"model": "nequip"})
