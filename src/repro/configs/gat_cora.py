"""gat-cora [gnn] n_layers=2 d_hidden=8 n_heads=8 aggregator=attn.
[arXiv:1710.10903; paper]  Feature/class dims come from each shape."""
from repro.configs.common import ArchDef
from repro.models.gnn import GATConfig


def make_full(d_in: int = 1433, n_classes: int = 7):
    return GATConfig(n_layers=2, d_hidden=8, n_heads=8, d_in=d_in,
                     n_classes=n_classes)


def make_smoke():
    return GATConfig(n_layers=2, d_hidden=4, n_heads=2, d_in=16, n_classes=3)


ARCH = ArchDef(name="gat-cora", family="gnn", make_full=make_full,
               make_smoke=make_smoke, notes="graph attention (SDDMM+softmax)",
               extras={"model": "gat"})
