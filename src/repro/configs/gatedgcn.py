"""gatedgcn [gnn] n_layers=16 d_hidden=70 aggregator=gated.
[arXiv:2003.00982; paper]"""
from repro.configs.common import ArchDef
from repro.models.gnn import GatedGCNConfig


def make_full(d_in: int = 1433, n_classes: int = 7):
    return GatedGCNConfig(n_layers=16, d_hidden=70, d_in=d_in,
                          d_out=n_classes)


def make_smoke():
    return GatedGCNConfig(n_layers=2, d_hidden=8, d_in=16, d_out=3)


ARCH = ArchDef(name="gatedgcn", family="gnn", make_full=make_full,
               make_smoke=make_smoke, notes="edge-gated graph convolution",
               extras={"model": "gatedgcn"})
