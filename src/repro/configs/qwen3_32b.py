"""qwen3-32b [dense] 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B family; hf]"""
from repro.configs.common import ArchDef
from repro.models.transformer import TransformerConfig


def make_full():
    return TransformerConfig(
        name="qwen3-32b", n_layers=64, d_model=5120, n_heads=64,
        n_kv_heads=8, head_dim=80, d_ff=25600, vocab=151936,
        attn_type="gqa", qk_norm=True, rope_theta=1_000_000.0)


def make_smoke():
    return TransformerConfig(
        name="qwen3-32b-smoke", n_layers=2, d_model=96, n_heads=6,
        n_kv_heads=2, head_dim=16, d_ff=256, vocab=512,
        attn_type="gqa", qk_norm=True, dtype="float32", remat=False,
        chunk_q=64, chunk_k=64)


ARCH = ArchDef(name="qwen3-32b", family="lm", make_full=make_full,
               make_smoke=make_smoke, notes="large dense GQA + qk_norm LM")
