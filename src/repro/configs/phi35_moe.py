"""phi3.5-moe-42b-a6.6b [moe] 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16e top-2.  [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.configs.common import ArchDef
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig


def make_full():
    moe = MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400)
    return TransformerConfig(
        name="phi3.5-moe-42b-a6.6b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, head_dim=128, d_ff=6400, vocab=32064,
        attn_type="gqa", qk_norm=False, moe=moe)


def make_smoke():
    # capacity 8x: smoke tests compare decode vs full-forward, so no tokens
    # may drop (GShard drop semantics are batch-composition-dependent)
    moe = MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                    capacity_factor=8.0)
    return TransformerConfig(
        name="phi3.5-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab=512,
        attn_type="gqa", moe=moe, dtype="float32", remat=False,
        chunk_q=64, chunk_k=64)


ARCH = ArchDef(name="phi3.5-moe-42b-a6.6b", family="lm", make_full=make_full,
               make_smoke=make_smoke, notes="16-expert top-2 MoE LM")
