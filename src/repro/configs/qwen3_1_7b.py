"""qwen3-1.7b [dense] 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B family; hf]"""
from repro.configs.common import ArchDef
from repro.models.transformer import TransformerConfig


def make_full():
    return TransformerConfig(
        name="qwen3-1.7b", n_layers=28, d_model=2048, n_heads=16,
        n_kv_heads=8, head_dim=128, d_ff=6144, vocab=151936,
        attn_type="gqa", qk_norm=True, rope_theta=1_000_000.0)


def make_smoke():
    return TransformerConfig(
        name="qwen3-1.7b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
        attn_type="gqa", qk_norm=True, dtype="float32", remat=False,
        chunk_q=64, chunk_k=64)


ARCH = ArchDef(name="qwen3-1.7b", family="lm", make_full=make_full,
               make_smoke=make_smoke, notes="GQA + qk_norm dense LM")
