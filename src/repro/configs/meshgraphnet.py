"""meshgraphnet [gnn] n_layers=15 d_hidden=128 aggregator=sum mlp_layers=2.
[arXiv:2010.03409]  Edge features are synthesized (d_edge_in=4) for shapes
without native edge attributes."""
from repro.configs.common import ArchDef
from repro.models.gnn import MGNConfig


def make_full(d_in: int = 1433, n_classes: int = 7):
    return MGNConfig(n_layers=15, d_hidden=128, mlp_layers=2, d_in=d_in,
                     d_edge_in=4, d_out=n_classes)


def make_smoke():
    return MGNConfig(n_layers=2, d_hidden=16, mlp_layers=2, d_in=8,
                     d_edge_in=4, d_out=3)


ARCH = ArchDef(name="meshgraphnet", family="gnn", make_full=make_full,
               make_smoke=make_smoke,
               notes="encode-process-decode mesh GNN with edge state",
               extras={"model": "mgn"})
