"""Config substrate: architecture definitions + per-family shape tables.

Every assigned architecture is a module defining ``ARCH = ArchDef(...)``;
the registry (configs/__init__.py) maps ``--arch <id>`` to it.  Full configs
are exercised only through the dry-run (ShapeDtypeStruct, no allocation);
smoke configs are small enough for a real CPU forward/train step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional


@dataclasses.dataclass(frozen=True)
class ArchDef:
    name: str
    family: str                     # lm | gnn | recsys
    make_full: Callable[[], Any]    # full published config
    make_smoke: Callable[[], Any]   # reduced same-family config
    notes: str = ""
    # family-specific extras (gnn: feature dims per shape; lm: none)
    extras: dict = dataclasses.field(default_factory=dict)


# --------------------------------------------------------------------------
# assigned input-shape sets (verbatim from the assignment)
# --------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k":    {"kind": "train",   "seq_len": 4096,    "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq_len": 32768,   "batch": 32},
    "decode_32k":  {"kind": "decode",  "seq_len": 32768,   "batch": 128},
    "long_500k":   {"kind": "decode",  "seq_len": 524288,  "batch": 1},
}

GNN_SHAPES = {
    "full_graph_sm": {"kind": "train", "mode": "full", "n_nodes": 2_708,
                      "n_edges": 10_556, "d_feat": 1_433, "n_classes": 7},
    "minibatch_lg":  {"kind": "train", "mode": "sampled", "n_nodes": 232_965,
                      "n_edges": 114_615_892, "batch_nodes": 1_024,
                      "fanouts": (15, 10), "d_feat": 602, "n_classes": 41},
    "ogb_products":  {"kind": "train", "mode": "full", "n_nodes": 2_449_029,
                      "n_edges": 61_859_140, "d_feat": 100, "n_classes": 47},
    "molecule":      {"kind": "train", "mode": "batched", "n_nodes": 30,
                      "n_edges": 64, "batch": 128, "d_feat": 16,
                      "n_classes": 8},
}

RECSYS_SHAPES = {
    "train_batch":    {"kind": "train",     "batch": 65_536},
    "serve_p99":      {"kind": "serve",     "batch": 512},
    "serve_bulk":     {"kind": "serve",     "batch": 262_144},
    "retrieval_cand": {"kind": "retrieval", "batch": 1,
                       "n_candidates": 1_000_000},
}

FAMILY_SHAPES = {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES}


def shapes_for(family: str) -> dict:
    return FAMILY_SHAPES[family]
