"""Engine registry behind ``repro.api.color`` (DESIGN.md §11).

This is a deliberately leaf module: it imports no engine code, so the engine
modules (``core/coloring.py``, ``core/frontier.py``, ``core/distance2.py``,
``core/distributed.py``, ``dynamic/incremental.py``) can decorate their
adapters with ``@register_engine(...)`` without creating an import cycle with
``repro.api`` (which imports all of them to populate the registry).

An engine is keyed by the four spec axes that select an implementation:

    (algorithm, distance, mode, backend)

and is a callable ``engine(g, spec, **engine_kwargs) -> ColoringResult``
where ``spec`` is a ``repro.api.ColoringSpec`` (duck-typed here — attribute
access only, so this module never needs the class).  New engines (distance-d,
star/acyclic, new backends) are new registry entries, not new public
functions.

The deprecation machinery for the legacy ``color_*`` shims also lives here
(shared by every engine module): each shim warns exactly once per process
and then routes through ``repro.api.color`` so its output is bit-identical
to the spec path by construction.
"""
from __future__ import annotations

from typing import Callable, Iterable
import warnings

EngineKey = tuple[str, int, str, str]   # (algorithm, distance, mode, backend)

_ENGINES: dict[EngineKey, Callable] = {}


def register_engine(algorithm: str, *, distance: int = 1,
                    mode: str = "static", backend: str = "local",
                    replaces: str | None = None):
    """Class a callable ``fn(g, spec, **kw) -> ColoringResult`` under a spec
    combo.  ``replaces`` names the pre-registry public entry point the engine
    subsumes (documentation + the migration table in DESIGN.md §11)."""
    key: EngineKey = (algorithm, int(distance), mode, backend)

    def deco(fn: Callable) -> Callable:
        if key in _ENGINES:
            raise ValueError(f"duplicate engine registration for {key}")
        _ENGINES[key] = fn
        fn.engine_key = key
        fn.replaces = replaces
        return fn

    return deco


def has_engine(algorithm: str, distance: int, mode: str, backend: str) -> bool:
    return (algorithm, int(distance), mode, backend) in _ENGINES


def get_engine(algorithm: str, distance: int, mode: str,
               backend: str) -> Callable:
    key: EngineKey = (algorithm, int(distance), mode, backend)
    try:
        return _ENGINES[key]
    except KeyError:
        near = nearest_key(key)
        raise ValueError(
            f"no engine registered for algorithm={algorithm!r}, "
            f"distance={distance}, mode={mode!r}, backend={backend!r}; "
            f"nearest supported spec: {format_key(near)} "
            f"(full matrix: repro.api.supported_specs())") from None


def engine_keys() -> list[EngineKey]:
    """All registered combos, sorted (the support matrix)."""
    return sorted(_ENGINES)


def engine_items() -> list[tuple[EngineKey, Callable]]:
    return [(k, _ENGINES[k]) for k in engine_keys()]


def nearest_key(key: EngineKey) -> EngineKey:
    """The registered combo closest to ``key`` — used by
    ``ColoringSpec.validate`` to make rejections actionable.

    Axes are weighted mode > distance > backend > algorithm: the mode is the
    *task* (a user asking for incremental coloring under the wrong algorithm
    wants the algorithm that supports it, not a different task), while the
    algorithm is the most fungible choice.  Deterministic: ties break toward
    the lexicographically first key.
    """
    if not _ENGINES:
        raise RuntimeError("engine registry is empty (import repro.api)")
    algorithm, distance, mode, backend = key

    def score(k: EngineKey) -> int:
        return ((k[2] == mode) * 8 + (k[1] == distance) * 4
                + (k[3] == backend) * 2 + (k[0] == algorithm) * 1)

    return max(engine_keys(), key=score)


def format_key(key: EngineKey) -> str:
    a, d, m, b = key
    return (f"algorithm={a!r}, distance={d}, mode={m!r}, backend={b!r}")


# --------------------------------------------------------------------------
# legacy-shim support: warn once per entry point, then use the front door
# --------------------------------------------------------------------------

_DEPRECATION_SEEN: set[str] = set()


def warn_legacy(name: str, hint: str, stacklevel: int = 2) -> None:
    """DeprecationWarning for legacy entry point ``name``, exactly once per
    process (tests reset with ``reset_legacy_warnings``)."""
    if name in _DEPRECATION_SEEN:
        return
    _DEPRECATION_SEEN.add(name)
    warnings.warn(
        f"{name}() is deprecated; call repro.api.color(g, {hint}) instead "
        f"(see DESIGN.md §11 for the migration table)",
        DeprecationWarning, stacklevel=stacklevel + 1)


def reset_legacy_warnings() -> None:
    _DEPRECATION_SEEN.clear()


def legacy_entry(name: str, hint: str, g, **kwargs):
    """Body of every ``color_*`` deprecation shim: warn once, then route
    through ``repro.api.color`` so legacy calls stay bit-identical to the
    spec path by construction."""
    # stacklevel 3: warnings.warn <- warn_legacy <- legacy_entry <- shim,
    # attributing the warning to the SHIM'S CALLER so the default
    # `default::DeprecationWarning:__main__` filter surfaces it in scripts
    warn_legacy(name, hint, stacklevel=3)
    from repro import api   # call-time import: api imports the engine modules
    return api.color(g, **kwargs)
