"""Coloring-derived execution schedules (the paper's motivating use-case).

A graph coloring partitions work-items into independent sets; here we build
the schedules our substrates consume:

  * ``edge_color_by_dst`` — color edges such that no two edges sharing a
    destination share a color (exact greedy on the dst-bucket rank).  Each
    color class is then a conflict-free scatter: used by
    ``models.gnn.colored_segment_sum`` for deterministic aggregation.
  * ``vertex_schedule`` — order vertices color-by-color (independent sets)
    for safe parallel execution of vertex kernels (PRAgMaTIc-style mesh
    adaptivity, the paper's own application).
"""
from __future__ import annotations

import numpy as np

from repro.core import coloring as col
from repro.graphs.csr import CSRGraph


def edge_color_by_dst(src: np.ndarray, dst: np.ndarray, n_nodes: int):
    """Color edges s.t. edges sharing a dst get distinct colors.

    Exact and linear-time: the k-th edge of a dst bucket gets color k (the
    conflict graph between same-dst edges is a clique; rank = optimal).
    Returns (edge_colors (E,), n_colors)."""
    order = np.argsort(dst, kind="stable")
    ranks = np.zeros(len(dst), np.int32)
    prev, r = -1, 0
    for idx in order:
        if dst[idx] != prev:
            prev, r = dst[idx], 0
        ranks[idx] = r
        r += 1
    n_colors = int(ranks.max()) + 1 if len(ranks) else 1
    return ranks, n_colors


def vertex_schedule(g: CSRGraph, algorithm: str = "rsoc", seed: int = 0,
                    *, max_rounds: int = 1000,
                    forbidden_impl: str | None = None, spec=None):
    """Vertices grouped into independent sets (list of index arrays).

    Routes through ``repro.api.color`` — pass ``spec=`` for full control, or
    the common knobs directly (``forbidden_impl``/``max_rounds`` used to be
    silently dropped here).
    """
    from repro import api
    if spec is None:
        spec = api.ColoringSpec(algorithm=algorithm, seed=seed,
                                max_rounds=max_rounds,
                                forbidden_impl=forbidden_impl)
    res = api.color(g, spec)
    assert col.is_proper(g, res.colors)
    return [np.nonzero(res.colors == c)[0] for c in range(res.n_colors)], res
