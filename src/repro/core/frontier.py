"""Frontier-compacted RSOC — beyond-paper optimization (EXPERIMENTS.md §Perf).

After round 0 the defect set U is a small fraction of V (sub-1% typically),
but the baseline fused pass still sweeps every ELL row each round: the
memory-roofline term is n*W*4 bytes/round regardless of |U|.  This variant
compacts U into a fixed-capacity index buffer (``jnp.nonzero(..., size=cap)``)
and gathers only those ELL rows, cutting per-round bytes from n*W to cap*W.

A second effect (measured in bench_conflicts): compaction re-packs the
frontier densely, so two vertices that collided inside one chunk land in
*different* chunks of the compacted pass with high probability — cross-chunk
fresh-data repair then resolves them without a re-collision.  This recovers,
deterministically, the paper's observation that immediate repair reduces
conflicts.

If |U| overflows the capacity (only plausible in round 1), the round falls
back to the full-width pass.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.csr import CSRGraph
from repro.core import coloring as col

MAX_ROUNDS_TRACE = col.MAX_ROUNDS_TRACE


def _compact_pass(ell, pri, colors, idx, idx_valid, C, n_chunks):
    """Fused detect-and-recolor over a compacted row-index buffer."""
    cap = idx.shape[0]
    cs = cap // n_chunks
    n_pad = colors.shape[0]

    def chunk_body(k, carry):
        colors, recolored, n_def = carry
        lo = k * cs
        ids = jax.lax.dynamic_slice_in_dim(idx, lo, cs, 0)
        live = jax.lax.dynamic_slice_in_dim(idx_valid, lo, cs, 0)
        ids_c = jnp.clip(ids, 0, n_pad - 1)
        ell_k = ell[ids_c]
        c_k = colors[ids_c]
        pri_k = pri[ids_c]
        nbrc, nbrp = col._gather_nbr(ell_k, colors, pri)
        defect = ((nbrc == c_k[:, None]) & (c_k[:, None] >= 0)
                  & (nbrp > pri_k[:, None])).any(axis=1) & live
        n_def = n_def + defect.sum(dtype=jnp.int32)
        forb = col._forbidden_from_nbrc(nbrc, C)
        mex, _ = col._mex(forb)
        colors = colors.at[ids_c].set(jnp.where(defect, mex, c_k))
        recolored = recolored.at[ids_c].max(defect)
        return colors, recolored, n_def

    init = (colors, jnp.zeros((n_pad,), bool), jnp.int32(0))
    return jax.lax.fori_loop(0, n_chunks, chunk_body, init)


@functools.partial(jax.jit, static_argnames=("p_static", "cap", "max_rounds"))
def _rsoc_compact_loop(ell, osrc, odst, pri, p_static, cap, max_rounds):
    n, n_pad, C, n_chunks = p_static
    colors0 = jnp.full((n_pad,), -1, jnp.int32)
    valid = jnp.arange(n_pad) < n
    zeros = jnp.zeros((n_pad,), bool)

    # round 0: full-width chunked coloring (everyone needs a color anyway)
    colors1, U, _, ovf0 = col._chunked_pass(
        p_static, ell, osrc, odst, pri, colors0, zeros, valid, detect=False)

    def compact(U):
        idx = jnp.nonzero(U, size=cap, fill_value=n_pad)[0].astype(jnp.int32)
        return idx, idx < n_pad

    def cond(s):
        return (s[4] > 0) & (s[3] < max_rounds)

    def body(s):
        colors, U, trace, r, last, tot, ovf = s
        count = U.sum(dtype=jnp.int32)

        def small(_):
            idx, live = compact(U)
            return _compact_pass(ell, pri, colors, idx, live, C, n_chunks)

        def big(_):
            c2, rec, nd, _ = col._chunked_pass(
                p_static, ell, osrc, odst, pri, colors, U, zeros, detect=True)
            return c2, rec, nd

        colors2, recolored, n_def = jax.lax.cond(count <= cap, small, big, None)
        trace = trace.at[jnp.minimum(r, MAX_ROUNDS_TRACE - 1)].set(n_def)
        return colors2, recolored, trace, r + 1, n_def, tot + n_def, ovf

    trace = jnp.zeros((MAX_ROUNDS_TRACE,), jnp.int32)
    s = (colors1, U, trace, jnp.int32(0), jnp.int32(1), jnp.int32(0), ovf0)
    colors, U, trace, r, _, tot, ovf = jax.lax.while_loop(cond, body, s)
    return colors[:n], r, trace, tot, ovf


def color_rsoc_compact(g: CSRGraph, seed: int = 0, C: Optional[int] = None,
                       n_chunks: int = 16, max_rounds: int = 1000,
                       ell_cap: int = 512, relabel: bool = True,
                       frontier_frac: float = 0.125) -> col.ColoringResult:
    """RSOC with frontier compaction after round 0."""
    prob = col.prepare(g, seed, n_chunks, ell_cap, C, relabel)
    cap = max(n_chunks, int(prob.n_pad * frontier_frac))
    cap = -(-cap // n_chunks) * n_chunks
    C_ = prob.C
    while True:
        p_static = (prob.n, prob.n_pad, C_, n_chunks)
        colors, r, trace, tot, ovf = _rsoc_compact_loop(
            prob.ell, prob.ovf_src, prob.ovf_dst, prob.pri, p_static, cap,
            max_rounds)
        if not bool(ovf):
            break
        C_ *= 2
    colors = col._unpermute(colors, prob.perm, prob.n)
    return col.ColoringResult(
        colors=colors, n_rounds=int(r), conflicts_per_round=np.asarray(trace),
        total_conflicts=int(tot), n_colors=col.n_colors_used(colors),
        overflow=False, gather_passes=1 + int(r))
