"""Frontier-compacted RSOC — beyond-paper optimization (EXPERIMENTS.md §Perf).

After round 0 the defect set U is a small fraction of V (sub-1% typically),
but the baseline fused pass still sweeps every ELL row each round: the
memory-roofline term is n*W*4 bytes/round regardless of |U|.  This variant
compacts U into a fixed-capacity index buffer (``jnp.nonzero(..., size=cap)``)
and gathers only those ELL rows, cutting per-round bytes from n*W to cap*W.

A second effect (measured in bench_conflicts): compaction re-packs the
frontier densely, so two vertices that collided inside one chunk land in
*different* chunks of the compacted pass with high probability — cross-chunk
fresh-data repair then resolves them without a re-collision.  This recovers,
deterministically, the paper's observation that immediate repair reduces
conflicts.

If |U| overflows the capacity (only plausible in round 1), the round falls
back to the full-width pass.

The repair loop is factored into ``_compact_repair`` so it can start from an
externally supplied (colors, U) pair: the from-scratch driver seeds it with
round 0's defects, while ``repro.dynamic.incremental`` seeds it with the
endpoints of mutated edges against the previous coloring (DESIGN.md §7).
Overflow (COO side-channel) edges participate via pass-start snapshots, same
as the full-width pass.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import registry
from repro.graphs.csr import CSRGraph
from repro.core import bitset
from repro.core import coloring as col
from repro.core.context import PassContext
from repro import obs

MAX_ROUNDS_TRACE = col.MAX_ROUNDS_TRACE


def _compact_pass(ctx, ell, osrc, odst, pri, colors, idx, idx_valid):
    """Fused detect-and-recolor over a compacted row-index buffer.

    ``idx`` holds the (≤ cap) row ids of the current frontier, dead slots
    hold n_pad (dropped by out-of-bounds scatter).  A row is re-colored when
    it is defective *right now* — or still uncolored (incremental seeds).
    Returns (colors, recolored_mask, n_defects, cap_overflowed).
    """
    n, n_pad_s, C, n_chunks, impl = ctx.unpack()
    cap = idx.shape[0]
    cs = cap // n_chunks
    n_pad = colors.shape[0]
    has_ovf = osrc.shape[0] > 0
    if has_ovf:
        # pass-start overflow snapshots (see coloring.py termination
        # argument), built *frontier-local*: an inverse index maps each
        # overflow edge to its compacted slot (or nowhere), so the tables
        # are (cap, C)/(cap,), not (n_pad, C) — the compaction win must
        # survive the spill regime the dynamic workloads live in.  The
        # scatter lands in a transient dense table; only the packed words
        # are retained across the chunk loop (scatter-then-pack,
        # DESIGN.md §10).
        inv = jnp.full((n_pad + 1,), -1, jnp.int32).at[idx].set(
            jnp.arange(cap, dtype=jnp.int32))
        olive = (osrc >= 0) & (odst >= 0)
        pos = jnp.where(olive, inv[jnp.clip(osrc, 0, n_pad)], -1)
        nbr_c = colors[jnp.clip(odst, 0, n_pad - 1)]
        ok = (pos >= 0) & (nbr_c >= 0) & (nbr_c < C)
        snap_forb = jnp.zeros((cap, C), jnp.uint8).at[
            jnp.clip(pos, 0, cap - 1),
            jnp.clip(nbr_c, 0, C - 1)].max(ok.astype(jnp.uint8))
        if impl == "bitset":
            snap_forb = bitset.pack_dense(snap_forb, C)
        conf = ((pos >= 0) & (colors[jnp.clip(osrc, 0, n_pad - 1)] == nbr_c)
                & (nbr_c >= 0)
                & (pri[jnp.clip(odst, 0, n_pad - 1)]
                   > pri[jnp.clip(osrc, 0, n_pad - 1)]))
        ovf_defect = jnp.zeros((cap,), jnp.uint8).at[
            jnp.clip(pos, 0, cap - 1)].max(conf.astype(jnp.uint8)).astype(bool)

    def chunk_body(k, carry):
        colors, recolored, n_def, ovf = carry
        lo = k * cs
        ids = jax.lax.dynamic_slice_in_dim(idx, lo, cs, 0)
        live = jax.lax.dynamic_slice_in_dim(idx_valid, lo, cs, 0)
        ids_c = jnp.clip(ids, 0, n_pad - 1)
        ell_k = ell[ids_c]
        c_k = colors[ids_c]
        pri_k = pri[ids_c]
        nbrc, nbrp = col._gather_nbr(ell_k, colors, pri)      # FRESH colors
        defect = ((nbrc == c_k[:, None]) & (c_k[:, None] >= 0)
                  & (nbrp > pri_k[:, None])).any(axis=1)
        if has_ovf:
            defect = defect | jax.lax.dynamic_slice_in_dim(
                ovf_defect, lo, cs, 0)
        defect = defect & live
        work = defect | (live & (c_k < 0))
        n_def = n_def + defect.sum(dtype=jnp.int32)
        forb = col._forbidden(nbrc, C, impl)
        if has_ovf:
            forb = col._merge_forbidden(forb, jax.lax.dynamic_slice_in_dim(
                snap_forb, lo, cs, 0), impl)
        mex, o = col._mex_of(forb, C, impl)
        # dead slots carry idx == n_pad: out-of-bounds -> dropped
        colors = colors.at[ids].set(jnp.where(work, mex, c_k), mode="drop")
        recolored = recolored.at[ids].max(work, mode="drop")
        return colors, recolored, n_def, ovf | (o & work).any()

    init = (colors, jnp.zeros((n_pad,), bool), jnp.int32(0), jnp.bool_(False))
    return jax.lax.fori_loop(0, n_chunks, chunk_body, init)


def _d1_passes(ctx, ell, osrc, odst, pri):
    """The distance-1 (pass_small, pass_big) pair for ``_compact_repair``."""
    def pass_small(colors, idx, idx_valid):
        return _compact_pass(ctx, ell, osrc, odst, pri, colors,
                             idx, idx_valid)

    def pass_big(colors, U, force):
        return col._chunked_pass(ctx, ell, osrc, odst, pri, colors,
                                 U, force, detect=True)

    return pass_small, pass_big


def _compact_repair(ctx, cap, pass_small, pass_big, colors, U,
                    max_rounds, ovf0=False):
    """Frontier-compacted fused repair from an arbitrary (colors, U) start.

    Same contract as ``coloring._fused_repair`` (one gather pass per round,
    U_{r+1} = recolored_r, terminates on a zero-defect pass) but each pass
    gathers only the ≤ cap compacted frontier rows; rounds whose frontier
    exceeds ``cap`` fall back to the full-width pass.

    The driver is engine-agnostic (the distance-2 engine in
    ``core/distance2.py`` supplies two-hop passes): ``pass_small(colors,
    idx, idx_valid)`` recolors the ≤ cap compacted frontier rows,
    ``pass_big(colors, U, force)`` is the full-width fallback; both return
    (colors, recolored_mask, n_defects, cap_overflowed).

    Under the static ``ctx.trace`` flag the return grows a per-round |U|
    trace (same splice-before-the-tail convention as
    ``coloring._fused_repair``); the frontier count is free here — every
    round already computes it to pick the small-vs-big pass.
    """
    n, n_pad, C, n_chunks, impl = ctx.unpack()

    def compact(U):
        idx = jnp.nonzero(U, size=cap, fill_value=n_pad)[0].astype(jnp.int32)
        return idx, idx < n_pad

    def cond(s):
        # state tail fixed at (..., r, last, tot, ovf)
        return (s[-3] > 0) & (s[-4] < max_rounds)

    def body(s):
        if ctx.trace:
            colors, U, trace, ftrace, r, last, tot, ovf = s
        else:
            colors, U, trace, r, last, tot, ovf = s
        count = U.sum(dtype=jnp.int32)
        if ctx.trace:
            ftrace = ftrace.at[jnp.minimum(r, MAX_ROUNDS_TRACE - 1)].set(count)
        n_forced = (U & (colors < 0)).sum(dtype=jnp.int32)

        def small(_):
            idx, live = compact(U)
            return pass_small(colors, idx, live)

        def big(_):
            force = U & (colors < 0)
            return pass_big(colors, U, force)

        colors2, recolored, n_def, ovf2 = jax.lax.cond(
            count <= cap, small, big, None)
        trace = trace.at[jnp.minimum(r, MAX_ROUNDS_TRACE - 1)].set(n_def)
        # forced (uncolored-seed) work is speculative: keep the loop alive
        # so the next pass verifies it (see coloring._fused_repair)
        head = ((colors2, recolored, trace, ftrace) if ctx.trace
                else (colors2, recolored, trace))
        return head + (r + 1, n_def + n_forced, tot + n_def, ovf | ovf2)

    trace = jnp.zeros((MAX_ROUNDS_TRACE,), jnp.int32)
    head = ((colors, U, trace, jnp.zeros((MAX_ROUNDS_TRACE,), jnp.int32))
            if ctx.trace else (colors, U, trace))
    s = head + (jnp.int32(0), jnp.int32(1), jnp.int32(0), jnp.bool_(ovf0))
    out = jax.lax.while_loop(cond, body, s)
    if ctx.trace:
        colors, U, trace, ftrace, r, _, tot, ovf = out
        return colors, r, trace, ftrace, tot, ovf
    colors, U, trace, r, _, tot, ovf = out
    return colors, r, trace, tot, ovf


@functools.partial(jax.jit, static_argnames=("ctx", "cap", "max_rounds"))
def _rsoc_compact_loop(ell, osrc, odst, pri, ctx, cap, max_rounds):
    n, n_pad, C, n_chunks, impl = ctx.unpack()
    colors0 = jnp.full((n_pad,), -1, jnp.int32)
    valid = jnp.arange(n_pad) < n
    zeros = jnp.zeros((n_pad,), bool)

    # round 0: full-width chunked coloring (everyone needs a color anyway)
    colors1, U, _, ovf0 = col._chunked_pass(
        ctx, ell, osrc, odst, pri, colors0, zeros, valid, detect=False)
    pass_small, pass_big = _d1_passes(ctx, ell, osrc, odst, pri)
    out = _compact_repair(
        ctx, cap, pass_small, pass_big, colors1, U, max_rounds, ovf0)
    return (out[0][:n],) + out[1:]


@functools.partial(jax.jit, static_argnames=("ctx", "cap", "max_rounds"))
def _repair_compact_loop(ell, osrc, odst, pri, colors, U, ctx, cap,
                         max_rounds):
    """Externally-seeded compacted repair (no round 0): the incremental
    recoloring entry point.  Returns full-length (n_pad) colors."""
    pass_small, pass_big = _d1_passes(ctx, ell, osrc, odst, pri)
    return _compact_repair(ctx, cap, pass_small, pass_big, colors, U,
                           max_rounds)


def _mega_compact_repair(ctx, cap, pass_small, colors, U, max_rounds,
                         esc0):
    """Batch-axis-tolerant compacted repair (DESIGN.md §13).

    Same per-round semantics as ``_compact_repair``'s small branch
    (``U_{r+1} = recolored_r``, forced uncolored seeds keep the loop alive,
    terminates on a zero-defect pass) but written to be ``vmap``-ed across a
    megabatch slot axis, which rules out the two per-instance control-flow
    escapes of the scalar loop:

      * no ``lax.cond`` full-width fallback — under vmap a batched predicate
        executes BOTH branches for every slot each round, so one tenant's
        frontier overflow would charge the whole slot class the O(n_pad*W)
        full-width pass;
      * no in-loop cap doubling — a doubled C is a new jit program, i.e. a
        batch-wide recompile.

    Instead, either condition (compacted frontier past ``cap``, or the mex
    overflowing the color cap) raises the instance's ``escape`` flag, zeroes
    its frontier so its loop terminates, and leaves the rest of the batch
    running at full speed; the host redoes escaped slots through the
    per-tenant ``_run_with_retry`` path, whose results are bit-identical to
    what the non-escaping loop would have produced.  Returns
    ``(colors, n_rounds, total_defects, escape)`` — colors of an escaped
    instance are garbage by contract and must be discarded.

    ``esc0`` marks instances escaped *before* this repair (an insert wave
    overflowed the buffer, or an earlier fused batch round escaped): they
    start with a zeroed frontier and run ZERO iterations — without this an
    already-garbage instance could fail to converge and spin the batched
    loop to ``max_rounds`` for everyone.
    """
    n, n_pad, C, n_chunks, impl = ctx.unpack()

    def compact(U):
        idx = jnp.nonzero(U, size=cap, fill_value=n_pad)[0].astype(jnp.int32)
        return idx, idx < n_pad

    def cond(s):
        colors, U, r, last, tot, esc = s
        return (last > 0) & (r < max_rounds)

    def body(s):
        colors, U, r, last, tot, esc = s
        count = U.sum(dtype=jnp.int32)
        esc = esc | (count > cap)      # frontier overflow: host must redo
        n_forced = (U & (colors < 0)).sum(dtype=jnp.int32)
        idx, live = compact(U)
        colors2, recolored, n_def, ovf = pass_small(colors, idx, live)
        esc = esc | ovf                # color-cap overflow: host must redo
        # an escaped instance stops looping (its colors are discarded);
        # forced seeds are speculative, same liveness rule as the scalar loop
        last2 = jnp.where(esc, 0, n_def + n_forced)
        return colors2, recolored, r + 1, last2, tot + n_def, esc

    s = (colors, U, jnp.int32(0),
         jnp.where(esc0, jnp.int32(0), jnp.int32(1)), jnp.int32(0), esc0)
    colors, U, r, _, tot, esc = jax.lax.while_loop(cond, body, s)
    return colors, r, tot, esc


@functools.partial(jax.jit, static_argnames=("ctx", "cap", "max_rounds"))
def _repair_mega_loop(ell, osrc, odst, pri, colors, U, esc0, ctx, cap,
                      max_rounds):
    """Megabatched externally-seeded repair: every operand carries a leading
    slot axis and ONE dispatch repairs every slot's coloring.  Per-slot
    ``(colors, n_rounds, total_defects, escape)``; a raised escape flag
    means that slot must be redone per-tenant (see ``_mega_compact_repair``).
    ``esc0`` flags slots already escaped upstream — they are frozen at zero
    iterations.  Slots whose loops finish early are frozen by the
    ``while_loop`` batching rule, so per-slot results are bit-identical to
    the scalar small-branch loop."""
    def one(ell_i, osrc_i, odst_i, pri_i, colors_i, U_i, esc0_i):
        pass_small, _ = _d1_passes(ctx, ell_i, osrc_i, odst_i, pri_i)
        return _mega_compact_repair(ctx, cap, pass_small, colors_i, U_i,
                                    max_rounds, esc0_i)

    return jax.vmap(one)(ell, osrc, odst, pri, colors, U, esc0)


@registry.register_engine("rsoc_compact", distance=1, mode="static",
                          replaces="color_rsoc_compact")
def _rsoc_compact_engine(g: CSRGraph, spec) -> col.ColoringResult:
    """RSOC with frontier compaction after round 0."""
    impl = col._resolve_impl(spec.forbidden_impl)
    tracer = obs.current_tracer()
    with obs.phase("prepare"):
        prob = col.prepare(g, spec.seed, spec.n_chunks, spec.ell_cap, spec.C,
                           spec.relabel)
    cap = frontier_cap(prob.n_pad, spec.n_chunks, spec.frontier_frac)

    def run(C_):
        ctx = PassContext.for_problem(prob, n_chunks=spec.n_chunks, C=C_,
                                      forbidden_impl=impl,
                                      trace=tracer is not None)
        return _rsoc_compact_loop(prob.ell, prob.ovf_src, prob.ovf_dst,
                                  prob.pri, ctx, cap, spec.max_rounds)

    out, C_, retries = col._run_with_retry(run, prob.C,
                                           engine="rsoc_compact",
                                           max_retries=spec.max_cap_retries)
    colors, r, trace, ftrace, tot = col._loop_outputs(out, tracer is not None)
    col._report_frontier(tracer, ftrace, r, cap=cap)
    conf, truncated = col._trim_trace(trace, r)
    colors = col._unpermute(colors, prob.perm, prob.n)
    return col.ColoringResult(
        colors=colors, n_rounds=int(r), conflicts_per_round=conf,
        total_conflicts=int(tot), n_colors=col.n_colors_used(colors),
        overflow=retries > 0, gather_passes=1 + int(r),
        final_C=C_, retries=retries, trace_truncated=truncated)


def color_rsoc_compact(g: CSRGraph, seed: int = 0, C: Optional[int] = None,
                       n_chunks: int = 16, max_rounds: int = 1000,
                       ell_cap: int = 512, relabel: bool = True,
                       frontier_frac: float = 0.125,
                       forbidden_impl: Optional[str] = None
                       ) -> col.ColoringResult:
    """Deprecated: use ``repro.api.color(g, algorithm="rsoc_compact")``."""
    return registry.legacy_entry(
        "color_rsoc_compact", "algorithm='rsoc_compact'", g,
        algorithm="rsoc_compact", seed=seed, C=C, n_chunks=n_chunks,
        max_rounds=max_rounds, ell_cap=ell_cap, relabel=relabel,
        frontier_frac=frontier_frac, forbidden_impl=forbidden_impl)


def frontier_cap(n_pad: int, n_chunks: int, frac: float = 0.125) -> int:
    """Compacted-frontier capacity: a fraction of n_pad, chunk-aligned."""
    cap = max(n_chunks, int(n_pad * frac))
    return -(-cap // n_chunks) * n_chunks
