"""Packed-bitset forbidden sets + branch-free mex (DESIGN.md §10).

Every coloring engine in this repo runs the same hot loop: gather neighbor
colors -> forbidden set -> smallest free color (mex).  The dense
representation materializes the forbidden set as a (rows, C) uint8/bool
table and takes ``argmin`` over the color axis — C compare lanes and C bytes
per row.  This module packs the same set into ``(rows, C//32)`` int32 words
(bit b of word w == color 32*w + b forbidden): 32× fewer compare lanes in
the pack, 8× less memory per retained row, and a branch-free mex built from
two classic bit tricks:

  * isolate the lowest ZERO bit of a word:  ``lz = ~w & (w + 1)``
    (power of two when w has a zero, 0 when w is all-ones), and
  * bit-index via the float-exponent trick: a power-of-two int32, routed
    through uint32 -> float32 (exact for powers of two), carries its bit
    index in the IEEE-754 exponent field: ``(bits >> 23) - 127``.

The per-word candidate ``32*word + bit_index`` (full words get the sentinel
C) is minimized across words — word k's candidates all precede word k+1's,
so the min IS the first zero bit, i.e. exactly the dense ``argmin``.  On
total overflow (every bit set) the dense ``argmin`` over an all-ones table
returns 0; we mirror that so the two implementations are bit-identical even
on rows the caller will retry at a doubled cap.

Color caps that are not multiples of 32 are handled by pre-forbidding the
tail bits (>= C) of the last word, so mex never returns an out-of-cap color
and the overflow test is simply "every word is all-ones".

All helpers are plain jnp on int32 lanes and trace equally inside Pallas
kernel bodies (iotas are ``broadcasted_iota``; no 1-D iota, no gathers, no
data-dependent branches), which is how the kernels in ``repro.kernels``
share this exact code path with the jnp engines.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

WORD = 32  # bits per packed word

# implementations understood by every engine's ``forbidden_impl`` switch:
# "bitset" is the production path, "dense" the differential oracle.
IMPLS = ("bitset", "dense")


def n_words(C: int) -> int:
    """Packed words per row for a cap of C colors (ceil division)."""
    return -(-int(C) // WORD)


def tail_mask(C: int) -> jnp.ndarray:
    """(1, n_words) int32 with every bit for colors >= C set.

    OR-ing this into a packed row pre-forbids the out-of-cap tail, making
    mex/overflow exact for caps that are not multiples of 32.
    """
    nW = n_words(C)
    base = jax.lax.broadcasted_iota(jnp.int32, (1, nW), 1) * WORD
    live = jnp.clip(C - base, 0, WORD)            # valid bits per word
    ones = jnp.where(live == WORD, jnp.int32(-1),
                     (jnp.int32(1) << live) - 1)  # low `live` bits set
    return ~ones


def pack_from_nbrc(nbrc: jnp.ndarray, C: int) -> jnp.ndarray:
    """Inline pack: (rows, W) neighbor colors -> (rows, n_words) bitset.

    A color c lands as bit ``c & 31`` of word ``c >> 5``; slots outside
    [0, C) (FILL = -1, overflowed colors) contribute nothing.  The compare
    fabric is ``(nbrc >> 5) == word_iota`` — C/32 lanes per neighbor slot
    instead of the dense path's C — and the OR-reduction over the neighbor
    axis happens in registers, never materializing a (rows, W, C) one-hot.
    Tail bits >= C come back pre-forbidden (see ``tail_mask``).
    """
    rows, W = nbrc.shape
    nW = n_words(C)
    ok = (nbrc >= 0) & (nbrc < C)
    w_idx = jnp.where(ok, nbrc >> 5, -1)                      # (rows, W)
    bit = jnp.where(ok, jnp.int32(1) << (nbrc & 31), 0)
    word_iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, nW), 2)
    hit = w_idx[:, :, None] == word_iota                      # (rows, W, nW)
    contrib = jnp.where(hit, bit[:, :, None], 0)
    words = jax.lax.reduce(contrib, np.int32(0), jax.lax.bitwise_or, (1,))
    return words | tail_mask(C)


def or_color(forb: jnp.ndarray, nc: jnp.ndarray, C: int) -> jnp.ndarray:
    """OR one column of neighbor colors (rows,) into a packed (rows, nW)
    table — the per-neighbor step of the inline pack, shaped for the Pallas
    kernels' fori loops over the ELL width (one (rows, C//32) compare +
    select per neighbor slot instead of the dense path's (rows, C))."""
    nW = forb.shape[1]
    ok = (nc >= 0) & (nc < C)
    w_idx = jnp.where(ok, nc >> 5, -1)
    bit = jnp.where(ok, jnp.int32(1) << (nc & 31), 0)
    word_iota = jax.lax.broadcasted_iota(jnp.int32, (1, nW), 1)
    return forb | jnp.where(w_idx[:, None] == word_iota, bit[:, None], 0)


def init_words(rows: int, C: int) -> jnp.ndarray:
    """All-free packed table with the out-of-cap tail pre-forbidden."""
    return jnp.zeros((rows, n_words(C)), jnp.int32) | tail_mask(C)


def pack_dense(forb_dense: jnp.ndarray, C: int) -> jnp.ndarray:
    """Pack a dense (rows, C) 0/1 table into (rows, n_words) int32 words.

    This is the scatter-then-pack route used for COO snapshot tables: COO
    edges scatter into a transient dense table (jnp scatter has max but no
    bitwise-or mode), which is packed once per pass — the *retained*
    snapshot the chunk loop slices every round is the 8×-smaller packed
    form.  The ELL gather path never needs the dense intermediate and packs
    inline via ``pack_from_nbrc``.
    """
    rows = forb_dense.shape[0]
    nW = n_words(C)
    padded = jnp.zeros((rows, nW * WORD), forb_dense.dtype)
    padded = jax.lax.dynamic_update_slice(padded, forb_dense, (0, 0))
    lanes = padded.reshape(rows, nW, WORD).astype(jnp.int32)
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 1, WORD), 2)
    words = jax.lax.reduce(jnp.where(lanes > 0, jnp.int32(1) << shifts, 0),
                           np.int32(0), jax.lax.bitwise_or, (2,))
    return words | tail_mask(C)


def mex_words(words: jnp.ndarray, C: int):
    """Branch-free mex over packed rows.  Returns (mex (rows,), ovf (rows,)).

    Per word: isolate the lowest zero bit (``~w & (w+1)``), recover its index
    through the float-exponent trick, form the candidate ``32*word + index``
    (sentinel C for all-ones words), and take the row minimum — bit-identical
    to ``argmin`` over the dense table, including the overflow convention
    (dense argmin over an all-ones row is 0).
    """
    rows, nW = words.shape
    full = words == -1
    lz = ~words & (words + 1)                     # lowest zero bit, isolated
    f = lz.astype(jnp.uint32).astype(jnp.float32)  # exact: power of two
    bidx = (jax.lax.bitcast_convert_type(f, jnp.int32) >> 23) - 127
    base = jax.lax.broadcasted_iota(jnp.int32, (1, nW), 1) * WORD
    cand = jnp.where(full, jnp.int32(C), base + bidx)
    mex = jnp.min(cand, axis=-1).astype(jnp.int32)
    ovf = mex >= C
    return jnp.where(ovf, jnp.int32(0), mex), ovf


def apply_recolor(work: jnp.ndarray, mex: jnp.ndarray, ovf: jnp.ndarray,
                  c_r: jnp.ndarray):
    """Recolor-commit tail shared by every detect-and-recolor path: rows in
    ``work`` take their mex, the rest keep ``c_r``; overflow only counts on
    rows that actually recolored.  Returns (newc, recolored, ovf&work)."""
    return jnp.where(work, mex, c_r), work, ovf & work


def recolor_epilogue(forb: jnp.ndarray, defect: jnp.ndarray, U: jnp.ndarray,
                     c_r: jnp.ndarray, C: int):
    """Fused kernel epilogue: work mask + branch-free mex evaluated on the
    packed (rows, C//32) words while they are still VMEM/register-resident —
    the forbidden table never round-trips through HBM.  One code path for the
    ``detect_recolor`` and ``twohop`` kernels and their jnp refs (firstfit is
    the degenerate case with no defect test: ``mex_words`` alone).

    Returns (new colors (rows,), recolored (rows,) bool, overflow (rows,)
    bool) — overflow is only raised on rows that actually recolored.
    """
    work = U & defect
    mex, ovf = mex_words(forb, C)
    return apply_recolor(work, mex, ovf, c_r)


def to_dense(words: jnp.ndarray, C: int) -> jnp.ndarray:
    """Unpack (rows, n_words) -> (rows, C) uint8 (test/debug helper)."""
    rows, nW = words.shape
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 1, WORD), 2)
    bits = (words[:, :, None] >> shifts) & 1
    return bits.reshape(rows, nW * WORD)[:, :C].astype(jnp.uint8)


def ws_bytes(rows: int, C: int, impl: str = "bitset") -> int:
    """Retained forbidden-table working set in bytes for ``rows`` rows.

    dense: one uint8 lane per color; bitset: one int32 word per 32 colors.
    The 8× ratio (at word-aligned C) is the per-tile VMEM shrink every
    engine and kernel inherits (DESIGN.md §10).
    """
    if impl == "dense":
        return rows * int(C)
    if impl == "bitset":
        return rows * n_words(C) * 4
    raise ValueError(f"unknown forbidden impl {impl!r}; known: {IMPLS}")


def ws_mb(rows: int, C: int, impl: str = "bitset") -> float:
    return ws_bytes(rows, C, impl) / 2**20
