"""Parallel graph coloring: serial First-Fit, Gebremedhin-Manne (GM),
Catalyurek et al. (CAT), and the paper's contribution RSOC — adapted for
lockstep SPMD execution (TPU/JAX).

Vocabulary of the TPU adaptation (DESIGN.md §2):

  * "thread concurrency" -> a *chunk*: the set of vertices (re)colored
    simultaneously in one data-parallel step.  Within a chunk execution is
    lockstep; across the ``n_chunks`` chunks of one pass execution is
    sequential and reads fresh colors — exactly a thread's sequential walk
    over its partition in the paper.  ``n_chunks`` plays the role of
    1/threads: chunk width n/n_chunks is the simulated thread count.
  * Vertices are randomly relabeled once (host-side) so a chunk is a random
    vertex sample — the paper shuffles RMAT vertex ids for the same reason.
  * CAT round = phase A: chunked re-color of the defect set U (against colors
    as of the previous detect, fresh within the pass); BARRIER; phase B:
    separate detect pass -> new U; BARRIER.  Two neighbor-gather passes,
    two materialization points per round.
  * RSOC round = ONE fused detect-and-recolor pass over U: a defect is
    repaired the moment it is seen, from the same gathered neighbor row
    ("freshest data", paper §3).  One gather pass, one materialization point.
    Repairs land a round earlier than CAT's, so rounds and conflicts drop —
    the paper's Figs. 3-6 mechanism.
  * Termination under lockstep (paper §5: SIMT livelock): conflicts are broken
    *asymmetrically* by a hashed random priority — of a conflicting edge only
    the lower-priority endpoint re-colors.  Every round the highest-priority
    defective vertex becomes permanently stable => termination in <= |V|
    rounds (observed 2-8).  This is the deterministic version of the paper's
    "emulated randomness" remedy for SIMT machines.

Graph encodings: ELL (n, width) padded neighbor table (gather-friendly,
VMEM-tileable — used by the Pallas kernels too), with a COO side-channel for
overflow edges of capped-width hubs (power-law graphs).  Overflow forbidden
sets are built from the round-start snapshot, which preserves the termination
argument (the stable neighbors' colors are always avoided).
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from collections.abc import Mapping
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import registry
from repro.core import bitset
from repro.core.context import (DEFAULT_FORBIDDEN_IMPL, PassContext,
                                resolve_impl)
from repro.graphs.csr import CSRGraph, FILL, from_edges, to_edge_list, to_ell
from repro import obs
from repro.resilience import faults
from repro.resilience.errors import CapRetryExhausted

MAX_ROUNDS_TRACE = 64  # fixed-size conflict trace (while_loop-friendly)

# back-compat alias: the canonical definition moved to core/context.py with
# the PassContext it configures (DESIGN.md §11)
_resolve_impl = resolve_impl


# --------------------------------------------------------------------------
# result container + verification
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ColoringResult:
    colors: np.ndarray             # (n,) int32, >= 0, original vertex ids
    n_rounds: int                  # while-loop rounds (excl. round 0)
    conflicts_per_round: np.ndarray
    total_conflicts: int
    n_colors: int
    overflow: bool                 # True iff the color cap was ever exceeded
    gather_passes: int             # neighbor-gather sweeps executed (perf proxy)
    final_C: int = 0               # color cap actually used (after doublings)
    retries: int = 0               # cap-doubling re-runs (0 = first cap fit)
    distance: int = 1              # coloring distance (2 = native two-hop)
    degrade_rung: int = 0          # resilience ladder rung that produced
                                   # the colors (0 = normal path; see
                                   # resilience/ladder.RUNG_NAMES)
    # the resolved repro.api.ColoringSpec that produced this result, echoed
    # by api.color for reproducibility (None on direct engine calls); typed
    # as object because this module must not import repro.api
    spec: Optional[object] = None
    # mode="incremental" only: the DynamicColoringState behind the colors
    state: Optional[object] = None
    # True iff n_rounds exceeded the MAX_ROUNDS_TRACE device buffer, i.e.
    # conflicts_per_round is a clipped view with the tail collapsed into its
    # last slot (also warned once per process — see _trim_trace)
    trace_truncated: bool = False
    # the obs.RunTrace of this run when tracing was on (api.color attaches
    # it); typed as object because this module must not import repro.obs.*
    # artifacts at class scope
    trace: Optional[object] = None

    def summary(self) -> dict:
        return {"rounds": int(self.n_rounds),
                "conflicts": int(self.total_conflicts),
                "colors": int(self.n_colors),
                "gather_passes": int(self.gather_passes),
                "final_C": int(self.final_C),
                "retries": int(self.retries),
                "distance": int(self.distance)}


_trace_truncation_warned = False


def _trim_trace(trace, n_rounds):
    """Per-round conflict trace, clipped to the rounds that actually ran.

    The device-side trace buffer is a fixed MAX_ROUNDS_TRACE slots (the
    while-loop carry needs a static shape), and runs past it used to hand
    back a silently-clipped 64-row array.  The clipping is now explicit:
    returns ``(trimmed, truncated)`` where ``truncated`` lands on
    ``ColoringResult.trace_truncated``, plus a once-per-process warning the
    first time a run overruns the buffer.
    """
    global _trace_truncation_warned
    n_rounds = int(n_rounds)
    trimmed = np.asarray(trace).reshape(-1)[:min(n_rounds, MAX_ROUNDS_TRACE)]
    truncated = n_rounds > MAX_ROUNDS_TRACE
    if truncated and not _trace_truncation_warned:
        _trace_truncation_warned = True
        warnings.warn(
            f"conflicts_per_round truncated: {n_rounds} repair rounds "
            f"exceed the MAX_ROUNDS_TRACE={MAX_ROUNDS_TRACE} device trace "
            f"buffer, so rounds past it collapsed into the last slot "
            f"(ColoringResult.trace_truncated=True flags this run; this "
            f"warning fires once per process)", RuntimeWarning, stacklevel=3)
    return trimmed, truncated


def is_proper(g: CSRGraph, colors: np.ndarray) -> bool:
    colors = np.asarray(colors)
    e = to_edge_list(g)
    if len(e) == 0:
        return bool((colors >= 0).all())
    return bool((colors >= 0).all() and (colors[e[:, 0]] != colors[e[:, 1]]).all())


def n_colors_used(colors) -> int:
    return int(np.asarray(colors).max()) + 1


# --------------------------------------------------------------------------
# serial oracle (paper Algorithm 1)
# --------------------------------------------------------------------------

def greedy_sequential(g: CSRGraph) -> np.ndarray:
    """Sequential First-Fit. Host-side numpy oracle."""
    colors = np.full(g.n_vertices, -1, dtype=np.int32)
    scratch = np.zeros(g.max_degree + 2, dtype=np.int64)
    for v in range(g.n_vertices):
        nc = colors[g.neighbors(v)]
        nc = nc[nc >= 0]
        scratch[nc] = v + 1          # stamp trick: no re-clearing
        c = 0
        while scratch[c] == v + 1:
            c += 1
        colors[v] = c
    return colors


# --------------------------------------------------------------------------
# problem prep (host)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ColoringProblem:
    """Device-ready relabeled graph: ELL + overflow COO + priorities."""

    ell: jnp.ndarray        # (n_pad, W) int32 neighbor ids (relabeled), FILL pad
    ovf_src: jnp.ndarray    # (m_ovf,) overflow edges (relabeled)
    ovf_dst: jnp.ndarray
    pri: jnp.ndarray        # (n_pad,) int32 priority (pad rows = -1)
    n: int
    n_pad: int
    perm: np.ndarray        # old id -> new id
    C: int                  # color cap (bitmask-friendly, multiple of 32)


def _pick_C(g: CSRGraph, C: Optional[int]) -> int:
    if C is not None:
        return int(C)
    # The packed-bitset forbidden set costs 4 bytes per 32 colors per row
    # (vs 1 byte/color dense), so the default cap can afford to be generous:
    # a larger cap means fewer cap-doubling retries on high-degree graphs
    # (the paper's Figs. 3-6 regime) at 1/8th the old per-row cost.
    c = min(g.max_degree + 2, 256)
    return int(max(32, -(-c // 32) * 32))


def prepare(g: CSRGraph, seed: int = 0, n_chunks: int = 16,
            ell_cap: int = 512, C: Optional[int] = None,
            relabel: bool = True) -> ColoringProblem:
    n = g.n_vertices
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n).astype(np.int64) if relabel else np.arange(n)
    if relabel:
        edges = perm[to_edge_list(g).astype(np.int64)]
        g = from_edges(n, edges, symmetrize=False)
    n_pad = -(-max(n, n_chunks) // n_chunks) * n_chunks
    W = max(1, min(g.max_degree, ell_cap))
    deg = g.degrees
    if g.max_degree <= ell_cap:
        ell = to_ell(g, max_degree=W, pad_vertices_to=n_pad)
        osrc = np.zeros((0,), np.int32)
        odst = np.zeros((0,), np.int32)
    else:
        ell = np.full((n_pad, W), FILL, dtype=np.int32)
        row = np.repeat(np.arange(n), deg)
        col = np.arange(g.n_edges) - np.repeat(g.indptr[:-1], deg)
        in_ell = col < W
        ell[row[in_ell], col[in_ell]] = g.indices[in_ell]
        osrc = row[~in_ell].astype(np.int32)
        odst = g.indices[~in_ell].astype(np.int32)
    # independent random priorities (asymmetric tie-break)
    pri = np.full(n_pad, -1, np.int32)
    pri[:n] = rng.permutation(n).astype(np.int32)
    return ColoringProblem(
        ell=jnp.asarray(ell), ovf_src=jnp.asarray(osrc),
        ovf_dst=jnp.asarray(odst), pri=jnp.asarray(pri),
        n=n, n_pad=n_pad, perm=perm, C=_pick_C(g, C))


def _unpermute(colors_new: np.ndarray, perm: np.ndarray, n: int) -> np.ndarray:
    """Map colors from relabeled space back to original ids.

    ``perm`` maps old id -> new id, so colors_old[i] = colors_new[perm[i]].
    """
    return np.asarray(colors_new)[perm[:n]]


# --------------------------------------------------------------------------
# jittable primitives
# --------------------------------------------------------------------------

def _forbidden_coo(src, dst, colors, n_rows, C):
    """COO forbidden sets; FILL (-1) entries in src/dst are dead slots."""
    live = (src >= 0) & (dst >= 0)
    nbr_c = colors[jnp.clip(dst, 0, colors.shape[0] - 1)]
    ok = live & (nbr_c >= 0) & (nbr_c < C)
    forb = jnp.zeros((n_rows, C), jnp.uint8)
    return forb.at[jnp.clip(src, 0, n_rows - 1),
                   jnp.clip(nbr_c, 0, C - 1)].max(ok.astype(jnp.uint8))


def _mex(forb):
    mex = jnp.argmin(forb, axis=-1).astype(jnp.int32)
    ovf = jnp.all(forb > 0, axis=-1)
    return mex, ovf


# ---- forbidden-set representation dispatch (bitset | dense) --------------
#
# ``impl`` rides in ctx, so it is a jit-cache key like C and n_chunks;
# the passes below only ever touch forbidden tables through these four
# helpers, which keeps the two representations bit-identical by contract
# (tests/test_bitset.py enforces it).

def _forbidden(nbrc, C, impl):
    """(rows, W) gathered neighbor colors -> forbidden table (inline pack)."""
    if impl == "dense":
        return _forbidden_from_nbrc(nbrc, C)
    return bitset.pack_from_nbrc(nbrc, C)


def _mex_of(forb, C, impl):
    """Smallest free color + overflow flag per row of a forbidden table."""
    if impl == "dense":
        return _mex(forb)
    return bitset.mex_words(forb, C)


def _merge_forbidden(a, b, impl):
    """Union of two forbidden tables (gathered row ∪ COO snapshot slice)."""
    if impl == "dense":
        return jnp.maximum(a, b)
    return a | b


def _snapshot_coo(src, dst, colors, n_rows, C, impl):
    """Pass-start COO snapshot table: scatter dense, then (bitset) pack —
    jnp scatters have no bitwise-or mode, so the packed path routes the
    one-off scatter through a transient dense table and retains only the
    packed words (see bitset.pack_dense)."""
    dense = _forbidden_coo(src, dst, colors, n_rows, C)
    if impl == "dense":
        return dense
    return bitset.pack_dense(dense, C)


def _ovf_conflict(osrc, odst, colors, pri, n_rows):
    """Per-row defect flags from overflow edges (FILL slots are dead)."""
    live = (osrc >= 0) & (odst >= 0)
    s = jnp.clip(osrc, 0, colors.shape[0] - 1)
    d = jnp.clip(odst, 0, colors.shape[0] - 1)
    conf = live & (colors[s] == colors[d]) & (colors[s] >= 0) & (pri[d] > pri[s])
    return jnp.zeros((n_rows,), jnp.uint8).at[jnp.clip(osrc, 0, n_rows - 1)].max(
        conf.astype(jnp.uint8)).astype(bool)


def _gather_nbr(ell_k, colors, pri):
    """Neighbor colors + priorities for a block of ELL rows."""
    safe = jnp.clip(ell_k, 0, colors.shape[0] - 1)
    m = ell_k >= 0
    return jnp.where(m, colors[safe], -1), jnp.where(m, pri[safe], -1)


def _forbidden_from_nbrc(nbrc, C):
    rows = nbrc.shape[0]
    ok = (nbrc >= 0) & (nbrc < C)
    forb = jnp.zeros((rows, C), jnp.uint8)
    r = jnp.arange(rows)[:, None]
    return forb.at[r, jnp.clip(nbrc, 0, C - 1)].max(ok.astype(jnp.uint8))


def _chunked_pass(ctx, ell, osrc, odst, pri, colors, U, force, *,
                  detect: bool, valid=None):
    """One sequential sweep over n_chunks chunks.

    detect=False (CAT phase A): re-color every vertex in U | force.
    detect=True  (RSOC fused) : re-color a vertex in U only if it is
                                defective right now (fresh check), or forced.
    ``valid`` overrides the default prefix validity mask (length
    ``ctx.n_pad``) — the sharded engine's per-shard row layout is not a
    prefix of the global vertex range.  ``colors``/``pri`` may be longer
    than ``ctx.n_pad`` (a sharded color table with a ghost tail): only the
    first ``n_pad`` rows are swept, but gathers read the full table.
    Returns (colors, recolored_mask, n_defects, overflowed).
    """
    n, n_pad, C, n_chunks, impl = ctx.unpack()
    cs = n_pad // n_chunks
    valid_row = jnp.arange(n_pad) < n if valid is None else valid
    has_ovf = osrc.shape[0] > 0
    snap_forb = (_snapshot_coo(osrc, odst, colors, n_pad, C, impl)
                 if has_ovf else None)
    # overflow-edge conflicts, evaluated once on the pass-start snapshot.
    # (Conflicts only ever arise between two vertices recolored in the same
    # earlier pass, so the snapshot view is sufficient for detection; see
    # module docstring termination argument.)
    ovf_defect = None
    if has_ovf and detect:
        ovf_defect = _ovf_conflict(osrc, odst, colors, pri, n_pad)

    def chunk_body(k, carry):
        colors, recolored, n_def, ovf = carry
        lo = k * cs
        ell_k = jax.lax.dynamic_slice_in_dim(ell, lo, cs, 0)
        U_k = jax.lax.dynamic_slice_in_dim(U, lo, cs, 0)
        force_k = jax.lax.dynamic_slice_in_dim(force, lo, cs, 0)
        valid_k = jax.lax.dynamic_slice_in_dim(valid_row, lo, cs, 0)
        c_k = jax.lax.dynamic_slice_in_dim(colors, lo, cs, 0)
        pri_k = jax.lax.dynamic_slice_in_dim(pri, lo, cs, 0)
        nbrc, nbrp = _gather_nbr(ell_k, colors, pri)          # FRESH colors
        if detect:
            defect = ((nbrc == c_k[:, None]) & (c_k[:, None] >= 0)
                      & (nbrp > pri_k[:, None])).any(axis=1)
            if ovf_defect is not None:
                defect = defect | jax.lax.dynamic_slice_in_dim(
                    ovf_defect, lo, cs, 0)
            work = valid_k & ((U_k & defect) | force_k)
            n_def = n_def + (valid_k & U_k & defect).sum(dtype=jnp.int32)
        else:
            work = valid_k & (U_k | force_k)
        forb = _forbidden(nbrc, C, impl)
        if has_ovf:
            sf_k = jax.lax.dynamic_slice_in_dim(snap_forb, lo, cs, 0)
            forb = _merge_forbidden(forb, sf_k, impl)
        mex, ovf_k = _mex_of(forb, C, impl)
        newc = jnp.where(work, mex, c_k)
        colors = jax.lax.dynamic_update_slice_in_dim(colors, newc, lo, 0)
        recolored = jax.lax.dynamic_update_slice_in_dim(recolored, work, lo, 0)
        return colors, recolored, n_def, ovf | (ovf_k & work).any()

    init = (colors, jnp.zeros((n_pad,), bool), jnp.int32(0), jnp.bool_(False))
    return jax.lax.fori_loop(0, n_chunks, chunk_body, init)


def _detect_pass(ctx, ell, osrc, odst, pri, colors, U):
    """CAT phase B: standalone defect detection over U (full gather pass)."""
    n, n_pad, C, n_chunks, impl = ctx.unpack()
    valid_row = jnp.arange(n_pad) < n
    nbrc, nbrp = _gather_nbr(ell, colors, pri)
    defect = ((nbrc == colors[:, None]) & (colors[:, None] >= 0)
              & (nbrp > pri[:, None])).any(axis=1)
    if osrc.shape[0] > 0:
        defect = defect | _ovf_conflict(osrc, odst, colors, pri, n_pad)
    return defect & U & valid_row


# --------------------------------------------------------------------------
# algorithm loops
# --------------------------------------------------------------------------

def _fused_repair(ctx, ell, osrc, odst, pri, colors, U, max_rounds,
                  ovf0=False):
    """Fused detect-and-recolor rounds from an arbitrary (colors, U) start.

    This is the RSOC inner loop factored out of the from-scratch driver so a
    caller (incremental recoloring, distributed shards) can supply its own
    seed set U and partial coloring.  Vertices in U are re-colored only when
    defective *right now*; uncolored seeds (colors < 0) are force-colored on
    their first pass.  Returns (colors, n_rounds, trace, total_defects, ovf)
    — one neighbor-gather pass per round — or, under the static
    ``ctx.trace`` flag, (colors, n_rounds, trace, ftrace, total_defects,
    ovf) with a per-round |U| trace spliced in BEFORE the trailing pair so
    the retry contract (overflow flag last) survives.  ``ctx.trace`` is a
    jit-cache key: the False program is exactly the pre-obs one.
    """
    n, n_pad, C, n_chunks, impl = ctx.unpack()

    def cond(s):
        # terminate when a full fused pass detected zero defects: colors were
        # untouched during that pass, so its detection was complete.
        # (state tail is fixed at (..., r, tot, last_def, ovf) whether or
        # not the optional ftrace rides along)
        return (s[-2] > 0) & (s[-4] < max_rounds)

    def body(s):
        if ctx.trace:
            colors, U, trace, ftrace, r, tot, last_def, ovf = s
            ftrace = ftrace.at[jnp.minimum(r, MAX_ROUNDS_TRACE - 1)].set(
                U.sum(dtype=jnp.int32))
        else:
            colors, U, trace, r, tot, last_def, ovf = s
        force = U & (colors < 0)
        # ONE fused detect-and-recolor pass
        colors2, recolored, n_def, ovf2 = _chunked_pass(
            ctx, ell, osrc, odst, pri, colors, U, force, detect=True)
        trace = trace.at[jnp.minimum(r, MAX_ROUNDS_TRACE - 1)].set(n_def)
        # forced vertices were colored speculatively, not verified: keep the
        # loop alive so the next pass checks them (two adjacent uncolored
        # seeds can pick the same color from one snapshot)
        n_work = n_def + force.sum(dtype=jnp.int32)
        head = ((colors2, recolored, trace, ftrace) if ctx.trace
                else (colors2, recolored, trace))
        return head + (r + 1, tot + n_def, n_work, ovf | ovf2)

    trace = jnp.zeros((MAX_ROUNDS_TRACE,), jnp.int32)
    head = ((colors, U, trace, jnp.zeros((MAX_ROUNDS_TRACE,), jnp.int32))
            if ctx.trace else (colors, U, trace))
    state = head + (jnp.int32(0), jnp.int32(0), jnp.int32(1),
                    jnp.bool_(ovf0))
    out = jax.lax.while_loop(cond, body, state)
    if ctx.trace:
        colors, U, trace, ftrace, r, tot, _, ovf = out
        return colors, r, trace, ftrace, tot, ovf
    colors, U, trace, r, tot, _, ovf = out
    return colors, r, trace, tot, ovf


@functools.partial(jax.jit, static_argnames=("ctx", "max_rounds"))
def _rsoc_loop(ell, osrc, odst, pri, ctx, max_rounds):
    n, n_pad, C, n_chunks, impl = ctx.unpack()
    colors0 = jnp.full((n_pad,), -1, jnp.int32)
    valid = jnp.arange(n_pad) < n
    zeros = jnp.zeros((n_pad,), bool)

    # round 0: tentative coloring of the whole graph (chunked, fresh)
    colors1, U, _, ovf0 = _chunked_pass(
        ctx, ell, osrc, odst, pri, colors0, zeros, valid, detect=False)
    out = _fused_repair(
        ctx, ell, osrc, odst, pri, colors1, U, max_rounds, ovf0)
    return (out[0][:n],) + out[1:]


@functools.partial(jax.jit, static_argnames=("ctx", "max_rounds"))
def _rsoc_repair_loop(ell, osrc, odst, pri, colors, U, ctx, max_rounds):
    """Externally-seeded fused repair (full-width passes; no round 0)."""
    return _fused_repair(ctx, ell, osrc, odst, pri, colors, U, max_rounds)


@functools.partial(jax.jit, static_argnames=("ctx", "max_rounds"))
def _cat_loop(ell, osrc, odst, pri, ctx, max_rounds):
    n, n_pad, C, n_chunks, impl = ctx.unpack()
    colors0 = jnp.full((n_pad,), -1, jnp.int32)
    valid = jnp.arange(n_pad) < n
    zeros = jnp.zeros((n_pad,), bool)

    # round 0 phase A: color everything (chunked, fresh within pass)
    colors1, _, _, ovf0 = _chunked_pass(
        ctx, ell, osrc, odst, pri, colors0, zeros, valid, detect=False)
    # round 0 phase B: detect                                   (pass 2)
    U1 = _detect_pass(ctx, ell, osrc, odst, pri, colors1, valid)

    def cond(s):
        return s[1].any() & (s[3] < max_rounds)

    def body(s):
        colors, U, trace, r, tot, ovf = s
        n_def = U.sum(dtype=jnp.int32)
        trace = trace.at[jnp.minimum(r, MAX_ROUNDS_TRACE - 1)].set(n_def)
        # phase A: re-color the defect set                      (pass 1)
        colors2, _, _, ovf2 = _chunked_pass(
            ctx, ell, osrc, odst, pri, colors, U, zeros, detect=False)
        # phase B: separate detect pass                         (pass 2)
        U2 = _detect_pass(ctx, ell, osrc, odst, pri, colors2, U)
        return colors2, U2, trace, r + 1, tot + n_def, ovf | ovf2

    trace = jnp.zeros((MAX_ROUNDS_TRACE,), jnp.int32)
    state = (colors1, U1, trace, jnp.int32(0), jnp.int32(0), ovf0)
    colors, U, trace, r, tot, ovf = jax.lax.while_loop(cond, body, state)
    return colors[:n], r, trace, tot, ovf


@functools.partial(jax.jit, static_argnames=("ctx",))
def _gm_round0(ell, osrc, odst, pri, ctx):
    n, n_pad, C, n_chunks, impl = ctx.unpack()
    colors0 = jnp.full((n_pad,), -1, jnp.int32)
    valid = jnp.arange(n_pad) < n
    zeros = jnp.zeros((n_pad,), bool)
    colors1, _, _, ovf = _chunked_pass(
        ctx, ell, osrc, odst, pri, colors0, zeros, valid, detect=False)
    defect = _detect_pass(ctx, ell, osrc, odst, pri, colors1, valid)
    return colors1, defect, ovf


@functools.partial(jax.jit, static_argnames=("n", "C", "max_rounds", "impl"))
def _jp_loop(src, dst, pri, n, C, max_rounds, impl=DEFAULT_FORBIDDEN_IMPL):
    colors0 = jnp.full((n,), -1, jnp.int32)

    def cond(s):
        return (s[0] < 0).any() & (s[1] < max_rounds)

    def body(s):
        colors, r, ovf = s
        uncolored = colors < 0
        nbr_pri = jnp.where(uncolored[dst], pri[dst], -1)
        best = jnp.full((n,), -1, jnp.int32).at[src].max(nbr_pri)
        elig = uncolored & (pri > best)
        forb = _snapshot_coo(src, dst, colors, n, C, impl)
        mex, o = _mex_of(forb, C, impl)
        colors = jnp.where(elig, mex, colors)
        return colors, r + 1, ovf | (o & elig).any()

    colors, r, ovf = jax.lax.while_loop(
        cond, body, (colors0, jnp.int32(0), jnp.bool_(False)))
    return colors, r, ovf


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------

def _run_with_retry(run, C: int, *, engine: str = "",
                    max_retries: Optional[int] = None):
    """Run ``run(C)``, doubling the color cap until it fits.

    ``run`` returns any tuple whose LAST element is the boolean overflow
    flag.  This is the single cap-doubling loop shared by every engine
    (from-scratch, frontier-compacted, JP, native distance-2, incremental)
    — they differ only in the closure they pass.  Returns
    (run output, final C, number of cap-doubling retries).

    ``max_retries`` bounds the doublings (``ColoringSpec.max_cap_retries``):
    a pathological graph/cap pair raises ``CapRetryExhausted`` instead of
    spinning, and the dynamic stack degrades through its ladder (DESIGN.md
    §14.2).  ``None`` keeps the legacy unbounded loop bit-exactly.  The
    ``cap.exhaust`` fault site rides here too — host-side, before the
    dispatch, so faults-off runs compile byte-identical programs.

    Observability rides here precisely because every engine funnels through:
    each attempt is a ``solve`` phase on the current tracer (blocking on the
    outputs so the wall time is real), and each doubling bumps the
    ``engine.cap_retry{engine=...}`` counter.  With no tracer and no armed
    faults the only addition over the pre-obs loop is two None checks per
    attempt.
    """
    retries = 0
    while True:
        if faults.fires("cap.exhaust", engine=engine):
            raise CapRetryExhausted(engine=engine, C=C, retries=retries,
                                    budget=max_retries, forced=True)
        tracer = obs.current_tracer()
        if tracer is None:
            out = run(C)
        else:
            with tracer.phase("solve", C=int(C), attempt=retries):
                out = jax.block_until_ready(run(C))
        if not bool(out[-1]):
            return out, C, retries
        if max_retries is not None and retries >= max_retries:
            raise CapRetryExhausted(engine=engine, C=C, retries=retries,
                                    budget=max_retries)
        C *= 2  # rare: color cap exceeded -> retry with doubled cap
        retries += 1
        obs.metrics.counter("engine.cap_retry",
                            engine=engine or "unknown").inc()


def _prob_runner(loop, prob: ColoringProblem, n_chunks: int, max_rounds: int,
                 impl: str, trace: bool = False):
    """Adapt the standard from-scratch loop signature to ``_run_with_retry``."""
    def run(C):
        ctx = PassContext.for_problem(prob, n_chunks=n_chunks, C=C,
                                      forbidden_impl=impl, trace=trace)
        return loop(prob.ell, prob.ovf_src, prob.ovf_dst, prob.pri,
                    ctx, max_rounds)
    return run


def _loop_outputs(out, traced: bool):
    """Split a retry-loop output tuple into (colors, r, trace, ftrace, tot).

    The traced program returns six elements (frontier trace spliced before
    the trailing (tot, ovf) pair), the plain program five; ftrace is None
    when the loop did not collect one.
    """
    if traced:
        colors, r, trace, ftrace, tot, _ = out
        return colors, r, trace, ftrace, tot
    colors, r, trace, tot, _ = out
    return colors, r, trace, None, tot


def _report_frontier(tracer, ftrace, r, cap=None):
    """Hand a loop-carried frontier trace to the tracer, clipped like the
    conflict trace is."""
    if tracer is not None and ftrace is not None:
        trimmed = np.asarray(ftrace).reshape(-1)[
            :min(int(r), MAX_ROUNDS_TRACE)]
        tracer.set_frontier_trace(trimmed, cap=cap)


# --------------------------------------------------------------------------
# registered engines (the implementations behind repro.api.color)
# --------------------------------------------------------------------------

@registry.register_engine("rsoc", distance=1, mode="static",
                          replaces="color_rsoc")
def _rsoc_engine(g: CSRGraph, spec) -> ColoringResult:
    """RSOC (paper Alg. 3): fused detect-and-recolor, one pass per round."""
    impl = resolve_impl(spec.forbidden_impl)
    tracer = obs.current_tracer()
    with obs.phase("prepare"):
        prob = prepare(g, spec.seed, spec.n_chunks, spec.ell_cap, spec.C,
                       spec.relabel)
    out, final_C, retries = _run_with_retry(
        _prob_runner(_rsoc_loop, prob, spec.n_chunks, spec.max_rounds, impl,
                     trace=tracer is not None),
        prob.C, engine="rsoc", max_retries=spec.max_cap_retries)
    colors, r, trace, ftrace, tot = _loop_outputs(out, tracer is not None)
    _report_frontier(tracer, ftrace, r)
    conf, truncated = _trim_trace(trace, r)
    colors = _unpermute(colors, prob.perm, prob.n)
    return ColoringResult(colors=colors, n_rounds=int(r),
                          conflicts_per_round=conf,
                          total_conflicts=int(tot),
                          n_colors=n_colors_used(colors),
                          overflow=retries > 0,
                          gather_passes=1 + int(r),
                          final_C=final_C, retries=retries,
                          trace_truncated=truncated)


@registry.register_engine("cat", distance=1, mode="static",
                          replaces="color_cat")
def _cat_engine(g: CSRGraph, spec) -> ColoringResult:
    """Catalyurek et al. (paper Alg. 2): two-phase rounds."""
    impl = resolve_impl(spec.forbidden_impl)
    tracer = obs.current_tracer()
    with obs.phase("prepare"):
        prob = prepare(g, spec.seed, spec.n_chunks, spec.ell_cap, spec.C,
                       spec.relabel)
    (colors, r, trace, tot, _), final_C, retries = _run_with_retry(
        _prob_runner(_cat_loop, prob, spec.n_chunks, spec.max_rounds, impl),
        prob.C, engine="cat", max_retries=spec.max_cap_retries)
    conf, truncated = _trim_trace(trace, r)
    # CAT's frontier IS its conflict count: a round re-colors exactly the
    # defect set U detected by the previous phase B, so no extra device
    # collection is needed (the traced and untraced programs are identical).
    _report_frontier(tracer, conf, r)
    colors = _unpermute(colors, prob.perm, prob.n)
    return ColoringResult(colors=colors, n_rounds=int(r),
                          conflicts_per_round=conf,
                          total_conflicts=int(tot),
                          n_colors=n_colors_used(colors),
                          overflow=retries > 0,
                          gather_passes=2 * (1 + int(r)),
                          final_C=final_C, retries=retries,
                          trace_truncated=truncated)


@registry.register_engine("gm", distance=1, mode="static",
                          replaces="color_gm")
def _gm_engine(g: CSRGraph, spec) -> ColoringResult:
    """Gebremedhin-Manne: speculate, detect, serial repair (one round —
    ``spec.max_rounds`` is inert for this engine)."""
    impl = resolve_impl(spec.forbidden_impl)
    with obs.phase("prepare"):
        prob = prepare(g, spec.seed, spec.n_chunks, spec.ell_cap, spec.C,
                       spec.relabel)
    ctx = PassContext.for_problem(prob, n_chunks=spec.n_chunks,
                                  forbidden_impl=impl)
    with obs.phase("solve", C=prob.C):
        colors, defect, ovf = jax.block_until_ready(
            _gm_round0(prob.ell, prob.ovf_src, prob.ovf_dst, prob.pri, ctx))
    colors_np = np.asarray(colors[:prob.n]).copy()
    defect_np = np.asarray(defect[:prob.n])
    # serial repair in the *relabeled* space: rebuild neighbor lists from ELL
    # plus the COO overflow side-channel (capped-width hub rows spill there —
    # skipping it produced improper repairs on power-law graphs).
    with obs.phase("serial_repair",
                         n_defects=int(defect_np.sum())):
        ell_np = np.asarray(prob.ell)
        osrc_np = np.asarray(prob.ovf_src)
        odst_np = np.asarray(prob.ovf_dst)
        order = np.argsort(osrc_np, kind="stable")
        osrc_sorted, odst_sorted = osrc_np[order], odst_np[order]
        for v in np.nonzero(defect_np)[0]:
            nb = ell_np[v]
            nb = nb[(nb >= 0) & (nb < prob.n)]
            if len(osrc_sorted):
                lo, hi = np.searchsorted(osrc_sorted, [v, v + 1])
                nb = np.concatenate([nb, odst_sorted[lo:hi]])
            nc = colors_np[nb]
            used = set(int(x) for x in nc if x >= 0)
            c = 0
            while c in used:
                c += 1
            colors_np[v] = c
    tot = int(defect_np.sum())
    colors_out = _unpermute(colors_np, prob.perm, prob.n)
    return ColoringResult(colors=colors_out, n_rounds=1,
                          conflicts_per_round=np.array([tot]),
                          total_conflicts=tot,
                          n_colors=n_colors_used(colors_out),
                          overflow=bool(ovf),
                          gather_passes=2, final_C=prob.C, retries=0)


@registry.register_engine("jp", distance=1, mode="static",
                          replaces="color_jp")
def _jp_engine(g: CSRGraph, spec) -> ColoringResult:
    """Jones-Plassmann priority-MIS baseline (COO formulation; the ELL/chunk
    fields of the spec — n_chunks, ell_cap, relabel — are inert here)."""
    impl = resolve_impl(spec.forbidden_impl)
    n = g.n_vertices
    with obs.phase("prepare"):
        e = to_edge_list(g)
        src = jnp.asarray(e[:, 0], jnp.int32)
        dst = jnp.asarray(e[:, 1], jnp.int32)
        pri = jnp.asarray(np.random.default_rng(spec.seed).permutation(n)
                          .astype(np.int32))
    (colors, r, _), Cv, retries = _run_with_retry(
        lambda Cv: _jp_loop(src, dst, pri, n, Cv, spec.max_rounds, impl),
        _pick_C(g, spec.C), engine="jp",
        max_retries=spec.max_cap_retries)
    colors = np.asarray(colors)
    if (colors < 0).any():
        # never silent: a JP round bound that is too small used to return a
        # partial coloring with -1 entries (the legacy color_jp default was
        # max_rounds=10000 vs the spec's 1000, so the spec path hits it
        # earlier on adversarial priority chains)
        raise RuntimeError(
            f"JP left {int((colors < 0).sum())} vertices uncolored after "
            f"max_rounds={spec.max_rounds}; raise ColoringSpec.max_rounds "
            f"(JP needs one round per step of its longest decreasing "
            f"priority path)")
    return ColoringResult(colors=colors, n_rounds=int(r),
                          conflicts_per_round=np.zeros(1),
                          total_conflicts=0,
                          n_colors=n_colors_used(colors),
                          overflow=retries > 0,
                          gather_passes=int(r),
                          final_C=Cv, retries=retries)


# --------------------------------------------------------------------------
# legacy entry points: thin deprecation shims over repro.api.color
# --------------------------------------------------------------------------

def color_rsoc(g: CSRGraph, seed: int = 0, C: Optional[int] = None,
               n_chunks: int = 16, max_rounds: int = 1000,
               ell_cap: int = 512, relabel: bool = True,
               forbidden_impl: Optional[str] = None) -> ColoringResult:
    """Deprecated: use ``repro.api.color(g, algorithm="rsoc", ...)``."""
    return registry.legacy_entry(
        "color_rsoc", "algorithm='rsoc'", g, algorithm="rsoc", seed=seed,
        C=C, n_chunks=n_chunks, max_rounds=max_rounds, ell_cap=ell_cap,
        relabel=relabel, forbidden_impl=forbidden_impl)


def color_cat(g: CSRGraph, seed: int = 0, C: Optional[int] = None,
              n_chunks: int = 16, max_rounds: int = 1000,
              ell_cap: int = 512, relabel: bool = True,
              forbidden_impl: Optional[str] = None) -> ColoringResult:
    """Deprecated: use ``repro.api.color(g, algorithm="cat", ...)``."""
    return registry.legacy_entry(
        "color_cat", "algorithm='cat'", g, algorithm="cat", seed=seed,
        C=C, n_chunks=n_chunks, max_rounds=max_rounds, ell_cap=ell_cap,
        relabel=relabel, forbidden_impl=forbidden_impl)


def color_gm(g: CSRGraph, seed: int = 0, C: Optional[int] = None,
             n_chunks: int = 16, ell_cap: int = 512,
             relabel: bool = True,
             forbidden_impl: Optional[str] = None) -> ColoringResult:
    """Deprecated: use ``repro.api.color(g, algorithm="gm", ...)``."""
    return registry.legacy_entry(
        "color_gm", "algorithm='gm'", g, algorithm="gm", seed=seed,
        C=C, n_chunks=n_chunks, ell_cap=ell_cap, relabel=relabel,
        forbidden_impl=forbidden_impl)


def color_jp(g: CSRGraph, seed: int = 0, C: Optional[int] = None,
             max_rounds: int = 10000,
             forbidden_impl: Optional[str] = None) -> ColoringResult:
    """Deprecated: use ``repro.api.color(g, algorithm="jp", ...)``."""
    return registry.legacy_entry(
        "color_jp", "algorithm='jp'", g, algorithm="jp", seed=seed, C=C,
        max_rounds=max_rounds, forbidden_impl=forbidden_impl)


class _AlgorithmsView(Mapping):
    """``ALGORITHMS`` as a live registry view (DESIGN.md §11).

    Keys are the algorithm names registered for the classic combo
    (distance=1, mode="static", backend="local"); values are callables
    ``fn(g, **spec_overrides) -> ColoringResult`` that route through
    ``repro.api.color`` — the supported bulk interface, so unlike the
    ``color_*`` shims it does not emit deprecation warnings.
    """

    def _names(self) -> list[str]:
        from repro import api
        return api.algorithms()   # the (1, "static", "local") slice

    def __getitem__(self, name: str):
        if name not in self._names():
            raise KeyError(name)

        def run(g, **overrides):
            from repro import api
            return api.color(g, algorithm=name, **overrides)

        run.__name__ = f"color_via_registry[{name}]"
        return run

    def __iter__(self):
        return iter(self._names())

    def __len__(self) -> int:
        return len(self._names())

    def __repr__(self) -> str:
        return f"ALGORITHMS({', '.join(self._names())})"


ALGORITHMS = _AlgorithmsView()
