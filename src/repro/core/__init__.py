"""The paper's contribution: optimistic parallel graph coloring (RSOC) and its
predecessors, adapted for lockstep SPMD (TPU/JAX) execution, single-device and
multi-device (shard_map halo/replicated exchange).
"""
from repro.core.context import (  # noqa: F401
    DEFAULT_FORBIDDEN_IMPL, PassContext, resolve_impl,
)
from repro.core.coloring import (  # noqa: F401
    ALGORITHMS, ColoringResult, color_cat, color_gm, color_jp, color_rsoc,
    greedy_sequential, is_proper, n_colors_used,
)
from repro.core.frontier import color_rsoc_compact  # noqa: F401
from repro.core.distance2 import (  # noqa: F401
    color_bipartite_partial, color_distance2, color_distance_d,
    is_bipartite_partial_proper, is_distance_d_proper,
)
