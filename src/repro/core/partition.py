"""Vertex partitioning + halo metadata for distributed coloring.

Baseline distributed scheme replicates the color vector and re-replicates it
with one ``all_gather`` per round.  The optimized scheme (EXPERIMENTS.md §Perf)
exchanges only *boundary* colors; this module builds the static metadata both
need:

  * block partition of [0, n) into D contiguous shards (after a
    *block-preserving* relabel: vertices are shuffled within their shard so
    chunks decorrelate, but shard membership — and hence partition locality —
    is preserved),
  * per-shard boundary list (my vertices referenced by other shards), padded
    to the max across shards,
  * per-shard ghost table (external vertices I reference) with (owner shard,
    slot in owner's boundary list) coordinates, padded likewise,
  * an ELL remap: neighbor ids -> local slot [0, n_loc) or ghost slot
    n_loc + g.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.csr import CSRGraph, FILL, from_edges, to_edge_list


@dataclasses.dataclass(frozen=True)
class Partition:
    n: int
    n_pad: int               # n rounded up to D * n_loc
    n_shards: int
    n_loc: int
    perm: np.ndarray          # old id -> new id (block-preserving shuffle)
    graph: CSRGraph           # relabeled graph


@dataclasses.dataclass(frozen=True)
class HaloPlan:
    boundary: np.ndarray      # (D, max_b) local slots I must publish, FILL pad
    n_boundary: np.ndarray    # (D,)
    ghost_owner: np.ndarray   # (D, max_g) owning shard of each ghost, FILL pad
    ghost_slot: np.ndarray    # (D, max_g) slot in owner's boundary list
    ell_local: np.ndarray     # (D, n_loc, W) remapped ELL: [0,n_loc) local,
                              # n_loc+g ghosts, FILL pad
    max_b: int
    max_g: int


def block_partition(g: CSRGraph, n_shards: int, seed: int = 0,
                    rng: np.random.Generator | None = None) -> Partition:
    """``rng`` lets a caller share one numpy stream across the partition
    shuffle and its own later draws (the sharded encoder threads the same
    generator through here and the priority draw, so a 1-shard partition
    replays ``core.coloring.prepare``'s stream exactly)."""
    n = g.n_vertices
    n_loc = -(-n // n_shards)
    n_pad = n_loc * n_shards
    rng = np.random.default_rng(seed) if rng is None else rng
    # shuffle within each shard's contiguous block only
    perm = np.arange(n, dtype=np.int64)
    for d in range(n_shards):
        lo, hi = d * n_loc, min((d + 1) * n_loc, n)
        if hi > lo:
            block = perm[lo:hi].copy()
            rng.shuffle(block)
            perm[lo:hi] = block
    # perm maps old->new within blocks; relabel edges
    inv = np.empty(n, dtype=np.int64)
    inv[perm] = np.arange(n)
    edges = to_edge_list(g).astype(np.int64)
    edges = perm[edges]
    g2 = from_edges(n, edges, symmetrize=False)
    return Partition(n=n, n_pad=n_pad, n_shards=n_shards, n_loc=n_loc,
                     perm=perm, graph=g2)


def build_halo(part: Partition, ell_width: int | None = None) -> HaloPlan:
    g, D, n_loc, n = part.graph, part.n_shards, part.n_loc, part.n
    W = ell_width or max(1, g.max_degree)
    if g.max_degree > W:
        raise ValueError("halo plan requires ell width >= max degree")
    shard_of = lambda v: np.minimum(v // n_loc, D - 1)

    boundary_sets = [set() for _ in range(D)]
    ghost_sets = [set() for _ in range(D)]
    e = to_edge_list(g).astype(np.int64)
    s_src, s_dst = shard_of(e[:, 0]), shard_of(e[:, 1])
    cross = s_src != s_dst
    for u, v, du, dv in zip(e[cross, 0], e[cross, 1], s_src[cross], s_dst[cross]):
        ghost_sets[du].add(int(v))     # u references remote v
        boundary_sets[dv].add(int(v))  # v must be published by its owner
    boundary_lists = [np.sort(np.fromiter(b, np.int64, len(b))) for b in boundary_sets]
    ghost_lists = [np.sort(np.fromiter(s, np.int64, len(s))) for s in ghost_sets]
    max_b = max(1, max(len(b) for b in boundary_lists))
    max_g = max(1, max(len(s) for s in ghost_lists))

    boundary = np.full((D, max_b), FILL, np.int32)
    n_boundary = np.zeros((D,), np.int32)
    ghost_owner = np.full((D, max_g), FILL, np.int32)
    ghost_slot = np.full((D, max_g), FILL, np.int32)
    for d in range(D):
        b = boundary_lists[d]
        boundary[d, :len(b)] = b - d * n_loc  # local slots
        n_boundary[d] = len(b)
    # slot of vertex v in its owner's boundary list
    slot_of = {}
    for d in range(D):
        for i, v in enumerate(boundary_lists[d]):
            slot_of[int(v)] = i
    for d in range(D):
        for i, v in enumerate(ghost_lists[d]):
            ghost_owner[d, i] = shard_of(v)
            ghost_slot[d, i] = slot_of[int(v)]

    # remapped ELL per shard
    ell_local = np.full((D, n_loc, W), FILL, np.int32)
    deg = g.degrees
    row = np.repeat(np.arange(n), deg)
    col = np.arange(g.n_edges) - np.repeat(g.indptr[:-1], deg)
    dst = g.indices.astype(np.int64)
    dshard = shard_of(row)
    nshard = shard_of(dst)
    local_rows = row - dshard * n_loc
    # local neighbors -> local slot
    same = dshard == nshard
    ell_local[dshard[same], local_rows[same], col[same]] = (dst[same] - nshard[same] * n_loc)
    # remote neighbors -> n_loc + ghost index (searchsorted in my ghost list)
    for d in range(D):
        m = (~same) & (dshard == d)
        if m.any():
            gidx = np.searchsorted(ghost_lists[d], dst[m])
            ell_local[d, local_rows[m], col[m]] = n_loc + gidx
    return HaloPlan(boundary=boundary, n_boundary=n_boundary,
                    ghost_owner=ghost_owner, ghost_slot=ghost_slot,
                    ell_local=ell_local, max_b=max_b, max_g=max_g)


@dataclasses.dataclass(frozen=True)
class MutableHaloPlan:
    """Halo metadata over the *mutable* per-shard ELL+overflow layout
    (DESIGN.md §15): unlike ``HaloPlan`` the row tables carry slack (extra
    FILL columns per row, spare boundary/ghost capacity) so edge inserts
    land in place instead of forcing an immediate re-plan, and hub rows
    spill to a per-shard overflow COO exactly like the single-device
    mutable encode."""

    ell_local: np.ndarray     # (D, n_loc, W+slack) slot-space ELL, FILL pad
    ovf_src: np.ndarray       # (D, ovf_cap) per-shard overflow COO rows
    ovf_dst: np.ndarray       # (D, ovf_cap) slot-space overflow targets
    boundary: np.ndarray      # (D, max_b_cap) local slots to publish, FILL
    n_boundary: np.ndarray    # (D,) live boundary slots
    ghost_ids: np.ndarray     # (D, max_g_cap) global (relabeled) ghost ids
    ghost_flat: np.ndarray    # (D, max_g_cap) owner*max_b_cap + slot, FILL
    n_ghost: np.ndarray       # (D,) live ghost slots
    n_loc: int                # row-table height (>= partition block size)
    max_b_cap: int
    max_g_cap: int
    ell_width: int            # W before slack columns


def _slack_cap(k: int, lo: int = 8) -> int:
    """Capacity with ~25% (min 8 slots) headroom so the first few inserts
    never trigger a re-plan."""
    return max(lo, k + max(8, k // 4))


def build_halo_mutable(part: Partition, *, n_loc: int | None = None,
                       ell_cap: int = 512, ell_slack: int = 4,
                       ovf_cap: int | None = None, delta_cap: int = 2048,
                       min_b_cap: int = 0,
                       min_g_cap: int = 0) -> MutableHaloPlan:
    """Mutable-ELL halo plan: per-shard slot-space neighbor tables with
    slack, overflow spill for hub rows, and capacity-slacked boundary/ghost
    arrays.  ``n_loc`` overrides the row-table height (the sharded engine
    passes the chunk-aligned height so each shard's sweep divides evenly);
    shard *membership* always follows ``part.n_loc`` blocks.  On a 1-shard
    partition the ELL/overflow arrays are bit-identical to
    ``core.coloring.prepare``'s mutable encode of the same graph."""
    g, D, blk, n = part.graph, part.n_shards, part.n_loc, part.n
    n_loc = blk if n_loc is None else int(n_loc)
    if n_loc < blk:
        raise ValueError(f"n_loc={n_loc} below partition block size {blk}")
    shard_of = lambda v: np.minimum(v // blk, D - 1)
    W = max(1, min(g.max_degree, ell_cap))

    # ghost/boundary membership from ALL cross edges (ELL or overflow alike:
    # an overflow edge's remote endpoint still needs a ghost color slot)
    boundary_sets = [set() for _ in range(D)]
    ghost_sets = [set() for _ in range(D)]
    e = to_edge_list(g).astype(np.int64)
    if len(e):
        s_src, s_dst = shard_of(e[:, 0]), shard_of(e[:, 1])
        cross = s_src != s_dst
        for v, du, dv in zip(e[cross, 1], s_src[cross], s_dst[cross]):
            ghost_sets[du].add(int(v))     # u references remote v
            boundary_sets[dv].add(int(v))  # v must be published by its owner
    boundary_lists = [np.sort(np.fromiter(b, np.int64, len(b)))
                      for b in boundary_sets]
    ghost_lists = [np.sort(np.fromiter(s, np.int64, len(s)))
                   for s in ghost_sets]
    max_b_cap = max(_slack_cap(max(len(b) for b in boundary_lists)),
                    int(min_b_cap))
    max_g_cap = max(_slack_cap(max(len(s) for s in ghost_lists)),
                    int(min_g_cap))

    boundary = np.full((D, max_b_cap), FILL, np.int32)
    n_boundary = np.zeros((D,), np.int32)
    slot_of = {}
    for d in range(D):
        b = boundary_lists[d]
        boundary[d, :len(b)] = (b - d * blk).astype(np.int32)
        n_boundary[d] = len(b)
        for i, v in enumerate(b):
            slot_of[int(v)] = i
    ghost_ids = np.full((D, max_g_cap), FILL, np.int64)
    ghost_flat = np.full((D, max_g_cap), FILL, np.int32)
    n_ghost = np.zeros((D,), np.int32)
    for d in range(D):
        gl = ghost_lists[d]
        ghost_ids[d, :len(gl)] = gl
        n_ghost[d] = len(gl)
        for i, v in enumerate(gl):
            ghost_flat[d, i] = shard_of(v) * max_b_cap + slot_of[int(v)]

    # slot-space ELL + per-shard overflow spill, in CSR order (bit-identical
    # to prepare()'s hub spill on a 1-shard partition)
    deg = g.degrees
    row = np.repeat(np.arange(n), deg)
    col = np.arange(g.n_edges) - np.repeat(g.indptr[:-1], deg)
    dst = g.indices.astype(np.int64)
    dshard = shard_of(row)
    nshard = shard_of(dst)
    local_rows = row - dshard * blk
    slot = np.empty(len(dst), np.int64)
    same = dshard == nshard
    slot[same] = dst[same] - nshard[same] * blk
    for d in range(D):
        m = (~same) & (dshard == d)
        if m.any():
            slot[m] = n_loc + np.searchsorted(ghost_lists[d], dst[m])
    in_ell = col < W
    ell_local = np.full((D, n_loc, W + ell_slack), FILL, np.int32)
    ell_local[dshard[in_ell], local_rows[in_ell], col[in_ell]] = \
        slot[in_ell].astype(np.int32)
    spill = ~in_ell
    n_ovf_max = max((int(np.sum(spill & (dshard == d))) for d in range(D)),
                    default=0)
    cap = (int(ovf_cap) if ovf_cap is not None
           else max(64, 2 * n_ovf_max, delta_cap // 2))
    cap = max(cap, n_ovf_max, 8)
    ovf_src = np.full((D, cap), FILL, np.int32)
    ovf_dst = np.full((D, cap), FILL, np.int32)
    for d in range(D):
        m = spill & (dshard == d)
        k = int(m.sum())
        if k:
            ovf_src[d, :k] = local_rows[m].astype(np.int32)
            ovf_dst[d, :k] = slot[m].astype(np.int32)
    return MutableHaloPlan(
        ell_local=ell_local, ovf_src=ovf_src, ovf_dst=ovf_dst,
        boundary=boundary, n_boundary=n_boundary, ghost_ids=ghost_ids,
        ghost_flat=ghost_flat, n_ghost=n_ghost, n_loc=n_loc,
        max_b_cap=max_b_cap, max_g_cap=max_g_cap, ell_width=W)


def partition_stats(part: Partition) -> dict:
    e = to_edge_list(part.graph).astype(np.int64)
    s = np.minimum(e // part.n_loc, part.n_shards - 1)
    cross_m = (s[:, 0] != s[:, 1]) if len(e) else np.zeros(0, bool)
    cross = cross_m.mean() if len(e) else 0.0
    # boundary vertices: endpoints some *other* shard references (the edge
    # list carries both directions, so dst-side endpoints cover the set)
    bverts = np.unique(e[cross_m, 1]) if len(e) else np.zeros(0, np.int64)
    if len(bverts):
        owners = np.minimum(bverts // part.n_loc, part.n_shards - 1)
        max_b = int(np.bincount(owners, minlength=part.n_shards).max())
    else:
        max_b = 0
    # one halo exchange gathers (max_b colors + 1 count) int32 per shard
    # (the static build_rsoc_halo payload); O(boundary), not O(n)
    return {"cross_edge_frac": float(cross), "n_shards": part.n_shards,
            "n_loc": part.n_loc,
            "boundary_frac": float(len(bverts) / max(1, part.n)),
            "halo_bytes_per_round": int(part.n_shards * (max_b + 1) * 4)}
