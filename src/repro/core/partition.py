"""Vertex partitioning + halo metadata for distributed coloring.

Baseline distributed scheme replicates the color vector and re-replicates it
with one ``all_gather`` per round.  The optimized scheme (EXPERIMENTS.md §Perf)
exchanges only *boundary* colors; this module builds the static metadata both
need:

  * block partition of [0, n) into D contiguous shards (after a
    *block-preserving* relabel: vertices are shuffled within their shard so
    chunks decorrelate, but shard membership — and hence partition locality —
    is preserved),
  * per-shard boundary list (my vertices referenced by other shards), padded
    to the max across shards,
  * per-shard ghost table (external vertices I reference) with (owner shard,
    slot in owner's boundary list) coordinates, padded likewise,
  * an ELL remap: neighbor ids -> local slot [0, n_loc) or ghost slot
    n_loc + g.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.csr import CSRGraph, FILL, from_edges, to_edge_list


@dataclasses.dataclass(frozen=True)
class Partition:
    n: int
    n_pad: int               # n rounded up to D * n_loc
    n_shards: int
    n_loc: int
    perm: np.ndarray          # old id -> new id (block-preserving shuffle)
    graph: CSRGraph           # relabeled graph


@dataclasses.dataclass(frozen=True)
class HaloPlan:
    boundary: np.ndarray      # (D, max_b) local slots I must publish, FILL pad
    n_boundary: np.ndarray    # (D,)
    ghost_owner: np.ndarray   # (D, max_g) owning shard of each ghost, FILL pad
    ghost_slot: np.ndarray    # (D, max_g) slot in owner's boundary list
    ell_local: np.ndarray     # (D, n_loc, W) remapped ELL: [0,n_loc) local,
                              # n_loc+g ghosts, FILL pad
    max_b: int
    max_g: int


def block_partition(g: CSRGraph, n_shards: int, seed: int = 0) -> Partition:
    n = g.n_vertices
    n_loc = -(-n // n_shards)
    n_pad = n_loc * n_shards
    rng = np.random.default_rng(seed)
    # shuffle within each shard's contiguous block only
    perm = np.arange(n, dtype=np.int64)
    for d in range(n_shards):
        lo, hi = d * n_loc, min((d + 1) * n_loc, n)
        if hi > lo:
            block = perm[lo:hi].copy()
            rng.shuffle(block)
            perm[lo:hi] = block
    # perm maps old->new within blocks; relabel edges
    inv = np.empty(n, dtype=np.int64)
    inv[perm] = np.arange(n)
    edges = to_edge_list(g).astype(np.int64)
    edges = perm[edges]
    g2 = from_edges(n, edges, symmetrize=False)
    return Partition(n=n, n_pad=n_pad, n_shards=n_shards, n_loc=n_loc,
                     perm=perm, graph=g2)


def build_halo(part: Partition, ell_width: int | None = None) -> HaloPlan:
    g, D, n_loc, n = part.graph, part.n_shards, part.n_loc, part.n
    W = ell_width or max(1, g.max_degree)
    if g.max_degree > W:
        raise ValueError("halo plan requires ell width >= max degree")
    shard_of = lambda v: np.minimum(v // n_loc, D - 1)

    boundary_sets = [set() for _ in range(D)]
    ghost_sets = [set() for _ in range(D)]
    e = to_edge_list(g).astype(np.int64)
    s_src, s_dst = shard_of(e[:, 0]), shard_of(e[:, 1])
    cross = s_src != s_dst
    for u, v, du, dv in zip(e[cross, 0], e[cross, 1], s_src[cross], s_dst[cross]):
        ghost_sets[du].add(int(v))     # u references remote v
        boundary_sets[dv].add(int(v))  # v must be published by its owner
    boundary_lists = [np.sort(np.fromiter(b, np.int64, len(b))) for b in boundary_sets]
    ghost_lists = [np.sort(np.fromiter(s, np.int64, len(s))) for s in ghost_sets]
    max_b = max(1, max(len(b) for b in boundary_lists))
    max_g = max(1, max(len(s) for s in ghost_lists))

    boundary = np.full((D, max_b), FILL, np.int32)
    n_boundary = np.zeros((D,), np.int32)
    ghost_owner = np.full((D, max_g), FILL, np.int32)
    ghost_slot = np.full((D, max_g), FILL, np.int32)
    for d in range(D):
        b = boundary_lists[d]
        boundary[d, :len(b)] = b - d * n_loc  # local slots
        n_boundary[d] = len(b)
    # slot of vertex v in its owner's boundary list
    slot_of = {}
    for d in range(D):
        for i, v in enumerate(boundary_lists[d]):
            slot_of[int(v)] = i
    for d in range(D):
        for i, v in enumerate(ghost_lists[d]):
            ghost_owner[d, i] = shard_of(v)
            ghost_slot[d, i] = slot_of[int(v)]

    # remapped ELL per shard
    ell_local = np.full((D, n_loc, W), FILL, np.int32)
    deg = g.degrees
    row = np.repeat(np.arange(n), deg)
    col = np.arange(g.n_edges) - np.repeat(g.indptr[:-1], deg)
    dst = g.indices.astype(np.int64)
    dshard = shard_of(row)
    nshard = shard_of(dst)
    local_rows = row - dshard * n_loc
    # local neighbors -> local slot
    same = dshard == nshard
    ell_local[dshard[same], local_rows[same], col[same]] = (dst[same] - nshard[same] * n_loc)
    # remote neighbors -> n_loc + ghost index (searchsorted in my ghost list)
    for d in range(D):
        m = (~same) & (dshard == d)
        if m.any():
            gidx = np.searchsorted(ghost_lists[d], dst[m])
            ell_local[d, local_rows[m], col[m]] = n_loc + gidx
    return HaloPlan(boundary=boundary, n_boundary=n_boundary,
                    ghost_owner=ghost_owner, ghost_slot=ghost_slot,
                    ell_local=ell_local, max_b=max_b, max_g=max_g)


def partition_stats(part: Partition) -> dict:
    e = to_edge_list(part.graph).astype(np.int64)
    s = np.minimum(e // part.n_loc, part.n_shards - 1)
    cross = (s[:, 0] != s[:, 1]).mean() if len(e) else 0.0
    return {"cross_edge_frac": float(cross), "n_shards": part.n_shards,
            "n_loc": part.n_loc}
