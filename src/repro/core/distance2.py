"""Distance-2 coloring: native fused two-hop engine + materialized oracle.

The paper's §6 outlook argues RSOC's edge over CAT grows with density, making
it the natural engine for distance-2 coloring — but materializing G² costs
|E(G²)| ≈ n·deg² memory plus a full ELL conversion per call, which rules out
exactly the dense workloads where the prediction bites.  The native engine
here colors G² *without ever constructing it*: one fused **two-hop gather
pass** walks the ELL tile twice (for each vertex: neighbor colors, then each
neighbor's own ELL row) and feeds a single (rows, C) forbidden table, wired
into the same speculative detect-and-recolor loop as distance-1 RSOC
(``coloring._chunked_pass``-style chunking, ``frontier._compact_repair``
frontier compaction).  Working set per round: n·W + chunk·W² gathered words
instead of n·W² resident ELL — and no G² CSR ever exists.

Semantics: vertex v's forbidden set is the colors of every u ≠ v within
distance ≤ 2; defects are broken asymmetrically by the same hashed priority
as distance-1 (of a conflicting pair only the lower-priority endpoint
re-colors), so the termination argument of ``coloring.py`` carries over
verbatim — the conflict graph is G², not G, but the highest-priority
defective vertex still becomes permanently stable each round.

``color_bipartite_partial`` is the Jacobian-compression entry point
(Çatalyürek et al., arXiv:1205.3809; Taş & Kaya, arXiv:1701.02628):
distance-2 color only one side of a bipartite graph.  It is the same two-hop
pass restricted to a row mask — hop-1 neighbors (the other side) stay
uncolored, so only the two-hop (same-side, shared-neighbor) colors bite.

The materialized ``power_graph`` path is kept as the oracle
(``color_distance_d`` / ``is_distance_d_proper``); the native path requires
the full adjacency in ELL (no overflow side-channel — a two-hop walk through
a spilled COO edge would silently miss constraints) and raises when
``max_degree > ell_cap``.

The Pallas expression of the two-hop pass is ``kernels/twohop.py``
(dispatched via ``kernels.ops.twohop``); this module is the jnp reference
engine, bit-matched by the kernel parity tests.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import registry
from repro.graphs.csr import CSRGraph, power_graph, to_edge_list
from repro.core import bitset
from repro.core import coloring as col
from repro.core import frontier as fr
from repro.core.context import PassContext
from repro import obs


# --------------------------------------------------------------------------
# materialized oracle path (kept: the ground truth the native engine is
# differentially tested against)
# --------------------------------------------------------------------------

def color_distance_d(g: CSRGraph, d: int = 2, algorithm: str = "rsoc",
                     **kwargs) -> tuple[col.ColoringResult, CSRGraph]:
    """Color G^d by materializing the power graph (oracle path)."""
    gd = power_graph(g, d)
    fn = col.ALGORITHMS[algorithm]
    res = dataclasses.replace(fn(gd, **kwargs), distance=d)
    return res, gd


def is_distance_d_proper(g: CSRGraph, colors: np.ndarray, d: int) -> bool:
    return col.is_proper(power_graph(g, d), colors)


def is_bipartite_partial_proper(g: CSRGraph, n_left: int,
                                colors: np.ndarray) -> bool:
    """Proper one-sided distance-2 coloring: every pair of left vertices
    (ids < n_left) sharing a neighbor has distinct colors, all colored."""
    colors = np.asarray(colors)
    if (colors[:n_left] < 0).any():
        return False
    e = to_edge_list(power_graph(g, 2))
    sel = (e[:, 0] < n_left) & (e[:, 1] < n_left)
    e = e[sel]
    if len(e) == 0:
        return True
    return bool((colors[e[:, 0]] != colors[e[:, 1]]).all())


def bipartite_partial_oracle(g: CSRGraph, n_left: int) -> np.ndarray:
    """Serial greedy one-sided distance-2 coloring (host-side numpy oracle,
    the partial-coloring analogue of ``coloring.greedy_sequential``)."""
    colors = np.full(n_left, -1, dtype=np.int32)
    for v in range(n_left):
        used = set()
        for w in g.neighbors(v):
            for x in g.neighbors(w):
                if x != v and x < n_left and colors[x] >= 0:
                    used.add(int(colors[x]))
        c = 0
        while c in used:
            c += 1
        colors[v] = c
    return colors


# --------------------------------------------------------------------------
# native engine: fused two-hop gather
# --------------------------------------------------------------------------

def _twohop_gather(ell, colors, pri, row_ids, n_pad):
    """Colors/priorities of every vertex within two hops of each row.

    Returns (allc, allp), both (R, W + W²): hop-1 neighbor colors followed by
    hop-2 colors gathered through each neighbor's own ELL row.  Dead slots
    and the row vertex itself (always its own two-hop neighbor through any
    neighbor) carry -1, so they never forbid a color or flag a defect.
    """
    W = ell.shape[1]
    safe_rows = jnp.clip(row_ids, 0, n_pad - 1)
    e1 = ell[safe_rows]                               # (R, W) hop-1 ids
    live1 = e1 >= 0
    s1 = jnp.clip(e1, 0, n_pad - 1)
    nc1 = jnp.where(live1, colors[s1], -1)
    np1 = jnp.where(live1, pri[s1], -1)
    e2 = ell[s1.reshape(-1)].reshape(-1, W * W)       # (R, W²) hop-2 ids
    live2 = (jnp.repeat(live1, W, axis=1) & (e2 >= 0)
             & (e2 != row_ids[:, None]))              # self-exclusion
    s2 = jnp.clip(e2, 0, n_pad - 1)
    nc2 = jnp.where(live2, colors[s2], -1)
    np2 = jnp.where(live2, pri[s2], -1)
    return (jnp.concatenate([nc1, nc2], axis=1),
            jnp.concatenate([np1, np2], axis=1))


def _d2_chunked_pass(ctx, ell, pri, rows_mask, colors, U, force, *,
                     detect: bool):
    """One sequential two-hop sweep over n_chunks chunks.

    The distance-2 mirror of ``coloring._chunked_pass`` (same fused
    detect-and-recolor contract, fresh colors across chunks) with the
    neighbor gather replaced by the two-hop gather.  ``rows_mask`` is the
    set of rows that participate at all — ``arange < n`` for plain
    distance-2, the left-side mask for bipartite partial coloring.
    Returns (colors, recolored_mask, n_defects, overflowed).
    """
    n, n_pad, C, n_chunks, impl = ctx.unpack()
    cs = n_pad // n_chunks

    def chunk_body(k, carry):
        colors, recolored, n_def, ovf = carry
        lo = k * cs
        row_ids = lo + jnp.arange(cs, dtype=jnp.int32)
        U_k = jax.lax.dynamic_slice_in_dim(U, lo, cs, 0)
        force_k = jax.lax.dynamic_slice_in_dim(force, lo, cs, 0)
        valid_k = jax.lax.dynamic_slice_in_dim(rows_mask, lo, cs, 0)
        c_k = jax.lax.dynamic_slice_in_dim(colors, lo, cs, 0)
        pri_k = jax.lax.dynamic_slice_in_dim(pri, lo, cs, 0)
        allc, allp = _twohop_gather(ell, colors, pri, row_ids, n_pad)
        if detect:
            defect = ((allc == c_k[:, None]) & (c_k[:, None] >= 0)
                      & (allp > pri_k[:, None])).any(axis=1)
            work = valid_k & ((U_k & defect) | force_k)
            n_def = n_def + (valid_k & U_k & defect).sum(dtype=jnp.int32)
        else:
            work = valid_k & (U_k | force_k)
        forb = col._forbidden(allc, C, impl)
        mex, ovf_k = col._mex_of(forb, C, impl)
        newc = jnp.where(work, mex, c_k)
        colors = jax.lax.dynamic_update_slice_in_dim(colors, newc, lo, 0)
        recolored = jax.lax.dynamic_update_slice_in_dim(recolored, work, lo, 0)
        return colors, recolored, n_def, ovf | (ovf_k & work).any()

    init = (colors, jnp.zeros((n_pad,), bool), jnp.int32(0), jnp.bool_(False))
    return jax.lax.fori_loop(0, n_chunks, chunk_body, init)


def _d2_compact_pass(ctx, ell, pri, colors, idx, idx_valid):
    """Two-hop fused pass over a compacted frontier-index buffer (the
    distance-2 mirror of ``frontier._compact_pass``): gathers only the
    ≤ cap frontier rows, so repair rounds pay cap·W² instead of n·W²."""
    n, n_pad_s, C, n_chunks, impl = ctx.unpack()
    cap = idx.shape[0]
    cs = cap // n_chunks
    n_pad = colors.shape[0]

    def chunk_body(k, carry):
        colors, recolored, n_def, ovf = carry
        lo = k * cs
        ids = jax.lax.dynamic_slice_in_dim(idx, lo, cs, 0)
        live = jax.lax.dynamic_slice_in_dim(idx_valid, lo, cs, 0)
        ids_c = jnp.clip(ids, 0, n_pad - 1)
        c_k = colors[ids_c]
        pri_k = pri[ids_c]
        allc, allp = _twohop_gather(ell, colors, pri, ids_c, n_pad)
        defect = ((allc == c_k[:, None]) & (c_k[:, None] >= 0)
                  & (allp > pri_k[:, None])).any(axis=1) & live
        work = defect | (live & (c_k < 0))
        n_def = n_def + defect.sum(dtype=jnp.int32)
        forb = col._forbidden(allc, C, impl)
        mex, o = col._mex_of(forb, C, impl)
        # dead slots carry idx == n_pad: out-of-bounds -> dropped
        colors = colors.at[ids].set(jnp.where(work, mex, c_k), mode="drop")
        recolored = recolored.at[ids].max(work, mode="drop")
        return colors, recolored, n_def, ovf | (o & work).any()

    init = (colors, jnp.zeros((n_pad,), bool), jnp.int32(0), jnp.bool_(False))
    return jax.lax.fori_loop(0, n_chunks, chunk_body, init)


@functools.partial(jax.jit, static_argnames=("ctx", "cap", "max_rounds"))
def _d2_loop(ell, pri, rows_mask, ctx, cap, max_rounds):
    """Round 0 (tentative two-hop coloring of every masked row) followed by
    the frontier-compacted fused repair, with two-hop passes plugged into
    ``frontier._compact_repair``."""
    n, n_pad, C, n_chunks, impl = ctx.unpack()
    colors0 = jnp.full((n_pad,), -1, jnp.int32)
    zeros = jnp.zeros((n_pad,), bool)
    colors1, U, _, ovf0 = _d2_chunked_pass(
        ctx, ell, pri, rows_mask, colors0, zeros, rows_mask,
        detect=False)

    def pass_small(colors, idx, idx_valid):
        return _d2_compact_pass(ctx, ell, pri, colors, idx, idx_valid)

    def pass_big(colors, U, force):
        return _d2_chunked_pass(ctx, ell, pri, rows_mask, colors, U,
                                force, detect=True)

    # arity follows ctx.trace: the compacted repair splices a frontier
    # trace before the (tot, ovf) tail when tracing (see frontier.py)
    return fr._compact_repair(
        ctx, cap, pass_small, pass_big, colors1, U, max_rounds, ovf0)


# --------------------------------------------------------------------------
# native engine: drivers
# --------------------------------------------------------------------------

def native_ws_mb(g: CSRGraph, n_chunks: int = 16, C: Optional[int] = None,
                 impl: str = "bitset") -> float:
    """Honest peak working set (MB) of one native two-hop gather pass: G's
    ELL table, the (n,) color/priority vectors, one chunk's transient
    (cs, W + W²) gathered color+priority panels, and the chunk's packed
    forbidden table — the last three are exactly the terms the old bench
    estimate dropped (it counted the ELL and a colors-only panel).  Used by
    ``benchmarks/bench_distance2.py``; the kernel-level account is
    ``kernels.ops.twohop_vmem_bytes``.
    """
    W = max(g.max_degree, 1)
    cap = _pick_C_d2(g, C)
    n = g.n_vertices
    cs = -(-n // max(int(n_chunks), 1))
    ell_bytes = n * W * 4
    vec_bytes = 2 * n * 4
    gather_bytes = 2 * cs * (W + W * W) * 4     # colors + priorities panels
    forb_bytes = bitset.ws_bytes(cs, cap, impl)
    return (ell_bytes + vec_bytes + gather_bytes + forb_bytes) / 2**20


def _pick_C_d2(g: CSRGraph, C: Optional[int]) -> int:
    if C is not None:
        return int(C)
    # distance-2 degree is bounded by deg² but typically far smaller
    # (neighborhoods overlap); start moderately generous — the packed-bitset
    # forbidden rows cost C/8 bytes, so doubling the old 256 default costs
    # what 64 dense colors used to, and saves cap-doubling retries exactly
    # where C is largest (this engine's tables dominate the working set).
    c = min(g.max_degree * g.max_degree + 2, 512)
    return int(max(32, -(-c // 32) * 32))


def _prepare_native(g: CSRGraph, seed: int, n_chunks: int, C: Optional[int],
                    relabel: bool, ell_cap: int) -> col.ColoringProblem:
    if g.max_degree > ell_cap:
        raise ValueError(
            f"native distance-2 needs the full adjacency in ELL: max_degree "
            f"{g.max_degree} > ell_cap {ell_cap} (two-hop walks cannot cross "
            f"the COO overflow side-channel; use color_distance_d instead)")
    prob = col.prepare(g, seed, n_chunks, ell_cap=max(g.max_degree, 1),
                       C=_pick_C_d2(g, C), relabel=relabel)
    assert prob.ovf_src.shape[0] == 0
    return prob


def _run_d2_with_retry(prob: col.ColoringProblem, rows_mask, n_chunks: int,
                       cap: int, max_rounds: int, impl: str,
                       engine: str = "rsoc_d2", trace: bool = False,
                       max_retries=None):
    def run(C):
        ctx = PassContext.for_problem(prob, n_chunks=n_chunks, C=C,
                                      forbidden_impl=impl, trace=trace)
        return _d2_loop(prob.ell, prob.pri, rows_mask, ctx, cap,
                        max_rounds)
    return col._run_with_retry(run, prob.C, engine=engine,
                               max_retries=max_retries)


def _d2_result(colors, r, trace, tot, final_C, retries,
               truncated: bool = False) -> col.ColoringResult:
    return col.ColoringResult(
        colors=colors, n_rounds=int(r),
        conflicts_per_round=np.asarray(trace), total_conflicts=int(tot),
        n_colors=col.n_colors_used(colors), overflow=retries > 0,
        gather_passes=1 + int(r), final_C=final_C, retries=retries,
        distance=2, trace_truncated=truncated)


@registry.register_engine("rsoc", distance=2, mode="static",
                          replaces="color_distance2")
def _distance2_engine(g: CSRGraph, spec) -> col.ColoringResult:
    """Native distance-2 RSOC: fused two-hop gather, G² never materialized."""
    impl = col._resolve_impl(spec.forbidden_impl)
    tracer = obs.current_tracer()
    with obs.phase("prepare"):
        prob = _prepare_native(g, spec.seed, spec.n_chunks, spec.C,
                               spec.relabel, spec.ell_cap)
    cap = fr.frontier_cap(prob.n_pad, spec.n_chunks, spec.frontier_frac)
    rows_mask = jnp.arange(prob.n_pad) < prob.n
    out, final_C, retries = _run_d2_with_retry(
        prob, rows_mask, spec.n_chunks, cap, spec.max_rounds, impl,
        engine="rsoc_d2", trace=tracer is not None,
        max_retries=spec.max_cap_retries)
    colors, r, trace, ftrace, tot = col._loop_outputs(out, tracer is not None)
    col._report_frontier(tracer, ftrace, r, cap=cap)
    conf, truncated = col._trim_trace(trace, r)
    colors = col._unpermute(colors, prob.perm, prob.n)
    return _d2_result(colors, r, conf, tot, final_C, retries, truncated)


@registry.register_engine("rsoc", distance=2, mode="partial",
                          replaces="color_bipartite_partial")
def _bipartite_partial_engine(g: CSRGraph, spec) -> col.ColoringResult:
    """One-sided distance-2 coloring of a bipartite graph (Jacobian
    compression): color only the left side [0, spec.n_left) so that any two
    left vertices sharing a neighbor get distinct colors.

    Same two-hop engine restricted to the left-side row mask; right-side
    vertices stay uncolored, so their (hop-1) contributions are inert and
    only shared-neighbor (hop-2) colors constrain.  Returns a result whose
    ``colors`` has length ``spec.n_left``.
    """
    n_left = spec.n_left
    if n_left is None or not 0 < n_left <= g.n_vertices:
        raise ValueError(f"n_left {n_left} out of range for n={g.n_vertices}")
    impl = col._resolve_impl(spec.forbidden_impl)
    tracer = obs.current_tracer()
    with obs.phase("prepare"):
        prob = _prepare_native(g, spec.seed, spec.n_chunks, spec.C,
                               spec.relabel, spec.ell_cap)
    cap = fr.frontier_cap(prob.n_pad, spec.n_chunks, spec.frontier_frac)
    mask_np = np.zeros(prob.n_pad, dtype=bool)
    mask_np[prob.perm[:n_left]] = True        # left side, relabeled space
    out, final_C, retries = _run_d2_with_retry(
        prob, jnp.asarray(mask_np), spec.n_chunks, cap, spec.max_rounds, impl,
        engine="rsoc_d2_partial", trace=tracer is not None,
        max_retries=spec.max_cap_retries)
    colors, r, trace, ftrace, tot = col._loop_outputs(out, tracer is not None)
    col._report_frontier(tracer, ftrace, r, cap=cap)
    conf, truncated = col._trim_trace(trace, r)
    colors = col._unpermute(colors, prob.perm, prob.n)[:n_left]
    return _d2_result(colors, r, conf, tot, final_C, retries, truncated)


def color_distance2(g: CSRGraph, seed: int = 0, C: Optional[int] = None,
                    n_chunks: int = 16, max_rounds: int = 1000,
                    ell_cap: int = 512, relabel: bool = True,
                    frontier_frac: float = 0.125,
                    forbidden_impl: Optional[str] = None
                    ) -> col.ColoringResult:
    """Deprecated: use ``repro.api.color(g, distance=2)``."""
    return registry.legacy_entry(
        "color_distance2", "distance=2", g, algorithm="rsoc", distance=2,
        seed=seed, C=C, n_chunks=n_chunks, max_rounds=max_rounds,
        ell_cap=ell_cap, relabel=relabel, frontier_frac=frontier_frac,
        forbidden_impl=forbidden_impl)


def color_bipartite_partial(g: CSRGraph, n_left: int, seed: int = 0,
                            C: Optional[int] = None, n_chunks: int = 16,
                            max_rounds: int = 1000, ell_cap: int = 512,
                            relabel: bool = True,
                            frontier_frac: float = 0.125,
                            forbidden_impl: Optional[str] = None
                            ) -> col.ColoringResult:
    """Deprecated: use ``repro.api.color(g, distance=2, mode="partial",
    n_left=...)``."""
    return registry.legacy_entry(
        "color_bipartite_partial", "distance=2, mode='partial', n_left=...",
        g, algorithm="rsoc", distance=2, mode="partial", n_left=n_left,
        seed=seed, C=C, n_chunks=n_chunks, max_rounds=max_rounds,
        ell_cap=ell_cap, relabel=relabel, frontier_frac=frontier_frac,
        forbidden_impl=forbidden_impl)
