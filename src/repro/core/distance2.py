"""Distance-d coloring (paper §6 outlook).

The paper argues RSOC's advantage grows with graph density, making it the
better candidate for d-distance colorings where G^d is much denser than G.
We validate exactly that: color G^d = power graph of G and compare RSOC vs CAT
round/pass counts (benchmarks/bench_distance2.py).
"""
from __future__ import annotations

import numpy as np

from repro.graphs.csr import CSRGraph, power_graph
from repro.core import coloring as col


def color_distance_d(g: CSRGraph, d: int = 2, algorithm: str = "rsoc",
                     **kwargs) -> tuple[col.ColoringResult, CSRGraph]:
    gd = power_graph(g, d)
    fn = col.ALGORITHMS[algorithm]
    res = fn(gd, **kwargs)
    return res, gd


def is_distance_d_proper(g: CSRGraph, colors: np.ndarray, d: int) -> bool:
    return col.is_proper(power_graph(g, d), colors)
