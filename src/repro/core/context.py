"""Typed pass context: the static configuration every gather pass closes over.

Through PR 3 this was a bare 5-tuple ``p_static = (n, n_pad, C, n_chunks,
impl)`` hand-rolled at every call site and positionally unpacked inside every
pass — the tuple's shape drifted once already (PR 3 grew it a fifth element)
and nothing but convention kept the sites in sync.  ``PassContext`` replaces
it: one frozen dataclass, constructed through builders, hashable so it keys
the jit cache exactly like the tuple did (it rides ``static_argnames``).

Shared by ``core/coloring.py``, ``core/frontier.py``, ``core/distance2.py``,
``core/distributed.py`` and ``dynamic/incremental.py``; derived from a
``repro.api.ColoringSpec`` by the engine adapters.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import bitset

# Forbidden-set representation used by every engine: "bitset" packs the
# (rows, C) table into (rows, C//32) int32 words (core/bitset.py), "dense"
# keeps the uint8 table and argmin mex — retained as the differential
# oracle.  Engines take ``forbidden_impl=None`` => this default.
DEFAULT_FORBIDDEN_IMPL = "bitset"


def resolve_impl(impl: Optional[str]) -> str:
    impl = DEFAULT_FORBIDDEN_IMPL if impl is None else impl
    if impl not in bitset.IMPLS:
        raise ValueError(
            f"unknown forbidden_impl {impl!r}; known: {bitset.IMPLS}")
    return impl


@dataclasses.dataclass(frozen=True)
class PassContext:
    """Static per-pass configuration (a jit-cache key, like C / n_chunks).

    ``n``       live vertices (rows past it are padding)
    ``n_pad``   padded row count of the device arrays
    ``C``       color cap (doubles on overflow via ``_run_with_retry``)
    ``n_chunks`` sequential chunks per pass (1/threads of the paper)
    ``forbidden_impl`` forbidden-set representation ("bitset" | "dense")
    ``trace``   collect per-round trace extras (frontier sizes) in the loop
                carry (DESIGN.md §12).  Static on purpose: ``trace=False``
                compiles the exact pre-obs program — zero extra device work
                or allocations when off — while ``trace=True`` is a separate
                jit-cache entry that pays for what it measures.
    """

    n: int
    n_pad: int
    C: int
    n_chunks: int
    forbidden_impl: str = DEFAULT_FORBIDDEN_IMPL
    trace: bool = False

    def __post_init__(self):
        if self.n_chunks < 1:
            raise ValueError(f"n_chunks must be >= 1 (got {self.n_chunks})")
        if self.C < 1:
            raise ValueError(f"C must be >= 1 (got {self.C})")
        if self.n_pad < self.n:
            raise ValueError(
                f"n_pad {self.n_pad} < n {self.n} (padding cannot shrink)")
        resolve_impl(self.forbidden_impl)

    @classmethod
    def for_problem(cls, prob, *, n_chunks: int, C: Optional[int] = None,
                    forbidden_impl: Optional[str] = None,
                    trace: bool = False) -> "PassContext":
        """Context for a prepared ``ColoringProblem`` (the standard builder:
        every engine derives its contexts here or via ``with_C``).  The
        problem does not record a chunking, so ``n_chunks`` is explicit."""
        return cls(n=prob.n, n_pad=prob.n_pad,
                   C=int(C if C is not None else prob.C),
                   n_chunks=int(n_chunks),
                   forbidden_impl=resolve_impl(forbidden_impl),
                   trace=bool(trace))

    def with_C(self, C: int) -> "PassContext":
        """Same context at a (doubled) color cap — the retry-loop builder."""
        return dataclasses.replace(self, C=int(C))

    def unpack(self) -> tuple[int, int, int, int, str]:
        """Positional view ``(n, n_pad, C, n_chunks, forbidden_impl)`` for
        the pass bodies.  The order is defined HERE and nowhere else.
        ``trace`` is deliberately NOT part of the positional view — the few
        loop drivers that collect trace extras read ``ctx.trace`` directly,
        the pass bodies never need it."""
        return (self.n, self.n_pad, self.C, self.n_chunks,
                self.forbidden_impl)
