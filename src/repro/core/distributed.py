"""Multi-device graph coloring via shard_map.

Collective schedules (DESIGN.md §2 — the paper's barrier analysis, in
collectives):

  RSOC  : one fused detect-and-recolor pass per round; the updated local color
          slice and the local defect count ride the SAME ``all_gather``
          (payload = [colors_local, n_defects_local]).   => 1 collective/round
  CAT   : phase A re-colors the defect set, whose colors must be re-replicated
          before phase B can detect (all_gather #1); phase B's defect count
          feeds the termination test, a global consensus (psum #2).  The data
          dependency detect-after-exchange is structural — exactly the second
          barrier of the paper's Algorithm 2.            => 2 collectives/round

Two color-exchange strategies:
  * ``replicated``: the full color vector is re-gathered each round
    (bytes/round = n*4).  Simple, the baseline.
  * ``halo``: only boundary colors are exchanged (bytes/round = D*max_b*4),
    using the static HaloPlan (partition.py).  This is the collective-term
    optimization recorded in EXPERIMENTS.md §Perf.

Both run under ``jax.jit`` + ``shard_map`` over a 1-D logical device axis
(callers flatten (data, model[, pod]) meshes).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import registry
from repro.graphs.csr import CSRGraph, FILL, to_ell
from repro.core import coloring as col
from repro.core.context import PassContext
from repro.core.partition import Partition, HaloPlan, block_partition, build_halo
from repro import obs

MAX_ROUNDS_TRACE = col.MAX_ROUNDS_TRACE


# --------------------------------------------------------------------------
# local fused pass (shared)
# --------------------------------------------------------------------------

def _local_fused_pass(ell_loc, colors_glb, pri_glb, U_loc, force_loc,
                      row_base, ctx: PassContext, *, detect: bool):
    """Chunked detect-and-recolor of this shard's rows against global colors.

    ell_loc:   (n_loc, W) global neighbor ids
    colors_glb:(n_glb,)   replicated (or local+ghost) color table
    row_base:  first global row of this shard
    ctx:       ``ctx.n`` bounds the valid global rows; ``ctx.n_pad`` is the
               table the caller sliced this shard from (unused here — the
               chunking runs over ell_loc's own rows)
    Returns (new local colors (n_loc,), recolored mask, n_defects).
    """
    n, _, C, n_chunks, impl = ctx.unpack()
    n_loc = ell_loc.shape[0]
    cs = n_loc // n_chunks
    colors_loc = jax.lax.dynamic_slice_in_dim(colors_glb, row_base, n_loc, 0)
    pri_loc = jax.lax.dynamic_slice_in_dim(pri_glb, row_base, n_loc, 0)
    valid_loc = (jnp.arange(n_loc) + row_base) < n

    def chunk_body(k, carry):
        colors_l, colors_g, recolored, n_def = carry
        lo = k * cs
        ell_k = jax.lax.dynamic_slice_in_dim(ell_loc, lo, cs, 0)
        c_k = jax.lax.dynamic_slice_in_dim(colors_l, lo, cs, 0)
        pri_k = jax.lax.dynamic_slice_in_dim(pri_loc, lo, cs, 0)
        U_k = jax.lax.dynamic_slice_in_dim(U_loc, lo, cs, 0)
        force_k = jax.lax.dynamic_slice_in_dim(force_loc, lo, cs, 0)
        valid_k = jax.lax.dynamic_slice_in_dim(valid_loc, lo, cs, 0)
        nbrc, nbrp = col._gather_nbr(ell_k, colors_g, pri_glb)
        if detect:
            defect = ((nbrc == c_k[:, None]) & (c_k[:, None] >= 0)
                      & (nbrp > pri_k[:, None])).any(axis=1)
            work = valid_k & ((U_k & defect) | force_k)
            n_def = n_def + (valid_k & U_k & defect).sum(dtype=jnp.int32)
        else:
            work = valid_k & (U_k | force_k)
        forb = col._forbidden(nbrc, C, impl)
        mex, _ = col._mex_of(forb, C, impl)
        newc = jnp.where(work, mex, c_k)
        colors_l = jax.lax.dynamic_update_slice_in_dim(colors_l, newc, lo, 0)
        # keep the *global* view fresh for later chunks of this shard
        colors_g = jax.lax.dynamic_update_slice_in_dim(
            colors_g, newc, row_base + lo, 0)
        recolored = jax.lax.dynamic_update_slice_in_dim(recolored, work, lo, 0)
        return colors_l, colors_g, recolored, n_def

    init = (colors_loc, colors_glb, jnp.zeros((n_loc,), bool), jnp.int32(0))
    colors_l, _, recolored, n_def = jax.lax.fori_loop(0, n_chunks, chunk_body, init)
    return colors_l, recolored, n_def


# --------------------------------------------------------------------------
# replicated-exchange engines
# --------------------------------------------------------------------------

def build_rsoc_distributed(mesh: Mesh, axis: str, ctx: PassContext,
                           max_rounds: int = 64):
    """Returns a jittable fn(ell (n_pad, W), pri (n_pad,)) -> (colors, rounds,
    conflicts). ONE fused collective per round (colors slice + defect count).

    ``ctx`` carries (n, n_pad, C, n_chunks, forbidden_impl) for the whole
    (unsharded) problem; each shard owns n_pad / D rows.
    """
    n_pad = ctx.n_pad
    D = int(np.prod([mesh.shape[a] for a in axis.split(",")]))
    axes = tuple(axis.split(","))
    n_loc = n_pad // D
    spec_rows = P(axes if len(axes) > 1 else axes[0])

    def body(ell_loc, pri):
        axname = axes if len(axes) > 1 else axes[0]
        idx = jax.lax.axis_index(axname)
        row_base = idx * n_loc
        colors0 = jnp.full((n_pad,), -1, jnp.int32)
        zeros = jnp.zeros((n_loc,), bool)
        ones = jnp.ones((n_loc,), bool)

        def exchange(colors_l, n_def_l):
            payload = jnp.concatenate(
                [colors_l, n_def_l[None].astype(jnp.int32)])
            allp = jax.lax.all_gather(payload, axname, tiled=False)
            allp = allp.reshape(D, n_loc + 1)
            colors = allp[:, :n_loc].reshape(n_pad)
            return colors, allp[:, n_loc].sum()

        # round 0: color everything; 1 collective
        c_l, _, _ = _local_fused_pass(ell_loc, colors0, pri, zeros, ones,
                                      row_base, ctx, detect=False)
        colors, _ = exchange(c_l, jnp.int32(0))
        U0 = ones

        def cond(s):
            _, _, _, r, _, last = s
            return (last > 0) & (r < max_rounds)

        def body_fn(s):
            colors, U, trace, r, tot, _ = s
            c_l, recolored, n_def_l = _local_fused_pass(
                ell_loc, colors, pri, U, jnp.zeros((n_loc,), bool),
                row_base, ctx, detect=True)
            colors2, n_def = exchange(c_l, n_def_l)      # ONE collective
            trace = trace.at[jnp.minimum(r, MAX_ROUNDS_TRACE - 1)].set(
                n_def.astype(jnp.int32))
            return (colors2, recolored, trace, r + 1,
                    tot + n_def.astype(jnp.int32), n_def.astype(jnp.int32))

        trace = jnp.zeros((MAX_ROUNDS_TRACE,), jnp.int32)
        s = (colors, U0, trace, jnp.int32(0), jnp.int32(0), jnp.int32(1))
        colors, _, trace, r, tot, _ = jax.lax.while_loop(cond, body_fn, s)
        return colors, r, trace, tot

    f = shard_map(body, mesh=mesh,
                  in_specs=(P(*((axes if len(axes) > 1 else (axes[0],)) + (None,))), P()),
                  out_specs=(P(), P(), P(), P()), check_rep=False)
    return jax.jit(f)


def build_cat_distributed(mesh: Mesh, axis: str, ctx: PassContext,
                          max_rounds: int = 64):
    """CAT with the structural 2-collectives-per-round schedule."""
    n_pad = ctx.n_pad
    axes = tuple(axis.split(","))
    D = int(np.prod([mesh.shape[a] for a in axes]))
    n_loc = n_pad // D

    def body(ell_loc, pri):
        axname = axes if len(axes) > 1 else axes[0]
        idx = jax.lax.axis_index(axname)
        row_base = idx * n_loc
        colors0 = jnp.full((n_pad,), -1, jnp.int32)
        zeros = jnp.zeros((n_loc,), bool)
        ones = jnp.ones((n_loc,), bool)

        def gather_colors(colors_l):
            allc = jax.lax.all_gather(colors_l, axname, tiled=False)
            return allc.reshape(n_pad)

        def detect_local(colors):
            c_l = jax.lax.dynamic_slice_in_dim(colors, row_base, n_loc, 0)
            p_l = jax.lax.dynamic_slice_in_dim(pri, row_base, n_loc, 0)
            nbrc, nbrp = col._gather_nbr(ell_loc, colors, pri)
            return ((nbrc == c_l[:, None]) & (c_l[:, None] >= 0)
                    & (nbrp > p_l[:, None])).any(axis=1)

        # round 0
        c_l, _, _ = _local_fused_pass(ell_loc, colors0, pri, zeros, ones,
                                      row_base, ctx, detect=False)
        colors = gather_colors(c_l)                       # collective 1
        U = detect_local(colors)
        n_def = jax.lax.psum(U.sum(dtype=jnp.int32), axname)  # collective 2

        def cond(s):
            return (s[4] > 0) & (s[2] < max_rounds)

        def body_fn(s):
            colors, U, r, tot, n_def, trace = s
            trace = trace.at[jnp.minimum(r, MAX_ROUNDS_TRACE - 1)].set(n_def)
            # phase A: recolor defect set
            c_l, _, _ = _local_fused_pass(ell_loc, colors, pri, U, zeros,
                                          row_base, ctx, detect=False)
            colors2 = gather_colors(c_l)                  # collective 1
            # phase B: detect + global consensus
            U2 = detect_local(colors2) & U
            n_def2 = jax.lax.psum(U2.sum(dtype=jnp.int32), axname)  # coll. 2
            return colors2, U2, r + 1, tot + n_def, n_def2, trace

        trace = jnp.zeros((MAX_ROUNDS_TRACE,), jnp.int32)
        s = (colors, U, jnp.int32(0), jnp.int32(0), n_def, trace)
        colors, U, r, tot, n_def, trace = jax.lax.while_loop(cond, body_fn, s)
        return colors, r, trace, tot

    f = shard_map(body, mesh=mesh,
                  in_specs=(P(*((axes if len(axes) > 1 else (axes[0],)) + (None,))), P()),
                  out_specs=(P(), P(), P(), P()), check_rep=False)
    return jax.jit(f)


# --------------------------------------------------------------------------
# halo-exchange RSOC (collective-term optimized; EXPERIMENTS.md §Perf)
# --------------------------------------------------------------------------

def build_rsoc_halo(mesh: Mesh, axis: str, plan_shapes: dict,
                    ctx: PassContext, max_rounds: int = 64):
    """RSOC exchanging only boundary colors.

    Inputs per shard (leading dim D, sharded): ell_local (n_loc, W) with
    local/ghost slot ids; boundary (max_b,); ghost flat index (max_g,) into the
    gathered (D*max_b,) boundary payload.  Color table per shard has
    n_loc + max_g slots (ghosts at the tail).  ``ctx`` supplies
    (C, n_chunks, forbidden_impl); its row counts are re-derived per shard.
    """
    axes = tuple(axis.split(","))
    D, n_loc = plan_shapes["D"], plan_shapes["n_loc"]
    max_b, max_g = plan_shapes["max_b"], plan_shapes["max_g"]
    # every local row is a valid candidate; the shard's color table carries
    # max_g ghost slots at the tail
    lctx = dataclasses.replace(ctx, n=n_loc, n_pad=n_loc + max_g)

    def body(ell_loc, pri_loc, pri_ghost, boundary, ghost_flat, valid_loc):
        axname = axes if len(axes) > 1 else axes[0]
        n_tab = n_loc + max_g
        colors_tab0 = jnp.full((n_tab,), -1, jnp.int32)
        pri_tab = jnp.concatenate([pri_loc, pri_ghost])
        zeros = jnp.zeros((n_loc,), bool)

        def exchange(colors_tab, n_def_l):
            b = jnp.where(boundary >= 0,
                          colors_tab[jnp.clip(boundary, 0, n_loc - 1)], -1)
            payload = jnp.concatenate([b, n_def_l[None].astype(jnp.int32)])
            allp = jax.lax.all_gather(payload, axname, tiled=False)
            allp = allp.reshape(D, max_b + 1)
            flat = allp[:, :max_b].reshape(D * max_b)
            ghosts = jnp.where(ghost_flat >= 0,
                               flat[jnp.clip(ghost_flat, 0, D * max_b - 1)], -1)
            colors_tab = jax.lax.dynamic_update_slice_in_dim(
                colors_tab, ghosts, n_loc, 0)
            return colors_tab, allp[:, max_b].sum()

        def fused(colors_tab, U, force, detect):
            return _local_fused_pass(ell_loc, colors_tab, pri_tab, U, force,
                                     0, lctx, detect=detect)

        # round 0
        c_l, _, _ = fused(colors_tab0, zeros, valid_loc, False)
        tab = jax.lax.dynamic_update_slice_in_dim(colors_tab0, c_l, 0, 0)
        tab, _ = exchange(tab, jnp.int32(0))              # 1 collective

        def cond(s):
            return (s[4] > 0) & (s[2] < max_rounds)

        def body_fn(s):
            tab, U, r, tot, _, trace = s
            c_l, recolored, n_def_l = fused(tab, U, zeros, True)
            tab = jax.lax.dynamic_update_slice_in_dim(tab, c_l, 0, 0)
            tab, n_def = exchange(tab, n_def_l)           # 1 collective
            trace = trace.at[jnp.minimum(r, MAX_ROUNDS_TRACE - 1)].set(
                n_def.astype(jnp.int32))
            return (tab, recolored, r + 1, tot + n_def.astype(jnp.int32),
                    n_def.astype(jnp.int32), trace)

        trace = jnp.zeros((MAX_ROUNDS_TRACE,), jnp.int32)
        s = (tab, valid_loc, jnp.int32(0), jnp.int32(0), jnp.int32(1), trace)
        tab, _, r, tot, _, trace = jax.lax.while_loop(cond, body_fn, s)
        colors_l = jax.lax.dynamic_slice_in_dim(tab, 0, n_loc, 0)
        return colors_l, r, trace, tot

    row = P(*((axes if len(axes) > 1 else (axes[0],)) + (None,)))
    vec = P(axes if len(axes) > 1 else axes[0])
    f = shard_map(body, mesh=mesh,
                  in_specs=(row, vec, vec, vec, vec, vec),
                  out_specs=(vec, P(), P(), P()), check_rep=False)
    return jax.jit(f)


# --------------------------------------------------------------------------
# sharded mutable-state passes (dynamic/sharded.py; DESIGN.md §15)
#
# Same halo protocol as build_rsoc_halo — ONE all_gather per round carrying
# [boundary colors, n_defects, work, overflow] — but over the *mutable*
# encode: per-shard overflow COO alongside the ELL, external (colors, U)
# seeds instead of a from-scratch start, and the overflow flag threaded out
# last so ``col._run_with_retry`` can drive cap doubling.  Builders are
# lru_cached: rebuilding a shard_map per call would mint a fresh function
# identity and recompile on every service step.
# --------------------------------------------------------------------------

def _sharded_exchange(axname, D, n_loc, max_b, boundary, ghost_flat):
    """Shared halo exchange: publish my boundary colors + (n_def, work, ovf)
    scalars, gather all shards' payloads, refresh my ghost tail.  Returns a
    closure ``exchange(tab, n_def_l, work_l, ovf_l) -> (tab, n_def, work,
    ovf)`` with the scalars globally summed/or-ed."""

    def exchange(tab, n_def_l, work_l, ovf_l):
        b = jnp.where(boundary >= 0,
                      tab[jnp.clip(boundary, 0, n_loc - 1)], -1)
        tail = jnp.stack([n_def_l.astype(jnp.int32),
                          work_l.astype(jnp.int32),
                          ovf_l.astype(jnp.int32)])
        allp = jax.lax.all_gather(jnp.concatenate([b, tail]), axname,
                                  tiled=False).reshape(D, max_b + 3)
        flat = allp[:, :max_b].reshape(D * max_b)
        ghosts = jnp.where(ghost_flat >= 0,
                           flat[jnp.clip(ghost_flat, 0, D * max_b - 1)], -1)
        tab = jax.lax.dynamic_update_slice_in_dim(tab, ghosts, n_loc, 0)
        return (tab, allp[:, max_b].sum(), allp[:, max_b + 1].sum(),
                allp[:, max_b + 2].sum() > 0)

    return exchange


@functools.lru_cache(maxsize=None)
def build_sharded_scratch(mesh: Mesh, axis: str, D: int, n_loc: int,
                          max_b: int, max_g: int, ctx: PassContext,
                          max_rounds: int):
    """From-scratch coloring of a sharded mutable state: round 0 force-colors
    every valid local row, then fused detect-and-recolor rounds with one halo
    exchange each.  On a 1-shard mesh this replays ``col._rsoc_loop``'s
    program bit-for-bit (same chunked pass, same carry schedule).

    Returns jit fn(ell (D*n_loc, W), ovf_src (D*cap,), ovf_dst (D*cap,),
    pri_tab (D*n_tab,), valid_loc (D*n_loc,), boundary (D*max_b,),
    ghost_flat (D*max_g,)) -> (colors_tab (D*n_tab,), rounds, trace,
    total_conflicts, overflowed)."""
    axes = tuple(axis.split(","))
    axname = axes if len(axes) > 1 else axes[0]
    n_tab = n_loc + max_g
    lctx = dataclasses.replace(ctx, n=n_loc, n_pad=n_loc, trace=False)

    def body(ell, osrc, odst, pri_tab, valid_loc, boundary, ghost_flat):
        exchange = _sharded_exchange(axname, D, n_loc, max_b, boundary,
                                     ghost_flat)
        tab0 = jnp.full((n_tab,), -1, jnp.int32)
        zeros = jnp.zeros((n_loc,), bool)

        # round 0: color every valid local row against fresh local colors
        tab, U, _, ovf0 = col._chunked_pass(
            lctx, ell, osrc, odst, pri_tab, tab0, zeros, valid_loc,
            detect=False, valid=valid_loc)
        tab, _, _, ovf_g = exchange(tab, jnp.int32(0), jnp.int32(0), ovf0)

        def cond(s):
            return (s[4] > 0) & (s[3] < max_rounds)

        def body_fn(s):
            tab, U, trace, r, _, tot, ovf = s
            colors_loc = jax.lax.dynamic_slice_in_dim(tab, 0, n_loc, 0)
            force = U & (colors_loc < 0)
            tab2, recolored, n_def_l, ovf_l = col._chunked_pass(
                lctx, ell, osrc, odst, pri_tab, tab, U, force,
                detect=True, valid=valid_loc)
            tab2, n_def, work, ovf2 = exchange(
                tab2, n_def_l, n_def_l + force.sum(dtype=jnp.int32),
                ovf | ovf_l)
            trace = trace.at[jnp.minimum(r, MAX_ROUNDS_TRACE - 1)].set(
                n_def.astype(jnp.int32))
            return (tab2, recolored, trace, r + 1, work.astype(jnp.int32),
                    tot + n_def.astype(jnp.int32), ovf2)

        trace = jnp.zeros((MAX_ROUNDS_TRACE,), jnp.int32)
        s = (tab, U, trace, jnp.int32(0), jnp.int32(1), jnp.int32(0), ovf_g)
        tab, _, trace, r, _, tot, ovf = jax.lax.while_loop(cond, body_fn, s)
        return tab, r, trace, tot, ovf

    row = P(*((axes if len(axes) > 1 else (axes[0],)) + (None,)))
    vec = P(axes if len(axes) > 1 else axes[0])
    f = shard_map(body, mesh=mesh,
                  in_specs=(row, vec, vec, vec, vec, vec, vec),
                  out_specs=(vec, P(), P(), P(), P()), check_rep=False)
    return jax.jit(f)


@functools.lru_cache(maxsize=None)
def build_sharded_repair(mesh: Mesh, axis: str, D: int, n_loc: int,
                         max_b: int, max_g: int, ctx: PassContext,
                         cap: int, max_rounds: int):
    """Incremental repair of a sharded mutable state from external
    (colors, U) seeds: the sharded counterpart of
    ``frontier._repair_compact_loop``, with a halo exchange per round.

    An up-front exchange freshens ghost colors before the first detect
    (newly-allocated ghost slots start at -1 on the referencing shard), then
    each round recolors the frontier — compacted to ``cap`` slots when small
    enough, full chunked sweep otherwise — and exchanges boundary colors +
    termination scalars in one collective.  On a 1-shard mesh this replays
    ``frontier._repair_compact_loop`` bit-for-bit.

    Returns jit fn(ell, ovf_src, ovf_dst, pri_tab, colors_tab, U, valid_loc,
    boundary, ghost_flat) -> (colors_tab, rounds, trace, total_conflicts,
    overflowed)."""
    from repro.core import frontier

    axes = tuple(axis.split(","))
    axname = axes if len(axes) > 1 else axes[0]
    n_tab = n_loc + max_g
    lctx = dataclasses.replace(ctx, n=n_loc, n_pad=n_loc, trace=False)

    def body(ell, osrc, odst, pri_tab, colors_tab, U, valid_loc, boundary,
             ghost_flat):
        exchange = _sharded_exchange(axname, D, n_loc, max_b, boundary,
                                     ghost_flat)
        tab0, _, _, _ = exchange(colors_tab, jnp.int32(0), jnp.int32(0),
                                 jnp.bool_(False))

        def cond(s):
            return (s[4] > 0) & (s[3] < max_rounds)

        def body_fn(s):
            tab, U, trace, r, _, tot, ovf = s
            count = U.sum(dtype=jnp.int32)
            colors_loc = jax.lax.dynamic_slice_in_dim(tab, 0, n_loc, 0)
            n_forced = (U & (colors_loc < 0)).sum(dtype=jnp.int32)

            def small(args):
                tab, U = args
                # fill_value = n_tab (NOT n_loc): dead frontier slots must
                # fall off the table, not alias ghost slot 0
                idx = jnp.nonzero(U, size=cap, fill_value=n_tab)[0].astype(
                    jnp.int32)
                tab2, rec, n_def, o = frontier._compact_pass(
                    lctx, ell, osrc, odst, pri_tab, tab, idx, idx < n_tab)
                return tab2, rec[:n_loc], n_def, o

            def big(args):
                tab, U = args
                force = U & (jax.lax.dynamic_slice_in_dim(
                    tab, 0, n_loc, 0) < 0)
                return col._chunked_pass(
                    lctx, ell, osrc, odst, pri_tab, tab, U, force,
                    detect=True, valid=valid_loc)

            tab2, recolored, n_def_l, ovf_l = jax.lax.cond(
                count <= cap, small, big, (tab, U))
            tab2, n_def, work, ovf2 = exchange(
                tab2, n_def_l, n_def_l + n_forced, ovf | ovf_l)
            trace = trace.at[jnp.minimum(r, MAX_ROUNDS_TRACE - 1)].set(
                n_def.astype(jnp.int32))
            return (tab2, recolored, trace, r + 1, work.astype(jnp.int32),
                    tot + n_def.astype(jnp.int32), ovf2)

        trace = jnp.zeros((MAX_ROUNDS_TRACE,), jnp.int32)
        s = (tab0, U, trace, jnp.int32(0), jnp.int32(1), jnp.int32(0),
             jnp.bool_(False))
        tab, _, trace, r, _, tot, ovf = jax.lax.while_loop(cond, body_fn, s)
        return tab, r, trace, tot, ovf

    row = P(*((axes if len(axes) > 1 else (axes[0],)) + (None,)))
    vec = P(axes if len(axes) > 1 else axes[0])
    f = shard_map(body, mesh=mesh,
                  in_specs=(row, vec, vec, vec, vec, vec, vec, vec, vec),
                  out_specs=(vec, P(), P(), P(), P()), check_rep=False)
    return jax.jit(f)


# --------------------------------------------------------------------------
# host-level drivers
# --------------------------------------------------------------------------

def _color_distributed(g: CSRGraph, mesh: Mesh, axis: str = "data",
                       algorithm: str = "rsoc", seed: int = 0,
                       n_chunks: int = 4, C: Optional[int] = None,
                       max_rounds: int = 64,
                       forbidden_impl: Optional[str] = None):
    """Run distributed coloring on real devices (tests use host platforms)."""
    axes = tuple(axis.split(","))
    D = int(np.prod([mesh.shape[a] for a in axes]))
    with obs.phase("prepare"):
        part = block_partition(g, D, seed)
        gg = part.graph
        W = max(1, gg.max_degree)
        n_loc = -(-part.n_pad // D)
        n_loc = -(-n_loc // n_chunks) * n_chunks
        n_pad = n_loc * D
        ell = to_ell(gg, max_degree=W, pad_vertices_to=n_pad)
        rng = np.random.default_rng(seed + 1)
        pri = np.full(n_pad, -1, np.int32)
        pri[:part.n] = rng.permutation(part.n).astype(np.int32)
    ctx = PassContext(n=part.n, n_pad=n_pad,
                      C=C or col._pick_C(gg, None), n_chunks=n_chunks,
                      forbidden_impl=col._resolve_impl(forbidden_impl))
    build = {"rsoc": build_rsoc_distributed, "cat": build_cat_distributed}[algorithm]
    fn = build(mesh, axis, ctx, max_rounds)
    ell_sharding = NamedSharding(mesh, P(*((axes if len(axes) > 1 else (axes[0],)) + (None,))))
    ellj = jax.device_put(jnp.asarray(ell), ell_sharding)
    prij = jax.device_put(jnp.asarray(pri), NamedSharding(mesh, P()))
    with obs.phase("solve", C=ctx.C, devices=D):
        colors, r, trace, tot = jax.block_until_ready(fn(ellj, prij))
    conf, truncated = col._trim_trace(trace, r)
    # back to original ids: perm maps old->new, colors_old[i] = colors_new[perm[i]]
    colors = np.asarray(colors)[part.perm]
    return col.ColoringResult(
        colors=colors, n_rounds=int(r), conflicts_per_round=conf,
        total_conflicts=int(tot), n_colors=col.n_colors_used(colors),
        overflow=False,
        gather_passes=(1 + int(r)) * (1 if algorithm == "rsoc" else 2),
        final_C=ctx.C, retries=0, distance=1, trace_truncated=truncated)


def _distributed_engine(algorithm: str):
    def engine(g: CSRGraph, spec, *, mesh: Optional[Mesh] = None,
               axis: str = "data") -> col.ColoringResult:
        if mesh is None:
            raise ValueError(
                "backend='distributed' requires a device mesh: "
                "repro.api.color(g, spec, mesh=<jax.sharding.Mesh>)")
        return _color_distributed(
            g, mesh, axis=axis, algorithm=algorithm, seed=spec.seed,
            n_chunks=spec.n_chunks, C=spec.C, max_rounds=spec.max_rounds,
            forbidden_impl=spec.forbidden_impl)
    engine.__name__ = f"_{algorithm}_distributed_engine"
    return engine


registry.register_engine("rsoc", distance=1, mode="static",
                         backend="distributed",
                         replaces="color_distributed")(
    _distributed_engine("rsoc"))
registry.register_engine("cat", distance=1, mode="static",
                         backend="distributed",
                         replaces="color_distributed")(
    _distributed_engine("cat"))


def color_distributed(g: CSRGraph, mesh: Mesh, axis: str = "data",
                      algorithm: str = "rsoc", seed: int = 0,
                      n_chunks: int = 4, C: Optional[int] = None,
                      max_rounds: int = 64):
    """Deprecated: use ``repro.api.color(g, backend="distributed",
    mesh=...)``."""
    return registry.legacy_entry(
        "color_distributed", "backend='distributed', mesh=...", g,
        algorithm=algorithm, backend="distributed", mesh=mesh, axis=axis,
        seed=seed, n_chunks=n_chunks, C=C, max_rounds=max_rounds)
