"""Fanout neighbor sampler (GraphSAGE-style) — the ``minibatch_lg`` substrate.

Two implementations:
  * `NeighborSampler` — host-side numpy sampler used by the data pipeline.
    Produces fixed-shape (padded) `SampledBlock`s so the jitted train step sees
    static shapes.
  * `sample_fanout_jax` — in-graph (jittable) uniform-with-replacement sampler
    over an ELL adjacency, for fully-on-device pipelines.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .csr import CSRGraph, FILL


@dataclasses.dataclass(frozen=True)
class SampledBlock:
    """One message-passing block: edges from sampled srcs -> dst seeds.

    Shapes are static: n_dst seeds, each with exactly ``fanout`` sampled
    neighbor slots (FILL-padded where degree < fanout is impossible here since
    we sample with replacement; FILL marks isolated vertices).
    """

    dst_nodes: np.ndarray   # (n_dst,) global ids of destination nodes
    src_nodes: np.ndarray   # (n_src,) global ids (union of sampled + dsts first)
    nbr_local: np.ndarray   # (n_dst, fanout) local indices into src_nodes, FILL pad


@dataclasses.dataclass(frozen=True)
class SampledBatch:
    seeds: np.ndarray             # (batch,) seed node ids
    blocks: tuple                 # one SampledBlock per layer, seed-side last
    node_ids: np.ndarray          # (n_input,) input-layer node ids (padded)


class NeighborSampler:
    """Uniform fanout sampler over a CSR graph with static output shapes."""

    def __init__(self, graph: CSRGraph, fanouts: Sequence[int], seed: int = 0):
        self.g = graph
        self.fanouts = tuple(int(f) for f in fanouts)
        self.rng = np.random.default_rng(seed)

    def _sample_layer(self, dst_nodes: np.ndarray, fanout: int) -> SampledBlock:
        """dst-PREFIX invariant: ``src_nodes[:len(dst_nodes)] == dst_nodes``.

        Chained across layers this makes every block's local indices valid in
        the outermost (input) layer's node list — the union-subgraph adapter
        (launch/cells.py) depends on it."""
        g = self.g
        n_dst = len(dst_nodes)
        deg = g.degrees[dst_nodes]
        # with-replacement uniform sample of `fanout` neighbors per dst
        r = self.rng.integers(0, np.maximum(deg, 1)[:, None], size=(n_dst, fanout))
        nbr = g.indices[g.indptr[dst_nodes][:, None] + r].astype(np.int64)
        nbr[deg == 0] = -1  # isolated
        new = np.setdiff1d(np.unique(nbr[nbr >= 0]), dst_nodes)
        src_nodes = np.concatenate([dst_nodes, new])
        # vectorized id -> local position (stable argsort + searchsorted)
        order = np.argsort(src_nodes, kind="stable")
        pos = np.searchsorted(src_nodes[order], np.where(nbr >= 0, nbr, src_nodes[0]))
        local = order[pos]
        local = np.where(nbr >= 0, local, FILL).astype(np.int32)
        return SampledBlock(dst_nodes=dst_nodes.astype(np.int64),
                            src_nodes=src_nodes.astype(np.int64),
                            nbr_local=local)

    def sample(self, seeds: np.ndarray) -> SampledBatch:
        seeds = np.asarray(seeds, dtype=np.int64)
        blocks = []
        dst = seeds
        for fanout in self.fanouts:          # outermost layer sampled last
            blk = self._sample_layer(dst, fanout)
            blocks.append(blk)
            dst = blk.src_nodes
        blocks = tuple(reversed(blocks))     # input-side block first
        return SampledBatch(seeds=seeds, blocks=blocks, node_ids=dst)

    def padded_sizes(self, batch: int) -> list[int]:
        """Static per-layer node-count caps (batch * prod(fanout+1) upper bound)."""
        sizes = [batch]
        for f in self.fanouts:
            sizes.append(sizes[-1] * (f + 1))
        return sizes


def pad_batch(batch: SampledBatch, sizes: Sequence[int], fanouts: Sequence[int]) -> dict:
    """Pad a SampledBatch to static shapes -> dict of arrays for the jitted step.

    Layout (L layers):
      nodes_k   : (sizes[L-k],) node ids of layer k input (k=0 is input layer)
      nbr_k     : (sizes[L-1-k], fanout_k) local indices into layer-k nodes
      n_valid_k : scalar count of valid dsts
    """
    L = len(batch.blocks)
    out = {}
    sizes = list(sizes)
    for k, blk in enumerate(batch.blocks):
        cap_src = sizes[L - k]
        cap_dst = sizes[L - 1 - k]
        fanout = fanouts[L - 1 - k]
        src_pad = np.full(cap_src, 0, dtype=np.int64)
        src_pad[: len(blk.src_nodes)] = blk.src_nodes
        nbr_pad = np.full((cap_dst, fanout), FILL, dtype=np.int32)
        nbr_pad[: len(blk.dst_nodes)] = blk.nbr_local
        out[f"nodes_{k}"] = src_pad
        out[f"nbr_{k}"] = nbr_pad
        out[f"n_valid_{k}"] = np.int32(len(blk.dst_nodes))
    out["seeds"] = np.pad(batch.seeds, (0, sizes[0] - len(batch.seeds)))
    return out


def union_caps(batch_nodes: int, fanouts_sampling: Sequence[int]) -> list[int]:
    """Static per-layer node caps, seed-side first: [batch, batch*(f0+1), ...]."""
    caps = [batch_nodes]
    for f in fanouts_sampling:
        caps.append(caps[-1] * (f + 1))
    return caps


def union_pad(batch: SampledBatch, batch_nodes: int,
              fanouts_sampling: Sequence[int],
              pad_edges_to: int = 8192) -> dict:
    """Flatten a SampledBatch into ONE static-shape union subgraph.

    Relies on the sampler's dst-prefix invariant: every block's local indices
    are valid positions in the input-layer node list.  Output (static shapes):
      nodes : (cap_in + 1,) global ids; last row is a SINK padding node
      src/dst: (E_cap,) local edge endpoints; masked edges become a
               sink->sink self-loop so they can never pollute real nodes
      seed outputs = model rows [0, batch_nodes)
    """
    caps = union_caps(batch_nodes, fanouts_sampling)
    cap_in = caps[-1]
    nodes = np.zeros(cap_in + 1, dtype=np.int64)
    nodes[: len(batch.node_ids)] = batch.node_ids
    srcs, dsts = [], []
    # batch.blocks are input-side first; seed-side block sampled first
    for k, blk in enumerate(reversed(batch.blocks)):   # seed-side first
        cap_dst = caps[k]
        f = fanouts_sampling[k]
        nbr = np.full((cap_dst, f), FILL, dtype=np.int32)
        nbr[: blk.nbr_local.shape[0]] = blk.nbr_local
        srcs.append(nbr.reshape(-1))
        dsts.append(np.repeat(np.arange(cap_dst, dtype=np.int32), f))
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    if pad_edges_to:
        e_pad = -(-len(src) // pad_edges_to) * pad_edges_to
        src = np.concatenate([src, np.full(e_pad - len(src), FILL, np.int32)])
        dst = np.concatenate([dst, np.zeros(e_pad - len(dst), np.int32)])
    sink = np.int32(cap_in)
    dst = np.where(src >= 0, dst, sink).astype(np.int32)
    src = np.where(src >= 0, src, sink).astype(np.int32)
    return {"nodes": nodes, "src": src, "dst": dst}


def sample_fanout_jax(key, ell_nbr, deg, seeds, fanout: int):
    """Jittable uniform-with-replacement fanout sample over ELL adjacency.

    ell_nbr: (n, max_deg) int32 neighbor table, FILL-padded
    deg:     (n,) int32 degrees
    seeds:   (b,) int32
    returns: (b, fanout) sampled global neighbor ids (FILL where isolated)
    """
    import jax
    import jax.numpy as jnp

    b = seeds.shape[0]
    d = jnp.maximum(deg[seeds], 1)
    r = jax.random.randint(key, (b, fanout), 0, 2**31 - 1) % d[:, None]
    nbr = jnp.take_along_axis(ell_nbr[seeds], r, axis=1)
    return jnp.where((deg[seeds] > 0)[:, None], nbr, FILL)
