"""Graph containers: CSR (host-side) and ELL (device-side, TPU-friendly).

The coloring kernels and the GNN aggregation kernel both consume the ELL
(padded-neighbor) layout: a rectangular ``(n_vertices, max_degree)`` int32 array
of neighbor ids with a fill sentinel.  Rectangular tiles map onto VMEM blocks;
CSR pointer-chasing does not.  CSR remains the host/pipeline format (compact,
easy to sample from); `to_ell` is the boundary between the two.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

FILL = np.int32(-1)  # ELL padding sentinel


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Undirected graph in CSR form (both directions stored)."""

    indptr: np.ndarray   # (n+1,) int64
    indices: np.ndarray  # (nnz,) int32
    n_vertices: int

    @property
    def n_edges(self) -> int:
        """Directed edge count (2x undirected)."""
        return int(self.indices.shape[0])

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max()) if self.n_vertices else 0

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def validate(self) -> None:
        assert self.indptr.shape == (self.n_vertices + 1,)
        assert self.indptr[0] == 0 and self.indptr[-1] == len(self.indices)
        assert np.all(np.diff(self.indptr) >= 0)
        if len(self.indices):
            assert self.indices.min() >= 0 and self.indices.max() < self.n_vertices


def from_edges(n_vertices: int, edges: np.ndarray, symmetrize: bool = True) -> CSRGraph:
    """Build a CSR graph from an (m, 2) edge array; dedups and removes self-loops."""
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    edges = edges[edges[:, 0] != edges[:, 1]]
    if symmetrize:
        edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
    # dedup via flat key
    key = edges[:, 0] * n_vertices + edges[:, 1]
    order = np.argsort(key, kind="stable")
    key = key[order]
    keep = np.ones(len(key), dtype=bool)
    keep[1:] = key[1:] != key[:-1]
    edges = edges[order][keep]
    src, dst = edges[:, 0], edges[:, 1]
    counts = np.bincount(src, minlength=n_vertices)
    indptr = np.zeros(n_vertices + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr=indptr, indices=dst.astype(np.int32), n_vertices=n_vertices)


def to_edge_list(g: CSRGraph) -> np.ndarray:
    """(nnz, 2) directed edge list (src, dst)."""
    src = np.repeat(np.arange(g.n_vertices, dtype=np.int32), g.degrees)
    return np.stack([src, g.indices], axis=1)


def to_ell(g: CSRGraph, max_degree: Optional[int] = None, pad_vertices_to: Optional[int] = None) -> np.ndarray:
    """CSR -> ELL padded neighbor array (n_pad, max_degree) int32, FILL-padded.

    Vertices whose degree exceeds ``max_degree`` raise (callers should cap via
    graph preprocessing or pick max_degree >= g.max_degree).
    """
    md = int(max_degree if max_degree is not None else g.max_degree)
    if g.max_degree > md:
        raise ValueError(f"max_degree {md} < graph max degree {g.max_degree}")
    n = g.n_vertices
    n_pad = int(pad_vertices_to if pad_vertices_to is not None else n)
    deg = g.degrees
    ell = np.full((n_pad, max(md, 1)), FILL, dtype=np.int32)
    # vectorized fill: position of each entry within its row
    if g.n_edges:
        row = np.repeat(np.arange(n), deg)
        col = np.arange(g.n_edges) - np.repeat(g.indptr[:-1], deg)
        ell[row, col] = g.indices
    return ell


def ell_to_edges(ell: np.ndarray, n: int,
                 ovf_src: Optional[np.ndarray] = None,
                 ovf_dst: Optional[np.ndarray] = None) -> np.ndarray:
    """ELL (+ optional COO overflow) -> (m, 2) directed edge list.

    The inverse boundary of `to_ell` for the *mutable* encoding
    (DESIGN.md §7.1): FILL slots — empty ELL cells and freed overflow
    entries — are skipped, so a slot table mutated by insert/delete batches
    decodes to exactly its live edge set.
    """
    ell = np.asarray(ell)[:n]
    row, slot = np.nonzero(ell >= 0)
    src = row.astype(np.int64)
    dst = ell[row, slot].astype(np.int64)
    if ovf_src is not None and len(ovf_src):
        os_np, od_np = np.asarray(ovf_src), np.asarray(ovf_dst)
        live = (os_np >= 0) & (od_np >= 0)
        src = np.concatenate([src, os_np[live].astype(np.int64)])
        dst = np.concatenate([dst, od_np[live].astype(np.int64)])
    return np.stack([src, dst], axis=1)


def from_ell(ell: np.ndarray, n: int,
             ovf_src: Optional[np.ndarray] = None,
             ovf_dst: Optional[np.ndarray] = None) -> CSRGraph:
    """Rebuild a CSRGraph from the (possibly mutated) device encoding."""
    return from_edges(n, ell_to_edges(ell, n, ovf_src, ovf_dst),
                      symmetrize=False)


def shuffle_vertices(g: CSRGraph, seed: int = 0) -> CSRGraph:
    """Random relabel of vertex ids (paper shuffles RMAT ids to kill locality)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.n_vertices).astype(np.int64)
    edges = to_edge_list(g).astype(np.int64)
    edges = perm[edges]
    return from_edges(g.n_vertices, edges, symmetrize=False)


def power_graph(g: CSRGraph, d: int) -> CSRGraph:
    """G^d: connect u,v iff dist(u,v) <= d.  Used for distance-d coloring (paper §6).

    BFS-free construction by repeated neighbor expansion; fine for the scales we
    color on CPU.
    """
    if d < 1:
        raise ValueError("d must be >= 1")
    if d == 1:
        return g
    # adjacency as set-of-arrays, expand d-1 times
    frontier_indptr, frontier_indices = g.indptr, g.indices
    all_src = [np.repeat(np.arange(g.n_vertices, dtype=np.int64), np.diff(g.indptr))]
    all_dst = [g.indices.astype(np.int64)]
    for _ in range(d - 1):
        # next frontier: neighbors of current frontier entries
        deg = np.diff(g.indptr)
        src = np.repeat(all_src[-1], deg[all_dst[-1]])
        starts = g.indptr[all_dst[-1]]
        counts = deg[all_dst[-1]]
        # gather neighbor blocks
        offs = np.arange(counts.sum()) - np.repeat(np.cumsum(counts) - counts, counts)
        dst = g.indices[np.repeat(starts, counts) + offs].astype(np.int64)
        all_src.append(src)
        all_dst.append(dst)
    src = np.concatenate(all_src)
    dst = np.concatenate(all_dst)
    return from_edges(g.n_vertices, np.stack([src, dst], 1), symmetrize=True)


def degree_histogram(g: CSRGraph, bins: int = 10) -> dict:
    deg = g.degrees
    return {
        "min": int(deg.min()), "max": int(deg.max()),
        "mean": float(deg.mean()), "p99": float(np.percentile(deg, 99)),
    }
