"""Synthetic graph generators mirroring the paper's benchmark suite.

The paper evaluates on:
  - ``mesh2d``  : ~250k-vertex anisotropic 2D triangular mesh
  - ``bmw3_2``  : ~227k-vertex 3D tetrahedral mesh (UF collection)
  - ``pwtk``    : ~218k-vertex 3D tetrahedral mesh (UF collection)
  - RMAT-ER / RMAT-G / RMAT-B : 16M-vertex / 128M-edge R-MAT graphs with the
    Chakrabarti–Faloutsos partition probabilities used by Catalyurek et al.:
       ER (0.25, 0.25, 0.25, 0.25)   uniform degrees
       G  (0.45, 0.15, 0.15, 0.25)   mild skew
       B  (0.55, 0.15, 0.15, 0.15)   heavy skew / high-degree hubs
    with vertex ids randomly shuffled to destroy locality (paper §4).

We regenerate the same *classes* synthetically (UF downloads are unavailable
offline): structured triangulations for the 2D mesh, tetrahedralized grids for
the 3D meshes, and a faithful R-MAT sampler.  Sizes are parameterized; the
benchmark suite defaults to scaled-down instances sized for this container and
records the scale factor (DESIGN.md §9.5).
"""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph, from_edges, shuffle_vertices


def rmat(scale: int, edge_factor: int = 8, a: float = 0.25, b: float = 0.25,
         c: float = 0.25, seed: int = 0, shuffle: bool = True) -> CSRGraph:
    """R-MAT generator (Chakrabarti & Faloutsos). n = 2**scale vertices."""
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    d = 1.0 - a - b - c
    if d < -1e-9:
        raise ValueError("probabilities must sum <= 1")
    probs = np.array([a, b, c, max(d, 0.0)])
    probs = probs / probs.sum()
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    # vectorized bit-by-bit quadrant sampling
    for _ in range(scale):
        q = rng.choice(4, size=m, p=probs)
        src = (src << 1) | (q >> 1)
        dst = (dst << 1) | (q & 1)
    g = from_edges(n, np.stack([src, dst], 1))
    if shuffle:
        g = shuffle_vertices(g, seed=seed + 1)
    return g


def rmat_er(scale: int, edge_factor: int = 8, seed: int = 0) -> CSRGraph:
    return rmat(scale, edge_factor, 0.25, 0.25, 0.25, seed=seed)


def rmat_g(scale: int, edge_factor: int = 8, seed: int = 0) -> CSRGraph:
    return rmat(scale, edge_factor, 0.45, 0.15, 0.15, seed=seed)


def rmat_b(scale: int, edge_factor: int = 8, seed: int = 0) -> CSRGraph:
    return rmat(scale, edge_factor, 0.55, 0.15, 0.15, seed=seed)


def mesh2d(nx: int, ny: int, anisotropy: float = 4.0, seed: int = 0) -> CSRGraph:
    """2D triangular mesh of a structured grid (each quad split into 2 tris).

    Vertex graph degree <= 8 like a CFD-adapted anisotropic triangulation;
    ``anisotropy`` only perturbs the split direction pattern (connectivity-level
    anisotropy), matching the paper's low-degree 2D regime.
    """
    n = nx * ny
    vid = lambda i, j: i * ny + j
    ii, jj = np.meshgrid(np.arange(nx - 1), np.arange(ny - 1), indexing="ij")
    ii, jj = ii.ravel(), jj.ravel()
    v00, v01 = vid(ii, jj), vid(ii, jj + 1)
    v10, v11 = vid(ii + 1, jj), vid(ii + 1, jj + 1)
    rng = np.random.default_rng(seed)
    # anisotropy-biased diagonal choice per quad
    diag = rng.random(len(ii)) < (anisotropy / (1.0 + anisotropy))
    # edges: quad boundary + one diagonal
    e = [np.stack([v00, v01], 1), np.stack([v00, v10], 1),
         np.stack([v01, v11], 1), np.stack([v10, v11], 1),
         np.stack([np.where(diag, v00, v01), np.where(diag, v11, v10)], 1)]
    return from_edges(n, np.concatenate(e, axis=0))


def mesh3d(nx: int, ny: int, nz: int) -> CSRGraph:
    """3D tetrahedral mesh of a structured grid (each cube -> 6 tets).

    Vertex graph degree up to ~26 — the same high-degree regime as bmw3_2/pwtk
    where the paper sees RSOC's largest advantage.
    """
    vid = lambda i, j, k: (i * ny + j) * nz + k
    ii, jj, kk = np.meshgrid(np.arange(nx - 1), np.arange(ny - 1), np.arange(nz - 1),
                             indexing="ij")
    ii, jj, kk = ii.ravel(), jj.ravel(), kk.ravel()
    c = {}
    for di in (0, 1):
        for dj in (0, 1):
            for dk in (0, 1):
                c[(di, dj, dk)] = vid(ii + di, jj + dj, kk + dk)
    # 6-tet decomposition (Kuhn triangulation) of each cube
    tets = [
        (c[0, 0, 0], c[1, 0, 0], c[1, 1, 0], c[1, 1, 1]),
        (c[0, 0, 0], c[1, 0, 0], c[1, 0, 1], c[1, 1, 1]),
        (c[0, 0, 0], c[0, 1, 0], c[1, 1, 0], c[1, 1, 1]),
        (c[0, 0, 0], c[0, 1, 0], c[0, 1, 1], c[1, 1, 1]),
        (c[0, 0, 0], c[0, 0, 1], c[1, 0, 1], c[1, 1, 1]),
        (c[0, 0, 0], c[0, 0, 1], c[0, 1, 1], c[1, 1, 1]),
    ]
    edges = []
    for t in tets:
        for x in range(4):
            for y in range(x + 1, 4):
                edges.append(np.stack([t[x], t[y]], 1))
    return from_edges(nx * ny * nz, np.concatenate(edges, axis=0))


def bipartite_random(n_left: int, n_right: int, avg_left_degree: float = 4.0,
                     seed: int = 0) -> CSRGraph:
    """Random bipartite graph: vertices [0, n_left) are the left side,
    [n_left, n_left + n_right) the right; edges only cross sides.

    The Jacobian-sparsity analogue (left = columns, right = rows, edge =
    structural nonzero) driving ``core.distance2.color_bipartite_partial``.
    """
    rng = np.random.default_rng(seed)
    m = int(n_left * avg_left_degree)
    src = rng.integers(0, n_left, size=m)
    dst = n_left + rng.integers(0, n_right, size=m)
    return from_edges(n_left + n_right, np.stack([src, dst], axis=1))


def bipartite_banded(n_left: int, n_right: int, band: int = 3) -> CSRGraph:
    """Banded Jacobian sparsity pattern (1-D stencil discretization): column
    j hits the rows within ``band`` of its scaled diagonal position."""
    j = np.arange(n_left)
    diag = (j * n_right) // max(n_left, 1)
    blocks = []
    for off in range(-band, band + 1):
        i = diag + off
        ok = (i >= 0) & (i < n_right)
        blocks.append(np.stack([j[ok], n_left + i[ok]], axis=1))
    return from_edges(n_left + n_right, np.concatenate(blocks, axis=0))


def erdos_renyi(n: int, avg_degree: float, seed: int = 0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree / 2)
    edges = rng.integers(0, n, size=(m, 2))
    return from_edges(n, edges)


def random_geometric_positions(n: int, box: float = 10.0, seed: int = 0) -> np.ndarray:
    """Positions for molecule-like point clouds (NequIP inputs)."""
    rng = np.random.default_rng(seed)
    return rng.uniform(0, box, size=(n, 3)).astype(np.float32)


def radius_graph(positions: np.ndarray, cutoff: float, max_degree: int | None = None) -> CSRGraph:
    """Edges between points within ``cutoff`` (O(n^2) host build; molecule scale)."""
    n = len(positions)
    d2 = ((positions[:, None, :] - positions[None, :, :]) ** 2).sum(-1)
    mask = (d2 < cutoff * cutoff) & ~np.eye(n, dtype=bool)
    src, dst = np.nonzero(mask)
    g = from_edges(n, np.stack([src, dst], 1), symmetrize=False)
    if max_degree is not None and g.max_degree > max_degree:
        # keep the nearest max_degree neighbors per vertex
        keep_src, keep_dst = [], []
        for v in range(n):
            nb = g.neighbors(v)
            order = np.argsort(d2[v, nb])[:max_degree]
            keep_src.append(np.full(len(order), v)); keep_dst.append(nb[order])
        g = from_edges(n, np.stack([np.concatenate(keep_src), np.concatenate(keep_dst)], 1))
    return g


# ---- paper benchmark suite ------------------------------------------------

def paper_suite(scale: str = "small") -> dict[str, CSRGraph]:
    """The six graph classes of the paper's Table 1 at a CPU-feasible scale.

    scale='tiny'   : ~0.5-1k vertices  (CI bench-smoke, sub-second sections)
    scale='small'  : ~10-50k vertices  (unit/bench default, seconds)
    scale='medium' : ~250k vertex meshes + 2^18-vertex RMATs (paper-mesh-scale)
    """
    if scale == "tiny":
        return {
            "mesh2d": mesh2d(24, 24),
            "bmw3_2": mesh3d(8, 8, 8),
            "pwtk": mesh3d(10, 8, 6),
            "rmat_er": rmat_er(9),
            "rmat_g": rmat_g(9),
            "rmat_b": rmat_b(9),
        }
    if scale == "small":
        return {
            "mesh2d": mesh2d(128, 128),
            "bmw3_2": mesh3d(24, 24, 24),
            "pwtk": mesh3d(32, 24, 18),
            "rmat_er": rmat_er(13),
            "rmat_g": rmat_g(13),
            "rmat_b": rmat_b(13),
        }
    if scale == "medium":
        return {
            "mesh2d": mesh2d(500, 500),
            "bmw3_2": mesh3d(61, 61, 61),
            "pwtk": mesh3d(72, 55, 55),
            "rmat_er": rmat_er(18),
            "rmat_g": rmat_g(18),
            "rmat_b": rmat_b(18),
        }
    raise ValueError(scale)
