"""Serving engines: long-lived, device-resident, submit/step APIs.

Two engines share the pattern (fixed-shape state, arrival/departure without
recompilation, queries always reflecting a fully-stepped state):

  * ``ServeEngine`` (`serve_loop.py`) — continuous-batching LM decode over
    fixed-capacity KV slots.
  * ``ColoringService`` (`repro.dynamic.service`) — incremental graph
    recoloring over mutating graphs, re-exported here as part of the
    serving surface (DESIGN.md §7.3).
"""
from repro.serving.serve_loop import Request, ServeEngine  # noqa: F401
from repro.dynamic.service import ColoringService  # noqa: F401
