"""Batched serving loop: continuous-batching-lite over fixed-capacity slots.

The engine holds ``batch`` request slots, each with a fixed-capacity KV (or
MLA latent) cache.  ``submit`` prefills a prompt into a free slot;
``step_all`` advances every active slot one token (one jitted decode_step for
the whole batch — requests are batched at the step level, the vLLM-style
throughput pattern without paging).  Finished slots (EOS or max_tokens) free
immediately and can be re-filled between steps — arrival/departure never
recompiles because shapes are static.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as TF


@dataclasses.dataclass
class Request:
    prompt: np.ndarray
    max_new_tokens: int = 32
    eos_id: int = -1             # -1: never stops early
    out_tokens: list = dataclasses.field(default_factory=list)
    slot: int = -1
    done: bool = False


class ServeEngine:
    def __init__(self, params, cfg: TF.TransformerConfig, batch: int,
                 max_len: int, greedy: bool = True, seed: int = 0):
        self.params, self.cfg = params, cfg
        self.batch, self.max_len = batch, max_len
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self.cache = TF.make_empty_cache(cfg, batch, max_len)
        self.length = jnp.zeros((batch,), jnp.int32)
        self.cur_token = jnp.zeros((batch,), jnp.int32)
        self.active: list[Optional[Request]] = [None] * batch
        self.budget = np.zeros(batch, np.int64)

        self._prefill = jax.jit(lambda p, t: TF.prefill(p, cfg, t))
        self._decode = jax.jit(lambda p, tok, cache, ln:
                               TF.decode_step(p, cfg, tok, cache, ln))

    # -- slot management ----------------------------------------------------

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    def submit(self, req: Request) -> bool:
        """Prefill ``req`` into a free slot; False if engine is full."""
        slots = self.free_slots()
        if not slots:
            return False
        slot = slots[0]
        L = len(req.prompt)
        logits, kv = self._prefill(self.params,
                                   jnp.asarray(req.prompt, jnp.int32)[None])
        # write the prefill caches into the slot's fixed-capacity buffers
        for k, v in kv.items():
            buf = self.cache[k]
            if self.cfg.attn_type == "mla":      # (layers, 1, L, r)
                upd = v[:, 0]
                buf = jax.lax.dynamic_update_slice(
                    buf, upd[:, None].astype(buf.dtype),
                    (0, slot, 0, 0))
            else:                                # (layers, 1, Hkv, L, Dh)
                upd = v[:, 0]
                buf = jax.lax.dynamic_update_slice(
                    buf, upd[:, None].astype(buf.dtype),
                    (0, slot, 0, 0, 0))
            self.cache[k] = buf
        tok = int(jnp.argmax(logits[0])) if self.greedy else \
            int(jax.random.categorical(self._next_key(), logits[0]))
        req.out_tokens.append(tok)
        req.slot = slot
        self.active[slot] = req
        self.length = self.length.at[slot].set(L)
        self.cur_token = self.cur_token.at[slot].set(tok)
        self.budget[slot] = req.max_new_tokens - 1
        return True

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    # -- decode -------------------------------------------------------------

    def step_all(self) -> int:
        """One batched decode step for all active slots; returns #finished."""
        if all(r is None for r in self.active):
            return 0
        logits, self.cache = self._decode(self.params, self.cur_token,
                                          self.cache, self.length)
        if self.greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(self._next_key(), logits).astype(jnp.int32)
        self.length = jnp.minimum(self.length + 1, self.max_len - 1)
        self.cur_token = nxt
        nxt_np = np.asarray(nxt)
        n_done = 0
        for i, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(nxt_np[i])
            req.out_tokens.append(tok)
            self.budget[i] -= 1
            if self.budget[i] <= 0 or tok == req.eos_id:
                req.done = True
                self.active[i] = None
                n_done += 1
        return n_done

    def run(self, requests: list[Request], max_steps: int = 10_000):
        """Serve a request list to completion with continuous batching."""
        pending = list(requests)
        steps = 0
        while (pending or any(r is not None for r in self.active)) \
                and steps < max_steps:
            while pending and self.free_slots():
                self.submit(pending.pop(0))
            self.step_all()
            steps += 1
        return requests
