"""Synthetic, seeded, checkpointable data streams for every arch family.

Every stream exposes:
  state()            -> json-serializable dict (stored in checkpoints)
  restore(state)     -> resume exactly (deterministic counter-based RNG)
  __next__           -> dict of numpy arrays with static shapes

Determinism: batches are a pure function of (seed, step) via
``np.random.default_rng(hash((seed, step)))`` — restoring from a checkpoint
at step k reproduces the identical remaining stream, so a restart after a
node failure is bitwise-reproducible (fault-tolerance requirement).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.graphs.csr import CSRGraph
from repro.graphs.sampler import NeighborSampler, union_caps, union_pad


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.uint64(seed * 0x9E3779B9 + step * 2654435761))


class Stream:
    """Base: counter-based, restartable."""

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.step = 0

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def restore(self, state: dict) -> None:
        self.seed = int(state["seed"])
        self.step = int(state["step"])

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        b = self._make(_rng(self.seed, self.step))
        self.step += 1
        return b

    def _make(self, rng) -> dict:
        raise NotImplementedError


class TokenStream(Stream):
    """LM tokens: zipf-distributed ids (realistic logit/loss magnitudes)."""

    def __init__(self, batch: int, seq_len: int, vocab: int, seed: int = 0):
        super().__init__(seed)
        self.batch, self.seq_len, self.vocab = batch, seq_len, vocab

    def _make(self, rng):
        toks = rng.zipf(1.3, size=(self.batch, self.seq_len + 1))
        toks = np.minimum(toks, self.vocab - 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class RecsysStream(Stream):
    def __init__(self, batch: int, n_dense: int, n_sparse: int, vocabs,
                 max_hots: int = 1, seed: int = 0):
        super().__init__(seed)
        self.batch, self.n_dense, self.n_sparse = batch, n_dense, n_sparse
        self.vocabs = list(vocabs)
        self.max_hots = max_hots

    def _make(self, rng):
        dense = rng.standard_normal((self.batch, self.n_dense)).astype(np.float32)
        sp = np.stack([rng.integers(0, v, size=(self.batch, self.max_hots))
                       for v in self.vocabs], axis=1).astype(np.int32)
        if self.max_hots > 1:  # ragged bags: pad a random suffix
            kill = rng.random((self.batch, self.n_sparse, self.max_hots)) < 0.3
            kill[..., 0] = False
            sp[kill] = -1
        # click labels correlated with a fixed random hyperplane (learnable)
        w = _rng(self.seed, 0).standard_normal(self.n_dense)
        p = 1.0 / (1.0 + np.exp(-(dense @ w) / np.sqrt(self.n_dense)))
        labels = (rng.random(self.batch) < p).astype(np.int32)
        return {"dense": dense, "sparse": sp, "labels": labels}


class FullGraphStream(Stream):
    """Full-batch GNN: fixed graph + features, fresh train mask per step.

    Emits the cell layout: one SINK node appended, edges padded to a
    multiple of ``pad_edges_to`` with sink->sink self-loops (launch/cells)."""

    def __init__(self, graph: CSRGraph, d_feat: int, n_classes: int,
                 seed: int = 0, pad_edges_to: int = 8192):
        super().__init__(seed)
        g = graph
        rng0 = _rng(seed, 0)
        from repro.graphs.csr import to_edge_list
        e = to_edge_list(g)
        n1 = g.n_vertices + 1                   # + sink
        sink = g.n_vertices
        E = len(e)
        e_pad = -(-max(E, 1) // pad_edges_to) * pad_edges_to if pad_edges_to \
            else E
        src = np.full(e_pad, sink, np.int32)
        dst = np.full(e_pad, sink, np.int32)
        src[:E] = e[:, 0]
        dst[:E] = e[:, 1]
        feats = rng0.standard_normal((n1, d_feat)).astype(np.float32)
        feats[sink] = 0.0
        self.const = {
            "src": src, "dst": dst, "feats": feats,
            "labels": rng0.integers(0, n_classes, n1).astype(np.int32),
        }
        self.n_nodes = n1
        self.sink = sink

    def _make(self, rng):
        mask = rng.random(self.n_nodes) < 0.6   # train split mask per step
        mask[self.sink] = False
        return dict(self.const, train_mask=mask.astype(np.float32))


class SampledGraphStream(Stream):
    """Minibatch GNN via the fanout sampler, flattened to one static-shape
    union subgraph (see sampler.union_pad).  ``fanouts`` are given input-side
    first (the published convention, e.g. 15-10); sampling expands seed-side
    first, so the sampler runs them reversed."""

    def __init__(self, graph: CSRGraph, d_feat: int, n_classes: int,
                 batch_nodes: int, fanouts, seed: int = 0):
        super().__init__(seed)
        self.g = graph
        self.batch_nodes = batch_nodes
        self.fanouts = tuple(fanouts)
        self.fanouts_sampling = tuple(reversed(self.fanouts))
        self.sampler = NeighborSampler(graph, self.fanouts_sampling, seed)
        rng0 = _rng(seed, 0)
        self.feats = rng0.standard_normal((graph.n_vertices, d_feat)).astype(np.float32)
        self.labels = rng0.integers(0, n_classes, graph.n_vertices).astype(np.int32)

    def restore(self, state):
        super().restore(state)
        self.sampler = NeighborSampler(self.g, self.fanouts_sampling, self.seed)

    def _make(self, rng):
        n = self.g.n_vertices
        seeds = rng.choice(n, size=min(self.batch_nodes, n), replace=False)
        if len(seeds) < self.batch_nodes:   # tiny graphs: repeat is fine
            seeds = np.resize(seeds, self.batch_nodes)
        batch = self.sampler.sample(seeds)
        out = union_pad(batch, self.batch_nodes, self.fanouts_sampling)
        feats = self.feats[out["nodes"] % n]
        feats[-1] = 0.0                      # sink row
        out["feats"] = feats
        out["labels"] = self.labels[seeds].astype(np.int32)
        return out


class MoleculeStream(Stream):
    """Batched small graphs, flattened block-diagonally (static shapes)."""

    def __init__(self, n_nodes: int, n_edges: int, batch: int,
                 n_species: int = 8, box: float = 6.0, seed: int = 0,
                 d_feat: int = 16):
        super().__init__(seed)
        self.n_nodes, self.n_edges, self.batch = n_nodes, n_edges, batch
        self.n_species, self.box, self.d_feat = n_species, box, d_feat

    def _make(self, rng, pad_edges_to: int = 8192):
        B, N, E = self.batch, self.n_nodes, self.n_edges
        pos = rng.uniform(0, self.box, (B, N, 3)).astype(np.float32)
        species = rng.integers(0, self.n_species, (B, N)).astype(np.int32)
        # E random pairs per graph (messages flow both directions anyway)
        src = rng.integers(0, N, (B, E)).astype(np.int32)
        off = rng.integers(1, N, (B, E)).astype(np.int32)
        dst = ((src + off) % N).astype(np.int32)
        base = (np.arange(B, dtype=np.int32) * N)[:, None]
        energy = np.sin(pos.sum((1, 2))).astype(np.float32)   # learnable target
        sink = B * N                              # + sink node, padded edges
        e_flat_s = (src + base).reshape(B * E)
        e_flat_d = (dst + base).reshape(B * E)
        e_pad = -(-len(e_flat_s) // pad_edges_to) * pad_edges_to \
            if pad_edges_to else len(e_flat_s)
        pad = e_pad - len(e_flat_s)
        graph_id = np.concatenate([np.repeat(np.arange(B, dtype=np.int32), N),
                                   np.int32([B])])   # sink -> dropped segment
        return {
            "positions": np.concatenate([pos.reshape(B * N, 3),
                                         np.zeros((1, 3), np.float32)]),
            "species": np.concatenate([species.reshape(B * N),
                                       np.int32([0])]),
            "src": np.concatenate([e_flat_s,
                                   np.full(pad, sink, np.int32)]),
            "dst": np.concatenate([e_flat_d,
                                   np.full(pad, sink, np.int32)]),
            "graph_id": graph_id,
            "energy": energy,
            "feats": np.concatenate([
                rng.standard_normal((B * N, self.d_feat)).astype(np.float32),
                np.zeros((1, self.d_feat), np.float32)]),
        }
