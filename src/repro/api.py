"""One front door for every coloring engine: ``repro.api.color`` (DESIGN.md §11).

Rokos et al.'s contribution is one speculative detect-and-recolor scheme that
subsumes its predecessors, and the optimistic loop extends unchanged to
distance-2, bipartite partial, incremental and distributed coloring — so the
public API is one entry point parameterized by a **spec**, not one function
per variant:

    from repro import api

    res = api.color(g)                                       # RSOC, defaults
    res = api.color(g, algorithm="cat", n_chunks=32)         # overrides
    spec = api.ColoringSpec(algorithm="rsoc", distance=2, seed=1)
    res = api.color(g, spec)                                 # explicit spec
    res.spec                                                 # resolved echo

Engines live in a registry keyed by ``(algorithm, distance, mode, backend)``
(``repro.registry``); ``core/coloring.py``, ``core/frontier.py``,
``core/distance2.py``, ``core/distributed.py`` and ``dynamic/incremental.py``
register theirs at import time, and new engines (distance-d, star/acyclic)
are new registry entries, not new public functions.  Unsupported combos are
rejected by ``ColoringSpec.validate`` with the nearest supported spec named.

The legacy ``color_*`` entry points survive one release as deprecation shims
routing through this module (bit-identical by construction; each warns once),
and ``repro.core.ALGORITHMS`` is a live registry view.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro import obs, registry
from repro.registry import register_engine  # noqa: F401  (re-export)
from repro.core.context import (DEFAULT_FORBIDDEN_IMPL, PassContext,
                                resolve_impl)
from repro.core.coloring import ColoringResult

# importing the engine modules populates the registry (order is not
# significant; each module registers its own combos)
from repro.core import coloring as _coloring        # noqa: F401
from repro.core import frontier as _frontier        # noqa: F401
from repro.core import distance2 as _distance2      # noqa: F401
from repro.core import distributed as _distributed  # noqa: F401
from repro.dynamic import incremental as _incremental  # noqa: F401
from repro.dynamic import sharded as _sharded          # noqa: F401

MODES = ("static", "incremental", "partial")
BACKENDS = ("local", "distributed")


@dataclasses.dataclass(frozen=True)
class ColoringSpec:
    """Complete, hashable description of a coloring task (minus the graph).

    The four axes ``algorithm`` / ``distance`` / ``mode`` / ``backend``
    select the engine from the registry; the remaining fields parameterize
    it.  Fields an engine does not consume are inert (e.g. ``max_rounds``
    for gm, ``n_chunks`` for jp) — the support matrix in DESIGN.md §11
    records which fields bite where.
    """

    algorithm: str = "rsoc"        # rsoc | cat | gm | jp | rsoc_compact
    distance: int = 1              # 1 | 2 (native two-hop; d>2 on ROADMAP)
    mode: str = "static"           # static | incremental | partial
    backend: str = "local"         # local | distributed (needs mesh=)
    seed: int = 0                  # relabel + priority RNG seed
    C: Optional[int] = None        # color cap (None: engine picks, then
                                   # doubles on overflow; result.final_C)
    n_chunks: int = 16             # sequential chunks/pass (1/threads)
    max_rounds: int = 1000         # repair-round bound
    forbidden_impl: Optional[str] = None   # bitset | dense (None: default)
    ell_cap: int = 512             # ELL width cap; hubs spill to COO
    relabel: bool = True           # host-side random vertex relabel
    frontier_frac: float = 0.125   # compacted-frontier capacity fraction
    n_left: Optional[int] = None   # mode="partial": bipartite left size
    ell_slack: int = 4             # mode="incremental": free ELL slots/row
    ovf_cap: Optional[int] = None  # mode="incremental": overflow buffer cap
    delta_cap: int = 2048          # mode="incremental": update-slice width
    trace: bool = False            # attach an obs.RunTrace to result.trace
                                   # (zero device overhead when False; also
                                   # forced by obs.trace() / REPRO_TRACE=1)
    max_cap_retries: Optional[int] = None  # color-cap doubling budget per
                                   # solve (None: unbounded, the legacy
                                   # behavior); exhaustion raises
                                   # CapRetryExhausted -> degradation
                                   # ladder in the dynamic stack (§14)
    max_ovf_growth: Optional[int] = None   # mode="incremental": overflow
                                   # buffer growth budget per batch (None:
                                   # unbounded); exhaustion raises
                                   # OvfGrowthExhausted -> ladder (§14)

    # -- resolution / validation -------------------------------------------

    def resolved(self) -> "ColoringSpec":
        """Spec with every defaultable field pinned (what ``color`` echoes
        into ``ColoringResult.spec``): same spec in => same colors out."""
        return dataclasses.replace(
            self, forbidden_impl=resolve_impl(self.forbidden_impl))

    def validate(self) -> "ColoringSpec":
        """Reject malformed fields and unsupported combos with actionable
        errors (the nearest supported spec is named)."""
        if self.mode not in MODES:
            raise ValueError(f"unknown mode {self.mode!r}; known: {MODES}")
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; known: {BACKENDS}")
        resolve_impl(self.forbidden_impl)   # raises on unknown impl
        if self.n_chunks < 1:
            raise ValueError(f"n_chunks must be >= 1 (got {self.n_chunks})")
        if self.max_rounds < 1:
            raise ValueError(
                f"max_rounds must be >= 1 (got {self.max_rounds})")
        if self.C is not None and self.C < 1:
            raise ValueError(f"C must be >= 1 or None (got {self.C})")
        if self.ell_cap < 1:
            raise ValueError(f"ell_cap must be >= 1 (got {self.ell_cap})")
        if self.max_cap_retries is not None and self.max_cap_retries < 0:
            raise ValueError(
                f"max_cap_retries must be >= 0 or None "
                f"(got {self.max_cap_retries})")
        if self.max_ovf_growth is not None and self.max_ovf_growth < 0:
            raise ValueError(
                f"max_ovf_growth must be >= 0 or None "
                f"(got {self.max_ovf_growth})")
        if not 0.0 < self.frontier_frac <= 1.0:
            raise ValueError(
                f"frontier_frac must be in (0, 1] (got {self.frontier_frac})")
        if self.mode == "partial":
            if self.n_left is None:
                raise ValueError(
                    "mode='partial' requires n_left (the bipartite "
                    "left-side size to color)")
        elif self.n_left is not None:
            raise ValueError(
                f"n_left is only meaningful with mode='partial' "
                f"(got mode={self.mode!r})")
        key = (self.algorithm, self.distance, self.mode, self.backend)
        if not registry.has_engine(*key):
            near = registry.nearest_key(key)
            raise ValueError(
                f"no engine registered for {registry.format_key(key)}; "
                f"nearest supported spec: {registry.format_key(near)} "
                f"(full matrix: repro.api.supported_specs())")
        return self

    # -- identity ----------------------------------------------------------

    def asdict(self) -> dict:
        return dataclasses.asdict(self)

    def spec_key(self) -> str:
        """Stable one-line identity of the *resolved* spec, recorded in
        every BENCH_*.json row so perf trajectories key on the exact task."""
        s = self.resolved()
        return ";".join(f"{f.name}={getattr(s, f.name)}"
                        for f in dataclasses.fields(s))


SPEC_FIELDS = tuple(f.name for f in dataclasses.fields(ColoringSpec))


def color(g, spec: Optional[ColoringSpec] = None, *,
          mesh=None, axis: Optional[str] = None,
          **overrides) -> ColoringResult:
    """Color graph ``g`` per ``spec`` (defaults + ``**overrides``).

    ``overrides`` are ``ColoringSpec`` field replacements applied on top of
    ``spec`` (or on the default spec).  ``mesh``/``axis`` are runtime device
    arguments for ``backend='distributed'`` — they select hardware, not the
    task, so they are not spec fields.

    Returns a ``ColoringResult`` whose ``spec`` field echoes the resolved
    spec (reproducibility: feed it back in to replay the run) and, for
    ``mode='incremental'``, whose ``state`` field carries the
    ``DynamicColoringState`` for subsequent ``recolor_incremental`` batches.
    """
    if spec is None:
        spec = ColoringSpec()
    elif not isinstance(spec, ColoringSpec):
        raise TypeError(
            f"spec must be a ColoringSpec (got {type(spec).__name__}); "
            f"pass field overrides as keyword arguments")
    if overrides:
        unknown = sorted(set(overrides) - set(SPEC_FIELDS))
        if unknown:
            raise TypeError(
                f"unknown ColoringSpec override(s) {unknown}; "
                f"spec fields: {list(SPEC_FIELDS)}")
        spec = dataclasses.replace(spec, **overrides)
    spec = spec.resolved()
    spec.validate()
    engine = registry.get_engine(spec.algorithm, spec.distance, spec.mode,
                                 spec.backend)
    kw = {}
    if spec.backend == "distributed":
        kw["mesh"] = mesh           # engine raises if None
        kw["axis"] = axis if axis is not None else "data"
    elif mesh is not None or axis is not None:
        raise ValueError(
            f"mesh=/axis= are only meaningful with backend='distributed' "
            f"(spec.backend={spec.backend!r})")
    if not obs.tracing_enabled(spec.trace):
        # untraced fast path: byte-for-byte the pre-obs call
        return dataclasses.replace(engine(g, spec, **kw), spec=spec)
    with obs.run_tracer() as tracer:
        res = engine(g, spec, **kw)
    engine_key = registry.format_key(
        (spec.algorithm, spec.distance, spec.mode, spec.backend))
    run_trace = tracer.finish(res, spec, engine_key, g.n_vertices)
    obs.collect(run_trace)
    return dataclasses.replace(res, spec=spec, trace=run_trace)


def supported_specs() -> list[dict]:
    """The registry's support matrix: one row per registered engine combo,
    with the legacy entry point it replaces (DESIGN.md §11)."""
    return [{"algorithm": a, "distance": d, "mode": m, "backend": b,
             "replaces": fn.replaces}
            for (a, d, m, b), fn in registry.engine_items()]


def algorithms(distance: int = 1, mode: str = "static",
               backend: str = "local") -> list[str]:
    """Algorithm names registered for a given (distance, mode, backend)."""
    return sorted({a for (a, d, m, b) in registry.engine_keys()
                   if (d, m, b) == (distance, mode, backend)})


__all__ = [
    "BACKENDS",
    "ColoringResult",
    "ColoringSpec",
    "DEFAULT_FORBIDDEN_IMPL",
    "MODES",
    "PassContext",
    "SPEC_FIELDS",
    "algorithms",
    "color",
    "register_engine",
    "supported_specs",
]
