"""Pallas TPU kernel: RSOC's fused detect-and-recolor over one chunk.

One VMEM round-trip does both the paper's conflict detection and the
immediate repair — the kernel-level expression of merging Alg. 2's two phases
into Alg. 3's single phase: neighbor colors are gathered ONCE and feed both
the defect test (same color as a higher-priority neighbor) and the first-fit
re-color.  The forbidden accumulator is the packed (BV, C//32) bitset of
DESIGN.md §10 (inline pack + branch-free mex via ``core/bitset.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import bitset


def _detect_recolor_kernel(ell_ref, colors_ref, pri_ref, U_ref, rowc_ref,
                           rowp_ref, newc_ref, rec_ref, ovf_ref,
                           *, C: int, n: int):
    ell = ell_ref[...]                        # (BV, W)
    colors = colors_ref[...]                  # (n,)
    pri = pri_ref[...]                        # (n,)
    U = U_ref[...]                            # (BV,)
    c_r = rowc_ref[...]                       # (BV,) this block's colors
    p_r = rowp_ref[...]                       # (BV,)
    BV, W = ell.shape

    def body(j, carry):
        forb, defect = carry
        idx = ell[:, j]
        safe = jnp.clip(idx, 0, n - 1)
        nc = jnp.where(idx >= 0, colors[safe], -1)
        np_ = jnp.where(idx >= 0, pri[safe], -1)
        defect = defect | ((nc == c_r) & (c_r >= 0) & (np_ > p_r))
        return bitset.or_color(forb, nc, C), defect

    forb, defect = jax.lax.fori_loop(
        0, W, body,
        (bitset.init_words(BV, C), jnp.zeros((BV,), jnp.bool_)))
    # fused epilogue: mex runs on the packed words while they are still
    # VMEM-resident — the (BV, C//32) forbidden table never reaches HBM
    newc, rec, ovf = bitset.recolor_epilogue(forb, defect, U, c_r, C)
    newc_ref[...] = newc
    rec_ref[...] = rec
    ovf_ref[...] = ovf


@functools.partial(jax.jit,
                   static_argnames=("C", "row_start", "block_rows", "interpret"))
def detect_recolor(ell, colors, pri, U_rows, row_start: int, C: int = 64,
                   block_rows: int = 256, interpret: bool = True):
    """Fused RSOC pass for rows [row_start, row_start + R).

    ell:    (R, W) neighbor tile for those rows
    colors: (n,) global colors;  pri: (n,) priorities
    U_rows: (R,) bool, in-frontier mask for those rows
    Returns (new row colors (R,), recolored (R,), overflow (R,)).
    """
    R, W = ell.shape
    n = colors.shape[0]
    assert R % block_rows == 0
    rowc = jax.lax.dynamic_slice_in_dim(colors, row_start, R, 0)
    rowp = jax.lax.dynamic_slice_in_dim(pri, row_start, R, 0)
    grid = (R // block_rows,)
    kernel = functools.partial(_detect_recolor_kernel, C=C, n=n)
    blk = lambda: pl.BlockSpec((block_rows,), lambda i: (i,))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, W), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            blk(), blk(), blk(),
        ],
        out_specs=[blk(), blk(), blk()],
        out_shape=[
            jax.ShapeDtypeStruct((R,), jnp.int32),
            jax.ShapeDtypeStruct((R,), jnp.bool_),
            jax.ShapeDtypeStruct((R,), jnp.bool_),
        ],
        interpret=interpret,
    )(ell, colors, pri, U_rows, rowc, rowp)
