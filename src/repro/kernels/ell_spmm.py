"""Pallas TPU kernel: ELL neighbor aggregation (GNN message passing).

Reuses the coloring kernels' rectangular ELL layout: out[v] = reduce over
feats[nbr[v, :]].  Grid is (vertex blocks, feature blocks); each program
gathers a (BV, W) neighbor tile against a (n, BF) feature column panel held
in VMEM and reduces on the VPU.  Feature panels bound VMEM use to n*BF*4
bytes; the ops.py wrapper picks BF accordingly and falls back to the
segment-sum jnp path for graphs whose node count makes any panel too large
(page-indirected DMA design for that regime is documented in DESIGN.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ell_spmm_kernel(ell_ref, feats_ref, out_ref, *, op: str, n: int):
    ell = ell_ref[...]                       # (BV, W)
    feats = feats_ref[...]                   # (n, BF)
    BV, W = ell.shape
    BF = feats.shape[1]
    if op == "max":
        init = jnp.full((BV, BF), -jnp.inf, feats.dtype)
    else:
        init = jnp.zeros((BV, BF), feats.dtype)

    def body(j, acc):
        idx = ell[:, j]
        valid = idx >= 0
        row = feats[jnp.clip(idx, 0, n - 1)]
        if op == "max":
            row = jnp.where(valid[:, None], row, -jnp.inf)
            return jnp.maximum(acc, row)
        row = jnp.where(valid[:, None], row, 0)
        return acc + row

    acc = jax.lax.fori_loop(0, W, body, init)
    if op == "mean":
        cnt = jnp.maximum((ell >= 0).sum(axis=1), 1).astype(feats.dtype)
        acc = acc / cnt[:, None]
    if op == "max":
        acc = jnp.where(jnp.isfinite(acc), acc, 0)
    out_ref[...] = acc


@functools.partial(jax.jit,
                   static_argnames=("op", "block_rows", "block_feats",
                                    "interpret"))
def ell_spmm(ell, feats, op: str = "sum", block_rows: int = 128,
             block_feats: int = 128, interpret: bool = True):
    """Aggregate neighbor features over an ELL table.

    ell: (R, W) int32; feats: (n, d) float32/bf16 -> (R, d)
    """
    R, W = ell.shape
    n, d = feats.shape
    br = min(block_rows, R)
    bf = min(block_feats, d)
    assert R % br == 0 and d % bf == 0, (R, d, br, bf)
    grid = (R // br, d // bf)
    kernel = functools.partial(_ell_spmm_kernel, op=op, n=n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, W), lambda i, j: (i, 0)),
            pl.BlockSpec((n, bf), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((br, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((R, d), feats.dtype),
        interpret=interpret,
    )(ell, feats)
