"""Pallas TPU kernel: first-fit tentative coloring over an ELL vertex tile.

The paper's hot loop (gather neighbor colors -> forbidden set -> smallest free
color).  TPU adaptation (DESIGN.md §2, §10): rectangular (BV, W) ELL tiles in
VMEM; the forbidden set is a packed (BV, C//32) int32 bitset built by W
vectorized compare+OR steps on the VPU — 32× fewer compare lanes and 8× less
VMEM than the old (BV, C) one-hot bool table, which is what lets the tile
take bigger BV/C without spilling.  First-fit = branch-free mex over the
packed words (isolate-lowest-zero-bit + float-exponent bit index,
``core/bitset.py`` — the identical code path the jnp engines trace), fused
into the kernel epilogue so the packed words never round-trip through HBM
(the degenerate no-defect case of ``bitset.recolor_epilogue``).  The color
vector is VMEM-resident per invocation; ``ops.firstfit_vmem_bytes`` is the
honest account and the ops.py wrapper falls back to the jnp path when it
busts the budget.

Grid: one program per BV-row block of the chunk being colored.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import bitset


def _firstfit_kernel(ell_ref, colors_ref, out_ref, ovf_ref, *, C: int, n: int):
    ell = ell_ref[...]                       # (BV, W) int32
    colors = colors_ref[...]                 # (n,) int32
    BV, W = ell.shape

    def body(j, forb):
        idx = ell[:, j]
        nc = colors[jnp.clip(idx, 0, n - 1)]
        nc = jnp.where(idx >= 0, nc, -1)
        return bitset.or_color(forb, nc, C)

    forb = jax.lax.fori_loop(0, W, body, bitset.init_words(BV, C))
    mex, ovf = bitset.mex_words(forb, C)
    out_ref[...] = mex
    ovf_ref[...] = ovf


@functools.partial(jax.jit, static_argnames=("C", "block_rows", "interpret"))
def firstfit(ell, colors, C: int = 64, block_rows: int = 256,
             interpret: bool = True):
    """First-fit colors for every ELL row. Returns (mex (R,), overflow (R,))."""
    R, W = ell.shape
    n = colors.shape[0]
    assert R % block_rows == 0, (R, block_rows)
    grid = (R // block_rows,)
    kernel = functools.partial(_firstfit_kernel, C=C, n=n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, W), lambda i: (i, 0)),   # ELL tile
            pl.BlockSpec((n,), lambda i: (0,)),                # full colors
        ],
        out_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R,), jnp.int32),
            jax.ShapeDtypeStruct((R,), jnp.bool_),
        ],
        interpret=interpret,
    )(ell, colors)
