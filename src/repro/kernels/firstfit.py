"""Pallas TPU kernel: first-fit tentative coloring over an ELL vertex tile.

The paper's hot loop (gather neighbor colors -> forbidden set -> smallest free
color).  TPU adaptation (DESIGN.md §2): rectangular (BV, W) ELL tiles in VMEM,
forbidden sets as a (BV, C) one-hot table built by W vectorized compares on
the VPU, first-fit = argmin over the color axis (priority encode).  The color
vector is VMEM-resident per invocation (graphs to ~4M vertices; beyond that
the ops.py wrapper falls back to the jnp path / page-indirected design notes).

Grid: one program per BV-row block of the chunk being colored.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _firstfit_kernel(ell_ref, colors_ref, out_ref, ovf_ref, *, C: int, n: int):
    ell = ell_ref[...]                       # (BV, W) int32
    colors = colors_ref[...]                 # (n,) int32
    BV, W = ell.shape

    def body(j, forb):
        idx = ell[:, j]
        nc = colors[jnp.clip(idx, 0, n - 1)]
        nc = jnp.where(idx >= 0, nc, -1)
        return forb | (nc[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, C), 1))

    forb = jax.lax.fori_loop(0, W, body, jnp.zeros((BV, C), jnp.bool_))
    out_ref[...] = jnp.argmin(forb.astype(jnp.int32), axis=1).astype(jnp.int32)
    ovf_ref[...] = forb.all(axis=1)


@functools.partial(jax.jit, static_argnames=("C", "block_rows", "interpret"))
def firstfit(ell, colors, C: int = 64, block_rows: int = 256,
             interpret: bool = True):
    """First-fit colors for every ELL row. Returns (mex (R,), overflow (R,))."""
    R, W = ell.shape
    n = colors.shape[0]
    assert R % block_rows == 0, (R, block_rows)
    grid = (R // block_rows,)
    kernel = functools.partial(_firstfit_kernel, C=C, n=n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, W), lambda i: (i, 0)),   # ELL tile
            pl.BlockSpec((n,), lambda i: (0,)),                # full colors
        ],
        out_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R,), jnp.int32),
            jax.ShapeDtypeStruct((R,), jnp.bool_),
        ],
        interpret=interpret,
    )(ell, colors)
