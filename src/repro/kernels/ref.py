"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

Each ``<name>_ref`` mirrors the corresponding kernel's contract exactly; the
kernel tests sweep shapes/dtypes and assert parity in interpret mode.

The coloring refs take ``impl``: "bitset" (default) traces the same packed
forbidden-set + branch-free mex the kernels use (core/bitset.py), "dense"
keeps the original (R, W, C) one-hot + argmin formulation as the
independent oracle — the parity tests cross-check all three corners
(kernel, bitset ref, dense ref) bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitset


def _forbidden_mex(nbrc, C: int, impl: str):
    """(R, W) gathered colors -> (mex (R,), all-forbidden (R,) bool)."""
    if impl == "dense":
        forb = (nbrc[:, :, None] == jnp.arange(C)[None, None, :]).any(axis=1)
        mex = jnp.argmin(forb.astype(jnp.int32), axis=1).astype(jnp.int32)
        return mex, forb.all(axis=1)
    words = bitset.pack_from_nbrc(nbrc, C)
    return bitset.mex_words(words, C)


# --------------------------------------------------------------------------
# first-fit tentative coloring (paper Alg. 1 inner loop, one chunk)
# --------------------------------------------------------------------------

def firstfit_ref(ell, colors, C: int, impl: str = "bitset"):
    """Smallest color not used by any neighbor, per ELL row.

    ell:    (R, W) int32 neighbor ids, FILL(-1) padded
    colors: (n,)   int32 current colors (-1 uncolored)
    returns (mex (R,) int32, overflow (R,) bool)
    """
    n = colors.shape[0]
    nbrc = jnp.where(ell >= 0, colors[jnp.clip(ell, 0, n - 1)], -1)
    return _forbidden_mex(nbrc, C, impl)


# --------------------------------------------------------------------------
# fused detect-and-recolor (RSOC, paper Alg. 3 inner loop, one chunk)
# --------------------------------------------------------------------------

def detect_recolor_ref(ell, colors, pri, row_start: int, U_rows, C: int,
                       impl: str = "bitset"):
    """For rows [row_start, row_start+R): if in U and defective (same color as
    a higher-priority neighbor), re-color with first-fit; else keep.

    returns (new row colors (R,), recolored (R,) bool, overflow (R,) bool)
    """
    n = colors.shape[0]
    R = ell.shape[0]
    rows = row_start + jnp.arange(R)
    c_r = colors[rows]
    p_r = pri[rows]
    nbrc = jnp.where(ell >= 0, colors[jnp.clip(ell, 0, n - 1)], -1)
    nbrp = jnp.where(ell >= 0, pri[jnp.clip(ell, 0, n - 1)], -1)
    defect = ((nbrc == c_r[:, None]) & (c_r[:, None] >= 0)
              & (nbrp > p_r[:, None])).any(axis=1)
    mex, ovf = _forbidden_mex(nbrc, C, impl)
    return bitset.apply_recolor(U_rows & defect, mex, ovf, c_r)


# --------------------------------------------------------------------------
# fused two-hop detect-and-recolor (native distance-2, one chunk)
# --------------------------------------------------------------------------

def twohop_ref(ell_rows, ell_all, colors, pri, row_start: int, U_rows, C: int,
               impl: str = "bitset"):
    """Distance-2 analogue of ``detect_recolor_ref``: the forbidden set and
    the defect test read the colors of every vertex reachable in one or two
    hops — hop 2 re-gathers each neighbor's ELL row from ``ell_all``, so
    G²'s adjacency is consumed on the fly, never materialized.  A vertex is
    its own two-hop neighbor through any neighbor and is excluded.

    ell_rows: (R, W) neighbor tile for rows [row_start, row_start+R)
    ell_all:  (n_all, W) full neighbor table (hop-2 source), n_all >= n
    colors:   (n,) global colors;  pri: (n,) priorities;  U_rows: (R,) bool
    returns (new row colors (R,), recolored (R,) bool, overflow (R,) bool)
    """
    n = colors.shape[0]
    R, W = ell_rows.shape
    vid = row_start + jnp.arange(R, dtype=jnp.int32)
    c_r = colors[vid]
    p_r = pri[vid]
    live1 = ell_rows >= 0
    safe1 = jnp.clip(ell_rows, 0, n - 1)
    nc1 = jnp.where(live1, colors[safe1], -1)
    np1 = jnp.where(live1, pri[safe1], -1)
    e2 = ell_all[safe1].reshape(R, W * W)              # hop-2 ids
    live2 = (jnp.repeat(live1, W, axis=1) & (e2 >= 0)
             & (e2 != vid[:, None]))                   # self-exclusion
    s2 = jnp.clip(e2, 0, n - 1)
    nc2 = jnp.where(live2, colors[s2], -1)
    np2 = jnp.where(live2, pri[s2], -1)
    allc = jnp.concatenate([nc1, nc2], axis=1)
    allp = jnp.concatenate([np1, np2], axis=1)
    defect = ((allc == c_r[:, None]) & (c_r[:, None] >= 0)
              & (allp > p_r[:, None])).any(axis=1)
    mex, ovf = _forbidden_mex(allc, C, impl)
    return bitset.apply_recolor(U_rows & defect, mex, ovf, c_r)


# --------------------------------------------------------------------------
# ELL aggregation (GNN message passing over padded neighbor tiles)
# --------------------------------------------------------------------------

def ell_spmm_ref(ell, feats, op: str = "sum"):
    """out[v] = op over feats[nbr] for nbr in ell[v], FILL ignored.

    ell:   (R, W) int32
    feats: (n, d) float
    op in {sum, mean, max}
    """
    n, d = feats.shape
    valid = (ell >= 0)[..., None]
    gathered = jnp.where(valid, feats[jnp.clip(ell, 0, n - 1)], 0.0)
    if op == "sum":
        return gathered.sum(axis=1)
    if op == "mean":
        cnt = jnp.maximum(valid.sum(axis=1), 1)
        return gathered.sum(axis=1) / cnt
    if op == "max":
        neg = jnp.where(valid, feats[jnp.clip(ell, 0, n - 1)], -jnp.inf)
        out = neg.max(axis=1)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(op)


# --------------------------------------------------------------------------
# blockwise (flash) attention
# --------------------------------------------------------------------------

def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """Plain softmax attention oracle.

    q: (B, Hq, Lq, D); k, v: (B, Hkv, Lk, D); GQA: Hq % Hkv == 0.
    """
    B, Hq, Lq, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    kr = jnp.repeat(k, G, axis=1)
    vr = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kr) * scale
    if causal:
        Lk = k.shape[2]
        # query i attends to keys <= i + (Lk - Lq)  (decode-friendly offset)
        mask = (jnp.arange(Lk)[None, :] <= jnp.arange(Lq)[:, None] + (Lk - Lq))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr).astype(q.dtype)
