"""Jit'd dispatch wrappers for the Pallas kernels.

``backend='auto'`` uses the Pallas kernel on TPU and the jnp oracle path on
CPU (this container) — the dry-run therefore lowers the pure-jnp
memory-efficient paths, while kernels are validated in interpret mode by the
test suite.  ``backend='pallas_interpret'`` forces the kernel body through the
Pallas interpreter (CPU-executable, bit-faithful to kernel semantics).

The coloring dispatchers take ``impl`` ("bitset" | "dense"), forwarded to
the jnp refs; the Pallas kernels are the packed-bitset expression by
construction (DESIGN.md §10) and ignore it — every (backend, impl) corner
must agree bit-for-bit (tests/test_kernels.py).

**VMEM accounting** (DESIGN.md §8.3): every dispatcher shares one honest
estimator, ``vmem_bytes(kernel, ...)``, that counts what a kernel program
actually keeps resident — double-buffered (×2) for grid-varying blocks
(the Pallas pipeline prefetches the next block while the current one
computes), single-buffered for grid-invariant blocks like the color and
priority vectors, plus accumulators/scratch.  A kernel only falls back to
the jnp reference when that estimate busts ``VMEM_BUDGET_BYTES`` —
post-paging this is the *degenerate-shape* predicate (e.g. the un-pageable
(n,) vectors alone exceeding the budget), not a cliff at table size: the
two-hop kernel pages its hop-2 table through VMEM (kernels/twohop.py), so
arbitrarily large ELL tables stay on the Pallas path.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from repro.core import bitset
from repro.kernels import ref
from repro.kernels import twohop as _twohop_mod
from repro.kernels.firstfit import firstfit as _firstfit_pallas
from repro.kernels.detect_recolor import detect_recolor as _dr_pallas
from repro.kernels.twohop import twohop_detect_recolor as _twohop_pallas
from repro.kernels.ell_spmm import ell_spmm as _spmm_pallas
from repro.kernels.flash_attention import flash_attention as _fa_pallas
from repro.obs import metrics as obs_metrics
from repro.resilience import faults

# Per-invocation VMEM residency budget (conservative: real cores have
# ~16 MB; half is left to XLA temporaries and the pipeline itself).
VMEM_BUDGET_BYTES = 8 * 2**20


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def _resolve(backend: str) -> str:
    return default_backend() if backend == "auto" else backend


def _forced_fallback(kernel: str, b: str) -> str:
    """``kernel.fallback`` fault site (DESIGN.md §14.4): force the jnp
    reference path — bit-identical output by the parity contract, so chaos
    runs exercise the fallback plumbing without changing results.  With
    faults off this is one module-global None check."""
    if b != "jnp" and faults.fires("kernel.fallback", kernel=kernel):
        obs_metrics.counter("kernels.fallback", kernel=kernel,
                            reason="forced").inc()
        return "jnp"
    return b


def _dispatched(kernel: str, backend: str) -> None:
    """Count every dispatch decision: ``kernels.dispatch{kernel=,backend=}``
    tells a perf report which path actually ran (DESIGN.md §12)."""
    obs_metrics.counter("kernels.dispatch", kernel=kernel,
                        backend=backend).inc()


_fallback_warned: set = set()


def _vmem_fallback(kernel: str, detail: str) -> None:
    """A requested Pallas kernel fell back to the jnp reference because its
    working set would not stay VMEM-resident.  Used to be silent — now it
    warns once per process per kernel (naming the overflowing shape) and
    counts every occurrence in ``kernels.fallback{kernel=,reason=vmem}``."""
    obs_metrics.counter("kernels.fallback", kernel=kernel,
                        reason="vmem").inc()
    if kernel not in _fallback_warned:
        _fallback_warned.add(kernel)
        warnings.warn(
            f"{kernel}: Pallas kernel fell back to the jnp reference — "
            f"{detail}. Counted in obs.metrics "
            f"'kernels.fallback{{kernel={kernel},reason=vmem}}'; this "
            f"warning fires once per process per kernel.",
            RuntimeWarning, stacklevel=3)


# --------------------------------------------------------------------------
# honest per-kernel VMEM estimators (unit-pinned by tests/test_kernels.py)
# --------------------------------------------------------------------------

def firstfit_vmem_bytes(R: int, W: int, n: int, C: int,
                        block_rows: int = 256) -> int:
    """Resident bytes of one firstfit program: double-buffered (BV, W) ELL
    tile, the full (n,) color vector, the packed forbidden accumulator, and
    double-buffered (BV,) outputs (mex int32 + ovf bool)."""
    BV = min(block_rows, R)
    return (2 * BV * W * 4            # ELL tile (pipelined)
            + n * 4                   # colors (grid-invariant)
            + BV * bitset.n_words(C) * 4
            + 2 * BV * (4 + 1))       # outputs


def detect_recolor_vmem_bytes(R: int, W: int, n: int, C: int,
                              block_rows: int = 256) -> int:
    """firstfit's account plus the (n,) priority vector, the per-block
    U/rowc/rowp inputs, and the recolored/overflow outputs."""
    BV = min(block_rows, R)
    return (2 * BV * W * 4                  # ELL tile
            + 2 * n * 4                     # colors + priorities
            + 2 * BV * (1 + 4 + 4)          # U, rowc, rowp
            + BV * bitset.n_words(C) * 4    # forbidden words
            + BV * 4                        # defect flags
            + 2 * BV * (4 + 1 + 1))         # newc, rec, ovf


def twohop_vmem_bytes(R: int, W: int, n: int, C: int,
                      block_rows: int = 128,
                      page_rows: int | None = None,
                      n_all: int | None = None) -> int:
    """Resident bytes of one paged two-hop program: detect_recolor's account
    plus TWO (page_rows, W) hop-2 table pages (compute + DMA prefetch), the
    (BV, W) hop-2 gather panel, the rowid block, and the accumulator
    scratch.  This replaces the old predicate, which counted only the
    *whole-table* ``n_all*W*4`` bytes and ignored every vector — wrong in
    both directions once the table is paged."""
    BV = min(block_rows, R)
    if page_rows is None:
        page_rows = _twohop_mod.default_page_rows(n_all if n_all else n, W)
    return (2 * BV * W * 4                  # row tile
            + 2 * page_rows * W * 4         # hop-2 pages (double-buffered)
            + 2 * n * 4                     # colors + priorities
            + 2 * BV * (1 + 4 + 4 + 4)      # U, rowc, rowp, rowid
            + BV * W * 4                    # per-neighbor hop-2 gather panel
            + BV * bitset.n_words(C) * 4    # forbidden word scratch
            + BV * 4                        # defect scratch
            + 2 * BV * (4 + 1 + 1))         # newc, rec, ovf


def ell_aggregate_vmem_bytes(R: int, W: int, n: int, d: int,
                             itemsize: int = 4, block_rows: int = 128,
                             block_feats: int = 128) -> int:
    """Resident bytes of one ELL-aggregation program: the feature panel is
    (n, min(block_feats, d)) — the *real* width, not a hardcoded 128-wide
    panel — double-buffered only when the feature axis actually pages
    (d > block_feats)."""
    br = min(block_rows, R)
    bf = min(block_feats, d)
    panel_bufs = 2 if d > bf else 1
    return (2 * br * W * 4                  # ELL tile
            + panel_bufs * n * bf * itemsize
            + br * bf * itemsize            # accumulator
            + 2 * br * bf * itemsize)       # output tile


_VMEM_ESTIMATORS = {
    "firstfit": firstfit_vmem_bytes,
    "detect_recolor": detect_recolor_vmem_bytes,
    "twohop": twohop_vmem_bytes,
    "ell_aggregate": ell_aggregate_vmem_bytes,
}


def vmem_bytes(kernel: str, **shape) -> int:
    """Honest resident-bytes estimate for ``kernel`` — the single fallback
    predicate shared by every dispatcher (and the bench working-set
    accountant)."""
    try:
        est = _VMEM_ESTIMATORS[kernel]
    except KeyError:
        raise ValueError(f"unknown kernel {kernel!r}; "
                         f"known: {sorted(_VMEM_ESTIMATORS)}") from None
    return est(**shape)


def _mb(b: int) -> str:
    return f"{b / 2**20:.1f} MB"


# --------------------------------------------------------------------------
# dispatchers
# --------------------------------------------------------------------------

def firstfit(ell, colors, C: int = 64, backend: str = "auto",
             impl: str = "bitset", **kw):
    b = _resolve(backend)
    R, W = ell.shape
    n = colors.shape[0]
    if b != "jnp":
        need = firstfit_vmem_bytes(R, W, n, C,
                                   kw.get("block_rows", 256))
        if min(R, W) == 0 or need > VMEM_BUDGET_BYTES:
            _vmem_fallback(
                "firstfit",
                f"resident set for ELL {R}x{W}, n={n}, C={C} is "
                f"{_mb(need)} > {_mb(VMEM_BUDGET_BYTES)} budget "
                f"(the (n,) color vector is not pageable)")
            b = "jnp"
    b = _forced_fallback("firstfit", b)
    _dispatched("firstfit", b)
    if b == "jnp":
        return ref.firstfit_ref(ell, colors, C, impl=impl)
    interp = b == "pallas_interpret"
    mex, ovf = _firstfit_pallas(ell, colors, C=C, interpret=interp, **kw)
    return mex, ovf


def detect_recolor(ell, colors, pri, U_rows, row_start: int, C: int = 64,
                   backend: str = "auto", impl: str = "bitset", **kw):
    b = _resolve(backend)
    R, W = ell.shape
    n = colors.shape[0]
    if b != "jnp":
        need = detect_recolor_vmem_bytes(R, W, n, C,
                                         kw.get("block_rows", 256))
        if min(R, W) == 0 or need > VMEM_BUDGET_BYTES:
            _vmem_fallback(
                "detect_recolor",
                f"resident set for ELL {R}x{W}, n={n}, C={C} is "
                f"{_mb(need)} > {_mb(VMEM_BUDGET_BYTES)} budget "
                f"(the (n,) color/priority vectors are not pageable)")
            b = "jnp"
    b = _forced_fallback("detect_recolor", b)
    _dispatched("detect_recolor", b)
    if b == "jnp":
        return ref.detect_recolor_ref(ell, colors, pri, row_start, U_rows, C,
                                      impl=impl)
    interp = b == "pallas_interpret"
    return _dr_pallas(ell, colors, pri, U_rows, row_start=row_start, C=C,
                      interpret=interp, **kw)


def twohop(ell_rows, ell_all, colors, pri, U_rows, row_start: int,
           C: int = 64, backend: str = "auto", impl: str = "bitset",
           page_rows: int | None = None, **kw):
    """Fused two-hop (distance-2) detect-and-recolor for rows
    [row_start, row_start + R).  The hop-2 table is paged through VMEM
    (``page_rows`` rows per page, None -> ~2 MB pages), so table size no
    longer forces a fallback; only degenerate shapes — empty tiles, or the
    un-pageable (n,) color/priority vectors busting the budget — take the
    jnp reference path."""
    b = _resolve(backend)
    R, W = ell_rows.shape
    n = colors.shape[0]
    n_all = ell_all.shape[0]
    if b != "jnp":
        block_rows = kw.get("block_rows", 128)
        pr = (page_rows if page_rows is not None
              else _twohop_mod.default_page_rows(n_all, W))
        need = twohop_vmem_bytes(R, W, n, C, block_rows, pr, n_all=n_all)
        if min(R, W, n_all) == 0 or need > VMEM_BUDGET_BYTES:
            _vmem_fallback(
                "twohop",
                f"paged resident set for rows {R}x{W}, table {n_all}x{W}, "
                f"n={n}, C={C}, page_rows={pr} is {_mb(need)} > "
                f"{_mb(VMEM_BUDGET_BYTES)} budget — the (n,) color/priority "
                f"vectors are not pageable (degenerate shape)")
            b = "jnp"
    b = _forced_fallback("twohop", b)
    _dispatched("twohop", b)
    if b == "jnp":
        return ref.twohop_ref(ell_rows, ell_all, colors, pri, row_start,
                              U_rows, C, impl=impl)
    interp = b == "pallas_interpret"
    return _twohop_pallas(ell_rows, ell_all, colors, pri, U_rows,
                          row_start=row_start, C=C, page_rows=page_rows,
                          interpret=interp, **kw)


def ell_aggregate(ell, feats, op: str = "sum", backend: str = "auto", **kw):
    """GNN neighbor aggregation.  Falls back to jnp when the honest resident
    set (feature panel at its REAL width min(block_feats, d), not a
    hardcoded 128 lanes) busts the VMEM budget."""
    b = _resolve(backend)
    R, W = ell.shape
    n, d = feats.shape
    if b != "jnp":
        need = ell_aggregate_vmem_bytes(
            R, W, n, d, feats.dtype.itemsize,
            kw.get("block_rows", 128), kw.get("block_feats", 128))
        if min(R, W, d) == 0 or need > VMEM_BUDGET_BYTES:
            _vmem_fallback(
                "ell_aggregate",
                f"resident set for ELL {R}x{W}, feature panel {n}x"
                f"{min(kw.get('block_feats', 128), d)} ({feats.dtype}) is "
                f"{_mb(need)} > {_mb(VMEM_BUDGET_BYTES)} budget")
            b = "jnp"
    b = _forced_fallback("ell_aggregate", b)
    _dispatched("ell_aggregate", b)
    if b == "jnp":
        return ref.ell_spmm_ref(ell, feats, op)
    interp = b == "pallas_interpret"
    return _spmm_pallas(ell, feats, op=op, interpret=interp, **kw)


def attention(q, k, v, *, causal: bool = True, backend: str = "auto", **kw):
    b = _resolve(backend)
    _dispatched("attention", b)
    if b == "jnp":
        return ref.flash_attention_ref(q, k, v, causal=causal)
    interp = b == "pallas_interpret"
    return _fa_pallas(q, k, v, causal=causal, interpret=interp, **kw)
