"""Jit'd dispatch wrappers for the Pallas kernels.

``backend='auto'`` uses the Pallas kernel on TPU and the jnp oracle path on
CPU (this container) — the dry-run therefore lowers the pure-jnp
memory-efficient paths, while kernels are validated in interpret mode by the
test suite.  ``backend='pallas_interpret'`` forces the kernel body through the
Pallas interpreter (CPU-executable, bit-faithful to kernel semantics).

The coloring dispatchers take ``impl`` ("bitset" | "dense"), forwarded to
the jnp refs; the Pallas kernels are the packed-bitset expression by
construction (DESIGN.md §10) and ignore it — every (backend, impl) corner
must agree bit-for-bit (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.firstfit import firstfit as _firstfit_pallas
from repro.kernels.detect_recolor import detect_recolor as _dr_pallas
from repro.kernels.twohop import twohop_detect_recolor as _twohop_pallas
from repro.kernels.ell_spmm import ell_spmm as _spmm_pallas
from repro.kernels.flash_attention import flash_attention as _fa_pallas


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def _resolve(backend: str) -> str:
    return default_backend() if backend == "auto" else backend


def firstfit(ell, colors, C: int = 64, backend: str = "auto",
             impl: str = "bitset", **kw):
    b = _resolve(backend)
    if b == "jnp":
        return ref.firstfit_ref(ell, colors, C, impl=impl)
    interp = b == "pallas_interpret"
    mex, ovf = _firstfit_pallas(ell, colors, C=C, interpret=interp, **kw)
    return mex, ovf


def detect_recolor(ell, colors, pri, U_rows, row_start: int, C: int = 64,
                   backend: str = "auto", impl: str = "bitset", **kw):
    b = _resolve(backend)
    if b == "jnp":
        return ref.detect_recolor_ref(ell, colors, pri, row_start, U_rows, C,
                                      impl=impl)
    interp = b == "pallas_interpret"
    return _dr_pallas(ell, colors, pri, U_rows, row_start=row_start, C=C,
                      interpret=interp, **kw)


def twohop(ell_rows, ell_all, colors, pri, U_rows, row_start: int,
           C: int = 64, backend: str = "auto", impl: str = "bitset", **kw):
    """Fused two-hop (distance-2) detect-and-recolor for rows
    [row_start, row_start + R).  Falls back to jnp when the full ELL table
    would not fit VMEM (n_all * W * 4 > ~8MB)."""
    b = _resolve(backend)
    if b == "pallas" and ell_all.size * 4 > 8 * 2**20:
        b = "jnp"
    if b == "jnp":
        return ref.twohop_ref(ell_rows, ell_all, colors, pri, row_start,
                              U_rows, C, impl=impl)
    interp = b == "pallas_interpret"
    return _twohop_pallas(ell_rows, ell_all, colors, pri, U_rows,
                          row_start=row_start, C=C, interpret=interp, **kw)


def ell_aggregate(ell, feats, op: str = "sum", backend: str = "auto", **kw):
    """GNN neighbor aggregation. Falls back to jnp when the feature panel
    would not fit VMEM (n * block_feats * 4 > ~8MB)."""
    b = _resolve(backend)
    n = feats.shape[0]
    if b == "pallas" and n * 128 * feats.dtype.itemsize > 8 * 2**20:
        b = "jnp"
    if b == "jnp":
        return ref.ell_spmm_ref(ell, feats, op)
    interp = b == "pallas_interpret"
    return _spmm_pallas(ell, feats, op=op, interpret=interp, **kw)


def attention(q, k, v, *, causal: bool = True, backend: str = "auto", **kw):
    b = _resolve(backend)
    if b == "jnp":
        return ref.flash_attention_ref(q, k, v, causal=causal)
    interp = b == "pallas_interpret"
    return _fa_pallas(q, k, v, causal=causal, interpret=interp, **kw)
