"""Jit'd dispatch wrappers for the Pallas kernels.

``backend='auto'`` uses the Pallas kernel on TPU and the jnp oracle path on
CPU (this container) — the dry-run therefore lowers the pure-jnp
memory-efficient paths, while kernels are validated in interpret mode by the
test suite.  ``backend='pallas_interpret'`` forces the kernel body through the
Pallas interpreter (CPU-executable, bit-faithful to kernel semantics).

The coloring dispatchers take ``impl`` ("bitset" | "dense"), forwarded to
the jnp refs; the Pallas kernels are the packed-bitset expression by
construction (DESIGN.md §10) and ignore it — every (backend, impl) corner
must agree bit-for-bit (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.firstfit import firstfit as _firstfit_pallas
from repro.kernels.detect_recolor import detect_recolor as _dr_pallas
from repro.kernels.twohop import twohop_detect_recolor as _twohop_pallas
from repro.kernels.ell_spmm import ell_spmm as _spmm_pallas
from repro.kernels.flash_attention import flash_attention as _fa_pallas
from repro.obs import metrics as obs_metrics


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def _resolve(backend: str) -> str:
    return default_backend() if backend == "auto" else backend


def _dispatched(kernel: str, backend: str) -> None:
    """Count every dispatch decision: ``kernels.dispatch{kernel=,backend=}``
    tells a perf report which path actually ran (DESIGN.md §12)."""
    obs_metrics.counter("kernels.dispatch", kernel=kernel,
                        backend=backend).inc()


_fallback_warned: set = set()


def _vmem_fallback(kernel: str, detail: str) -> None:
    """A requested Pallas kernel fell back to the jnp reference because its
    working set would not stay VMEM-resident.  Used to be silent — now it
    warns once per process per kernel (naming the overflowing shape) and
    counts every occurrence in ``kernels.fallback{kernel=,reason=vmem}``."""
    obs_metrics.counter("kernels.fallback", kernel=kernel,
                        reason="vmem").inc()
    if kernel not in _fallback_warned:
        _fallback_warned.add(kernel)
        warnings.warn(
            f"{kernel}: Pallas kernel fell back to the jnp reference — "
            f"{detail}. Counted in obs.metrics "
            f"'kernels.fallback{{kernel={kernel},reason=vmem}}'; this "
            f"warning fires once per process per kernel.",
            RuntimeWarning, stacklevel=3)


def firstfit(ell, colors, C: int = 64, backend: str = "auto",
             impl: str = "bitset", **kw):
    b = _resolve(backend)
    _dispatched("firstfit", b)
    if b == "jnp":
        return ref.firstfit_ref(ell, colors, C, impl=impl)
    interp = b == "pallas_interpret"
    mex, ovf = _firstfit_pallas(ell, colors, C=C, interpret=interp, **kw)
    return mex, ovf


def detect_recolor(ell, colors, pri, U_rows, row_start: int, C: int = 64,
                   backend: str = "auto", impl: str = "bitset", **kw):
    b = _resolve(backend)
    _dispatched("detect_recolor", b)
    if b == "jnp":
        return ref.detect_recolor_ref(ell, colors, pri, row_start, U_rows, C,
                                      impl=impl)
    interp = b == "pallas_interpret"
    return _dr_pallas(ell, colors, pri, U_rows, row_start=row_start, C=C,
                      interpret=interp, **kw)


def twohop(ell_rows, ell_all, colors, pri, U_rows, row_start: int,
           C: int = 64, backend: str = "auto", impl: str = "bitset", **kw):
    """Fused two-hop (distance-2) detect-and-recolor for rows
    [row_start, row_start + R).  Falls back to jnp when the full ELL table
    would not fit VMEM (n_all * W * 4 > ~8MB)."""
    b = _resolve(backend)
    if b == "pallas" and ell_all.size * 4 > 8 * 2**20:
        _vmem_fallback(
            "twohop",
            f"full ELL table {ell_all.shape[0]}x{ell_all.shape[1]} int32 = "
            f"{ell_all.size * 4 / 2**20:.1f} MB exceeds the ~8 MB VMEM "
            f"residency bound")
        b = "jnp"
    _dispatched("twohop", b)
    if b == "jnp":
        return ref.twohop_ref(ell_rows, ell_all, colors, pri, row_start,
                              U_rows, C, impl=impl)
    interp = b == "pallas_interpret"
    return _twohop_pallas(ell_rows, ell_all, colors, pri, U_rows,
                          row_start=row_start, C=C, interpret=interp, **kw)


def ell_aggregate(ell, feats, op: str = "sum", backend: str = "auto", **kw):
    """GNN neighbor aggregation. Falls back to jnp when the feature panel
    would not fit VMEM (n * block_feats * 4 > ~8MB)."""
    b = _resolve(backend)
    n = feats.shape[0]
    if b == "pallas" and n * 128 * feats.dtype.itemsize > 8 * 2**20:
        _vmem_fallback(
            "ell_aggregate",
            f"feature panel {n}x128 ({feats.dtype}) = "
            f"{n * 128 * feats.dtype.itemsize / 2**20:.1f} MB exceeds the "
            f"~8 MB VMEM residency bound")
        b = "jnp"
    _dispatched("ell_aggregate", b)
    if b == "jnp":
        return ref.ell_spmm_ref(ell, feats, op)
    interp = b == "pallas_interpret"
    return _spmm_pallas(ell, feats, op=op, interpret=interp, **kw)


def attention(q, k, v, *, causal: bool = True, backend: str = "auto", **kw):
    b = _resolve(backend)
    _dispatched("attention", b)
    if b == "jnp":
        return ref.flash_attention_ref(q, k, v, causal=causal)
    interp = b == "pallas_interpret"
    return _fa_pallas(q, k, v, causal=causal, interpret=interp, **kw)
