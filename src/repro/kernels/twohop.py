"""Pallas TPU kernel: fused two-hop detect-and-recolor (native distance-2),
with the hop-2 ELL table **paged through VMEM**.

Two nested W-loops over the (BV, W) ELL tile feed ONE packed (BV, C//32)
forbidden bitset (DESIGN.md §10): hop 1 gathers each row's neighbor colors,
hop 2 re-gathers every neighbor's own ELL row — so G²'s adjacency is
consumed on the fly inside VMEM and never materialized (|E(G²)| ≈ n·deg²
would not fit anyway).

The old kernel required the *whole* (n_all, W) table VMEM-resident, so the
ops.py dispatcher fell back to the jnp reference above ~8 MB — exactly the
high-degree graphs the paper's speedup claims are about.  The table is now
split into ``page_rows``-row pages and the grid is

    (row blocks, table pages)        # pages minor: for each row block i,
                                     # pages p = 0 .. n_pages-1 in order

with per-page BlockSpec index maps: the Pallas pipeline double-buffers the
page input, DMA-ing page p+1 from HBM while the kernel gathers through page
p.  Neighbor j's hop-2 row lives in exactly one page (``lo <= ell[i,j] <
lo + page_rows``), so accumulating the masked per-page contributions visits
every two-hop edge exactly once.  The packed forbidden words and the defect
flags live in VMEM scratch across the page sweep and the branch-free mex
epilogue (``bitset.recolor_epilogue``) runs on the final page — the
forbidden words never round-trip through HBM.

Resident per program: one (BV, W) row tile, two (page_rows, W) page
buffers, the (n,) color/priority vectors, and the (BV, C//32) accumulator —
``ops.twohop_vmem_bytes`` is the honest account, and the only remaining
jnp fallback is for degenerate shapes (the un-pageable (n,) vectors
themselves busting the budget, or empty tiles).

Hop-1 contributions (neighbor colors + the hop-1 defect test) are masked to
the first page visit so they are counted once per row block, not once per
page.  A vertex is always its own two-hop neighbor (v -> w -> v through any
neighbor w); those slots are masked so a row never forbids its own color.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bitset

# Target bytes of one hop-2 table page (two pages are resident: compute +
# prefetch).  2 MB keeps pages + vectors + accumulators comfortably inside
# the ~8 MB per-invocation envelope ops.py budgets (DESIGN.md §8.3).
PAGE_TARGET_BYTES = 2 * 2**20


def default_page_rows(n_all: int, W: int,
                      page_bytes: int = PAGE_TARGET_BYTES) -> int:
    """Rows per hop-2 table page: ~page_bytes worth of (W,) int32 rows,
    multiple-of-128 aligned (TPU sublane friendliness), never exceeding the
    table itself."""
    rows = max(page_bytes // max(W * 4, 1), 128)
    rows = max(rows // 128, 1) * 128
    return min(rows, max(n_all, 1))


def _twohop_kernel(ell_ref, page_ref, colors_ref, pri_ref, U_ref,
                   rowc_ref, rowp_ref, rowid_ref,
                   newc_ref, rec_ref, ovf_ref,
                   forb_ref, defect_ref,
                   *, C: int, n: int, page_rows: int):
    p = pl.program_id(1)                      # table page index (minor axis)
    ell = ell_ref[...]                        # (BV, W) rows being recolored
    page = page_ref[...]                      # (page_rows, W) hop-2 page
    colors = colors_ref[...]                  # (n,)
    pri = pri_ref[...]                        # (n,)
    U = U_ref[...]                            # (BV,)
    c_r = rowc_ref[...]                       # (BV,) this block's colors
    p_r = rowp_ref[...]                       # (BV,)
    vid = rowid_ref[...]                      # (BV,) global ids (self-mask)
    BV, W = ell.shape

    first = p == 0
    lo = p * page_rows
    # scratch persists across the page sweep of one row block; page 0
    # re-initializes (scratch contents from the previous row block are
    # discarded by the where, never read into the accumulation).
    forb0 = jnp.where(first, bitset.init_words(BV, C), forb_ref[...])
    defect0 = jnp.where(first, False, defect_ref[...] != 0)

    def hop1(j, carry):
        forb, defect = carry
        idx = ell[:, j]
        live = idx >= 0
        # hop-1 colors count once per row block: first page visit only
        safe = jnp.clip(idx, 0, n - 1)
        nc = jnp.where(live & first, colors[safe], -1)
        npr = jnp.where(live & first, pri[safe], -1)
        defect = defect | ((nc == c_r) & (c_r >= 0) & (npr > p_r))
        forb = bitset.or_color(forb, nc, C)
        # hop 2: gather neighbor j's own ELL row iff it lives in this page
        in_page = (idx >= lo) & (idx < lo + page_rows)
        row2 = page[jnp.clip(idx - lo, 0, page_rows - 1)]   # (BV, W)

        def hop2(jj, carry2):
            forb2, defect2 = carry2
            idx2 = row2[:, jj]
            live2 = in_page & (idx2 >= 0) & (idx2 != vid)
            safe2 = jnp.clip(idx2, 0, n - 1)
            nc2 = jnp.where(live2, colors[safe2], -1)
            np2 = jnp.where(live2, pri[safe2], -1)
            defect2 = defect2 | ((nc2 == c_r) & (c_r >= 0) & (np2 > p_r))
            return bitset.or_color(forb2, nc2, C), defect2

        return jax.lax.fori_loop(0, W, hop2, (forb, defect))

    forb, defect = jax.lax.fori_loop(0, W, hop1, (forb0, defect0))
    forb_ref[...] = forb
    defect_ref[...] = defect.astype(jnp.int32)
    # fused epilogue on the accumulated words — only the final page's write
    # survives in the (row-block-indexed) output buffers, flushed to HBM
    # when the row block advances.  The (BV, C//32) words never leave VMEM.
    newc, rec, ovf = bitset.recolor_epilogue(forb, defect, U, c_r, C)
    newc_ref[...] = newc
    rec_ref[...] = rec
    ovf_ref[...] = ovf


@functools.partial(jax.jit,
                   static_argnames=("C", "row_start", "block_rows",
                                    "page_rows", "interpret"))
def twohop_detect_recolor(ell_rows, ell_all, colors, pri, U_rows,
                          row_start: int, C: int = 64, block_rows: int = 128,
                          page_rows: int | None = None,
                          interpret: bool = True):
    """Fused two-hop pass for rows [row_start, row_start + R).

    ell_rows:  (R, W) neighbor tile for those rows
    ell_all:   (n_all, W) full neighbor table (hop-2 gathers), n_all >= n
    colors:    (n,) global colors;  pri: (n,) priorities
    U_rows:    (R,) bool, in-frontier mask for those rows
    page_rows: rows per VMEM page of ell_all (None -> ~2 MB pages); the
               table is FILL-padded to a whole number of pages.
    Returns (new row colors (R,), recolored (R,), overflow (R,)).
    """
    R, W = ell_rows.shape
    n = colors.shape[0]
    n_all = ell_all.shape[0]
    assert R % block_rows == 0, (R, block_rows)
    if page_rows is None:
        page_rows = default_page_rows(n_all, W)
    n_pages = -(-n_all // page_rows)
    pad = n_pages * page_rows - n_all
    if pad:
        # FILL-padded rows are unreachable (vertex ids < n_all) — padding
        # only squares the table up to whole pages for the BlockSpec.
        ell_all = jnp.pad(ell_all, ((0, pad), (0, 0)), constant_values=-1)
    rowc = jax.lax.dynamic_slice_in_dim(colors, row_start, R, 0)
    rowp = jax.lax.dynamic_slice_in_dim(pri, row_start, R, 0)
    rowid = row_start + jnp.arange(R, dtype=jnp.int32)
    grid = (R // block_rows, n_pages)
    kernel = functools.partial(_twohop_kernel, C=C, n=n, page_rows=page_rows)
    blk = lambda: pl.BlockSpec((block_rows,), lambda i, p: (i,))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, W), lambda i, p: (i, 0)),  # row tile
            pl.BlockSpec((page_rows, W), lambda i, p: (p, 0)),   # table page
            pl.BlockSpec((n,), lambda i, p: (0,)),               # colors
            pl.BlockSpec((n,), lambda i, p: (0,)),               # priorities
            blk(), blk(), blk(), blk(),
        ],
        out_specs=[blk(), blk(), blk()],
        out_shape=[
            jax.ShapeDtypeStruct((R,), jnp.int32),
            jax.ShapeDtypeStruct((R,), jnp.bool_),
            jax.ShapeDtypeStruct((R,), jnp.bool_),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_rows, bitset.n_words(C)), jnp.int32),
            pltpu.VMEM((block_rows,), jnp.int32),
        ],
        interpret=interpret,
    )(ell_rows, ell_all, colors, pri, U_rows, rowc, rowp, rowid)
