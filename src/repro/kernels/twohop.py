"""Pallas TPU kernel: fused two-hop detect-and-recolor (native distance-2).

Two nested W-loops over the (BV, W) ELL tile feed ONE packed (BV, C//32)
forbidden bitset (DESIGN.md §10): hop 1 gathers each row's neighbor colors,
hop 2 re-gathers every neighbor's own ELL row from the full table — so G²'s
adjacency is consumed on the fly inside VMEM and never materialized
(|E(G²)| ≈ n·deg² would not fit anyway).  Distance-2 is where the packed
accumulator buys the most: C is largest here, and the 8× table shrink is
VMEM the W² hop-2 gather panel gets back.  The same gathered colors feed
both the distance-2 defect test (same color as a higher-priority vertex
within two hops) and the first-fit recolor: the distance-2 expression of
merging Alg. 2's phases into Alg. 3's single fused phase.

A vertex is always its own two-hop neighbor (v -> w -> v through any
neighbor w); those slots are masked so a row never forbids its own color.

The full ELL table and the color/priority vectors are VMEM-resident per
invocation (same residency envelope as firstfit.py: graphs to ~1M rows at
mesh widths; beyond that the ops.py wrapper falls back to the jnp path).

Grid: one program per BV-row block of the chunk being recolored.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import bitset


def _twohop_kernel(ell_ref, ell_all_ref, colors_ref, pri_ref, U_ref,
                   rowc_ref, rowp_ref, rowid_ref,
                   newc_ref, rec_ref, ovf_ref, *, C: int, n: int):
    ell = ell_ref[...]                        # (BV, W) rows being recolored
    ell_all = ell_all_ref[...]                # (n_all, W) hop-2 source table
    colors = colors_ref[...]                  # (n,)
    pri = pri_ref[...]                        # (n,)
    U = U_ref[...]                            # (BV,)
    c_r = rowc_ref[...]                       # (BV,) this block's colors
    p_r = rowp_ref[...]                       # (BV,)
    vid = rowid_ref[...]                      # (BV,) global ids (self-mask)
    BV, W = ell.shape

    def hop1(j, carry):
        forb, defect = carry
        idx = ell[:, j]
        live = idx >= 0
        safe = jnp.clip(idx, 0, n - 1)
        nc = jnp.where(live, colors[safe], -1)
        npr = jnp.where(live, pri[safe], -1)
        defect = defect | ((nc == c_r) & (c_r >= 0) & (npr > p_r))
        forb = bitset.or_color(forb, nc, C)
        row2 = ell_all[safe]                  # (BV, W) two-hop ids via nbr j

        def hop2(jj, carry2):
            forb2, defect2 = carry2
            idx2 = row2[:, jj]
            live2 = live & (idx2 >= 0) & (idx2 != vid)
            safe2 = jnp.clip(idx2, 0, n - 1)
            nc2 = jnp.where(live2, colors[safe2], -1)
            np2 = jnp.where(live2, pri[safe2], -1)
            defect2 = defect2 | ((nc2 == c_r) & (c_r >= 0) & (np2 > p_r))
            return bitset.or_color(forb2, nc2, C), defect2

        return jax.lax.fori_loop(0, W, hop2, (forb, defect))

    forb, defect = jax.lax.fori_loop(
        0, W, hop1,
        (bitset.init_words(BV, C), jnp.zeros((BV,), jnp.bool_)))
    work = U & defect
    mex, ovf = bitset.mex_words(forb, C)
    newc_ref[...] = jnp.where(work, mex, c_r)
    rec_ref[...] = work
    ovf_ref[...] = ovf & work


@functools.partial(jax.jit,
                   static_argnames=("C", "row_start", "block_rows",
                                    "interpret"))
def twohop_detect_recolor(ell_rows, ell_all, colors, pri, U_rows,
                          row_start: int, C: int = 64, block_rows: int = 128,
                          interpret: bool = True):
    """Fused two-hop pass for rows [row_start, row_start + R).

    ell_rows: (R, W) neighbor tile for those rows
    ell_all:  (n_all, W) full neighbor table (hop-2 gathers), n_all >= n
    colors:   (n,) global colors;  pri: (n,) priorities
    U_rows:   (R,) bool, in-frontier mask for those rows
    Returns (new row colors (R,), recolored (R,), overflow (R,)).
    """
    R, W = ell_rows.shape
    n = colors.shape[0]
    n_all = ell_all.shape[0]
    assert R % block_rows == 0, (R, block_rows)
    rowc = jax.lax.dynamic_slice_in_dim(colors, row_start, R, 0)
    rowp = jax.lax.dynamic_slice_in_dim(pri, row_start, R, 0)
    rowid = row_start + jnp.arange(R, dtype=jnp.int32)
    grid = (R // block_rows,)
    kernel = functools.partial(_twohop_kernel, C=C, n=n)
    blk = lambda: pl.BlockSpec((block_rows,), lambda i: (i,))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, W), lambda i: (i, 0)),   # row tile
            pl.BlockSpec((n_all, W), lambda i: (0, 0)),        # full ELL
            pl.BlockSpec((n,), lambda i: (0,)),                # colors
            pl.BlockSpec((n,), lambda i: (0,)),                # priorities
            blk(), blk(), blk(), blk(),
        ],
        out_specs=[blk(), blk(), blk()],
        out_shape=[
            jax.ShapeDtypeStruct((R,), jnp.int32),
            jax.ShapeDtypeStruct((R,), jnp.bool_),
            jax.ShapeDtypeStruct((R,), jnp.bool_),
        ],
        interpret=interpret,
    )(ell_rows, ell_all, colors, pri, U_rows, rowc, rowp, rowid)
