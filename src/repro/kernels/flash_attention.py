"""Pallas TPU kernel: blockwise-softmax (flash) attention, forward.

VMEM tiling: (BQ, D) query block resident; KV streamed in (BK, D) blocks with
running max / running sum (log-sum-exp) accumulation — the standard
IO-aware schedule, MXU-aligned (BQ, BK multiples of 128; D = head_dim).
Supports GQA via a query-head -> kv-head grid mapping and causal masking with
a decode offset (Lk >= Lq).

Training uses the chunked pure-jnp path (models/layers.py) with native
autodiff + remat; this kernel is the serving-path hot spot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, Lq, Lk,
                  block_k):
    q = q_ref[0, 0]                     # (BQ, D)
    BQ, D = q.shape
    nk = Lk // block_k
    qi = pl.program_id(2)               # query-block index
    q_off = qi * BQ + (Lk - Lq)         # causal diag offset (decode-friendly)

    def body(j, carry):
        acc, m, l = carry
        k = k_ref[0, 0, pl.ds(j * block_k, block_k), :]  # (BK, D)
        v = v_ref[0, 0, pl.ds(j * block_k, block_k), :]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            rows = jax.lax.broadcasted_iota(jnp.int32, (BQ, block_k), 0)
            cols = jax.lax.broadcasted_iota(jnp.int32, (BQ, block_k), 1)
            mask = (j * block_k + cols) <= (q_off + rows)
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((BQ, D), jnp.float32)
    m0 = jnp.full((BQ,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((BQ,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nk, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (B, Hq, Lq, D); k, v: (B, Hkv, Lk, D); GQA when Hq > Hkv."""
    B, Hq, Lq, D = q.shape
    _, Hkv, Lk, _ = k.shape
    G = Hq // Hkv
    bq = min(block_q, Lq)
    bk = min(block_k, Lk)
    assert Lq % bq == 0 and Lk % bk == 0
    scale = 1.0 / (D ** 0.5)
    grid = (B, Hq, Lq // bq)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               Lq=Lq, Lk=Lk, block_k=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Lk, D), lambda b, h, i: (b, h // G, 0, 0)),
            pl.BlockSpec((1, 1, Lk, D), lambda b, h, i: (b, h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Lq, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
