"""Quarantine records and the dead-letter queue (DESIGN.md §14.3).

When a tenant's steps fail ``quarantine_after`` times in a row, the service
freezes it: the drained-but-unapplied batches of the final attempt are
preserved verbatim in a ``DeadLetterQueue`` (the forensic record AND the
replay source for ``heal``), and a ``QuarantineEntry`` carries the
structured reason every subsequent no-op step reports.

This module is import-light on purpose (numpy + stdlib only): the service,
the ladder, and core engine modules can all reach it without cycles.
"""
from __future__ import annotations

import collections
import dataclasses
import json
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class QuarantineEntry:
    """Why a tenant is frozen (returned by ``service.quarantined``)."""

    reason: str          # classified failure reason (rollback counter label)
    error: str           # repr of the final exception
    since_version: int   # last-good committed version (still being served)
    failures: int        # consecutive failed steps that tripped the freeze


@dataclasses.dataclass(frozen=True)
class DeadLetter:
    """One failed drain: the batches that could not be applied.

    ``batches`` is a tuple of ``(inserts, deletes)`` numpy pairs in original
    vertex ids, in submit order — exactly what ``heal(mode='replay')``
    re-applies."""

    tenant: str
    batches: tuple       # ((ins, dels), ...) numpy (k, 2) int64 pairs
    reason: str
    error: str
    version: int         # tenant version the drain failed against
    seq: int             # service-wide step sequence number

    def n_edges(self) -> int:
        return sum(len(i) + len(d) for i, d in self.batches)


class DeadLetterQueue:
    """Bounded FIFO of ``DeadLetter``s (oldest dropped past ``cap``)."""

    def __init__(self, cap: int = 64):
        self._q: "collections.deque[DeadLetter]" = collections.deque(
            maxlen=max(1, int(cap)))

    def __len__(self) -> int:
        return len(self._q)

    def push(self, letter: DeadLetter) -> None:
        self._q.append(letter)

    def letters(self, tenant: Optional[str] = None) -> list[DeadLetter]:
        return [dl for dl in self._q
                if tenant is None or dl.tenant == tenant]

    def drain(self, tenant: str) -> list[DeadLetter]:
        """Remove and return ``tenant``'s letters (oldest first) — used by
        a successful replay heal, which has applied them."""
        mine = self.letters(tenant)
        for dl in mine:
            self._q.remove(dl)
        return mine

    def export_jsonl(self, path) -> int:
        """Write one JSON object per letter (CI chaos artifacts); returns
        the number written."""
        n = 0
        with open(path, "w") as f:
            for dl in self._q:
                f.write(json.dumps({
                    "tenant": dl.tenant, "reason": dl.reason,
                    "error": dl.error, "version": dl.version,
                    "seq": dl.seq, "n_batches": len(dl.batches),
                    "batches": [
                        {"inserts": np.asarray(i).tolist(),
                         "deletes": np.asarray(d).tolist()}
                        for i, d in dl.batches],
                }) + "\n")
                n += 1
        return n
