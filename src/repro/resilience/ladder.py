"""The degradation ladder (DESIGN.md §14.2).

When a tenant's incremental repair exhausts its budgets — ``max_cap_retries``
color-cap doublings or ``max_ovf_growth`` overflow-buffer growths — the
service does not spin and does not drop the batch; it *degrades
deterministically* through three rungs, each strictly more conservative and
strictly harder to exhaust:

    rung 0  incremental repair       (``recolor_incremental``: work ∝ delta)
    rung 1  from-scratch re-encode   (``api.color`` on the updated graph —
                                      fresh caps, fresh overflow sizing)
    rung 2  serial oracle            (host ``greedy_sequential`` + encode:
                                      no device coloring loop at all, so no
                                      budget left to exhaust)

Every rung produces a state that is *consistent* — proper colors over the
fully-applied updated graph, version bumped exactly once per batch — so a
degraded tenant never serves a half-applied triple.  The rung taken is
recorded on ``DynamicColoringState.last_degrade_rung`` (surfaced through
``summary()``/``StepStats``) and counted in ``resilience.degrade{rung=..}``.

Heavy imports (api, dynamic, core) are deferred into function bodies:
``core/coloring`` and ``dynamic/delta`` import ``repro.resilience`` at
module scope, so this module must not import them back at its own.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.resilience.errors import CapRetryExhausted, OvfGrowthExhausted

RUNG_NAMES = ("incremental", "scratch", "oracle")


def updated_graph(state, inserts, deletes):
    """Host-side edge-set algebra: the tenant's current graph with the
    batch applied (original vertex ids, deletes before inserts, self-loop
    inserts dropped like the device wave planner does)."""
    from repro.dynamic import delta
    from repro.graphs.csr import from_edges, to_edge_list

    g = delta.state_to_csr(state)
    e = to_edge_list(g).astype(np.int64)
    live = {(int(min(u, v)), int(max(u, v))) for u, v in e}
    for u, v in np.asarray(deletes).reshape(-1, 2):
        live.discard((int(min(u, v)), int(max(u, v))))
    for u, v in np.asarray(inserts).reshape(-1, 2):
        if u != v:
            live.add((int(min(u, v)), int(max(u, v))))
    edges = (np.array(sorted(live), np.int64).reshape(-1, 2)
             if live else np.zeros((0, 2), np.int64))
    return from_edges(state.n, edges, symmetrize=True)


def scratch_state(state, inserts=None, deletes=None):
    """Rung 1: re-encode + recolor the updated graph through the
    ``api.color`` front door, inheriting the tenant's statics and budgets.

    A fresh encode re-picks the color cap and re-sizes the overflow buffer,
    so budget exhaustion that was really cap starvation is cured here; a
    genuinely unsatisfiable budget (or a still-armed fault) raises again
    and the caller falls to rung 2."""
    from repro import api
    from repro.dynamic import sharded

    if isinstance(state, sharded.ShardedColoringState):
        return sharded.scratch_sharded(state, inserts, deletes)
    empty = np.zeros((0, 2), np.int64)
    g2 = updated_graph(state, empty if inserts is None else inserts,
                       empty if deletes is None else deletes)
    res = api.color(
        g2, mode="incremental", seed=0, n_chunks=state.n_chunks,
        ell_cap=int(state.ell.shape[1]), ell_slack=0, C=None,
        ovf_cap=int(state.ovf_src.shape[0]), delta_cap=state.delta_cap,
        max_rounds=state.max_rounds, forbidden_impl=state.forbidden_impl,
        max_cap_retries=state.max_cap_retries,
        max_ovf_growth=state.max_ovf_growth)
    st = res.state
    # the incremental engine itself falls back to the oracle encode when the
    # from-scratch solve exhausts its cap budget — keep that attribution (a
    # "scratch" label on an oracle coloring would lie to the operator)
    rung = 2 if st.last_degrade_rung == 2 else 1
    return dataclasses.replace(
        st, version=state.version + 1, last_degrade_rung=rung,
        retries=state.retries + st.retries, ovf_grows=state.ovf_grows,
        total_gather_passes=(state.total_gather_passes
                             + st.total_gather_passes))


def oracle_state(state, inserts=None, deletes=None):
    """Rung 2: serial First-Fit on the host, then a pure encode — no device
    coloring loop runs, so nothing is left to exhaust or inject into."""
    from repro.dynamic import sharded

    if isinstance(state, sharded.ShardedColoringState):
        return sharded.oracle_sharded(state, inserts, deletes)
    empty = np.zeros((0, 2), np.int64)
    g2 = updated_graph(state, empty if inserts is None else inserts,
                       empty if deletes is None else deletes)
    st = encode_oracle_state(
        g2, seed=0, n_chunks=state.n_chunks,
        ell_cap=int(state.ell.shape[1]), ell_slack=0,
        ovf_cap=int(state.ovf_src.shape[0]), delta_cap=state.delta_cap,
        max_rounds=state.max_rounds, forbidden_impl=state.forbidden_impl,
        max_cap_retries=state.max_cap_retries,
        max_ovf_growth=state.max_ovf_growth)
    return dataclasses.replace(
        st, version=state.version + 1, retries=state.retries,
        ovf_grows=state.ovf_grows,
        total_gather_passes=state.total_gather_passes)


def encode_oracle_state(g, *, seed=0, n_chunks=16, ell_cap=512, ell_slack=4,
                        ovf_cap=None, delta_cap=2048, frontier_frac=0.125,
                        max_rounds=1000, forbidden_impl=None,
                        max_cap_retries=None, max_ovf_growth=None):
    """Serial-oracle colors + the standard mutable encode of ``g``: the
    ``dynamic_state`` layout with ``greedy_sequential`` colors in place of
    the device coloring loop (also the ``mode='incremental'`` engine's
    fallback when the *initial* from-scratch coloring exhausts its budget).
    """
    import jax.numpy as jnp

    from repro.core import coloring as col
    from repro.core import frontier
    from repro.dynamic.incremental import DynamicColoringState
    from repro.graphs.csr import FILL

    impl = col._resolve_impl(forbidden_impl)
    colors = col.greedy_sequential(g)
    prob = col.prepare(g, seed, n_chunks, ell_cap, C=None)
    ell_np = np.asarray(prob.ell)
    if ell_slack > 0:
        pad = np.full((ell_np.shape[0], ell_slack), FILL, np.int32)
        ell_np = np.concatenate([ell_np, pad], axis=1)
    n_ovf = int(prob.ovf_src.shape[0])
    cap = int(ovf_cap) if ovf_cap is not None else max(64, 2 * n_ovf,
                                                       delta_cap // 2)
    cap = max(cap, n_ovf, 8)
    osrc = np.full((cap,), FILL, np.int32)
    odst = np.full((cap,), FILL, np.int32)
    osrc[:n_ovf] = np.asarray(prob.ovf_src)
    odst[:n_ovf] = np.asarray(prob.ovf_dst)
    colors_pad = np.full((prob.n_pad,), -1, np.int32)
    colors_pad[prob.perm] = colors
    n_used = int(colors.max()) + 1 if len(colors) else 1
    C = max(32, -(-n_used // 32) * 32)   # headroom for future repairs
    return DynamicColoringState(
        ell=jnp.asarray(ell_np), ovf_src=jnp.asarray(osrc),
        ovf_dst=jnp.asarray(odst), pri=prob.pri,
        colors_dev=jnp.asarray(colors_pad),
        n=prob.n, n_pad=prob.n_pad, C=C, n_chunks=n_chunks,
        frontier_cap=frontier.frontier_cap(prob.n_pad, n_chunks,
                                           frontier_frac),
        delta_cap=int(delta_cap), perm=prob.perm,
        inv_perm=np.argsort(prob.perm), forbidden_impl=impl,
        max_rounds=int(max_rounds), max_cap_retries=max_cap_retries,
        max_ovf_growth=max_ovf_growth, version=0, last_degrade_rung=2)


def apply_with_ladder(state, inserts, deletes):
    """Apply one batch, degrading on budget exhaustion.

    Returns ``(new_state, rung)`` with ``rung`` the index into
    ``RUNG_NAMES`` that produced the state.  Only budget-exhaustion errors
    degrade; anything else (injected step faults, real bugs) propagates so
    the service's transactional rollback handles it."""
    from repro.dynamic.incremental import recolor_incremental
    from repro.dynamic.sharded import ShardedColoringState, recolor_sharded

    recolor = (recolor_sharded if isinstance(state, ShardedColoringState)
               else recolor_incremental)
    try:
        return recolor(state, inserts, deletes), 0
    except (CapRetryExhausted, OvfGrowthExhausted):
        pass
    obs_metrics.counter("resilience.degrade", rung="scratch").inc()
    try:
        st = scratch_state(state, inserts, deletes)
    except (CapRetryExhausted, OvfGrowthExhausted):
        pass
    else:
        if st.last_degrade_rung == 2:   # engine already dropped to oracle
            obs_metrics.counter("resilience.degrade", rung="oracle").inc()
        return st, st.last_degrade_rung
    obs_metrics.counter("resilience.degrade", rung="oracle").inc()
    return oracle_state(state, inserts, deletes), 2
