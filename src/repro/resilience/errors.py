"""Typed failure vocabulary of the resilience layer (DESIGN.md §14).

Every failure the serving stack can *recover from* is a subclass of
``ResilienceError``: budget exhaustion (``CapRetryExhausted``,
``OvfGrowthExhausted``) triggers the degradation ladder, verification
failures (``ImproperColoring``) and injected faults (``InjectedFault``)
trigger a transactional rollback, and repeated rollbacks land a tenant in
quarantine (``QuarantinedError`` on subsequent submits).  Anything NOT in
this hierarchy is an ordinary bug — the service still rolls the tenant back
bit-exactly, but nothing attempts to degrade around it.
"""
from __future__ import annotations


class ResilienceError(RuntimeError):
    """Base class of every recoverable serving-stack failure."""


class CapRetryExhausted(ResilienceError):
    """``_run_with_retry`` hit its ``max_cap_retries`` budget (or a forced
    ``cap.exhaust`` fault) with the color cap still overflowing."""

    def __init__(self, engine: str = "", C: int = 0, retries: int = 0,
                 budget=None, forced: bool = False):
        self.engine, self.C, self.retries = engine, int(C), int(retries)
        self.budget, self.forced = budget, bool(forced)
        why = "forced by fault injection" if forced else \
            f"budget max_cap_retries={budget} exhausted"
        super().__init__(
            f"color-cap retry exhausted ({why}) in engine "
            f"{engine or 'unknown'!r} at C={C} after {retries} retries")


class OvfGrowthExhausted(ResilienceError):
    """``delta.apply_updates`` hit its ``max_ovf_growth`` budget (or a
    forced ``ovf.exhaust`` fault) with an insert wave still spilling."""

    def __init__(self, grows: int = 0, budget=None, cap: int = 0,
                 forced: bool = False):
        self.grows, self.budget = int(grows), budget
        self.cap, self.forced = int(cap), bool(forced)
        why = "forced by fault injection" if forced else \
            f"budget max_ovf_growth={budget} exhausted"
        super().__init__(
            f"overflow-buffer growth exhausted ({why}) after {grows} "
            f"doublings (cap {cap})")


class ImproperColoring(ResilienceError):
    """Post-step verification found a conflicting edge — the step's output
    is discarded and the tenant rolled back to its pre-step state."""

    def __init__(self, name: str = "", version: int = 0):
        self.name, self.version = name, int(version)
        super().__init__(
            f"step output for {name!r} (version {version}) is not a proper "
            f"coloring; rolled back")


class QuarantinedError(ResilienceError):
    """The tenant is frozen after repeated step failures; ``heal(name)``
    re-admits it."""

    def __init__(self, name: str, reason: str = "", since_version: int = 0):
        self.name, self.reason = name, reason
        self.since_version = int(since_version)
        super().__init__(
            f"graph {name!r} is quarantined (reason={reason!r}, since "
            f"version {since_version}); heal({name!r}) to re-admit")


class HealFailed(ResilienceError):
    """``heal`` could not produce an oracle-verified proper state; the
    tenant stays quarantined."""

    def __init__(self, name: str, detail: str = ""):
        self.name = name
        super().__init__(f"heal({name!r}) failed: {detail}")


class InjectedFault(ResilienceError):
    """Raised by an armed ``resilience.faults`` site (never with faults
    off)."""

    def __init__(self, site: str, meta: dict | None = None):
        self.site = site
        self.meta = dict(meta or {})
        extra = f" {self.meta}" if self.meta else ""
        super().__init__(f"injected fault at {site!r}{extra}")
