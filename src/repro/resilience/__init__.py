"""Self-healing serving layer: transactional steps, bounded retries with a
degradation ladder, quarantine + dead-letter, and deterministic fault
injection (DESIGN.md §14).

Import-light on purpose: ``core/coloring`` and ``dynamic/delta`` pull the
error types and fault registry from here at module scope, so this package
must not import them back.  The heavier submodules (``ladder``,
``quarantine``) are imported explicitly by their consumers
(``dynamic/service``) and lazy-load engine code inside function bodies.
"""
from repro.resilience import faults  # noqa: F401
from repro.resilience.errors import (  # noqa: F401
    CapRetryExhausted, HealFailed, ImproperColoring, InjectedFault,
    OvfGrowthExhausted, QuarantinedError, ResilienceError)

__all__ = [
    "CapRetryExhausted",
    "HealFailed",
    "ImproperColoring",
    "InjectedFault",
    "OvfGrowthExhausted",
    "QuarantinedError",
    "ResilienceError",
    "faults",
]
