"""Deterministic fault injection for the serving stack (DESIGN.md §14.4).

A *fault point* is a named host-side site in the production code path —
``faults.fires("cap.exhaust", ...)`` — that is a single ``is None`` check
when injection is off and a seeded, reproducible coin flip when on.  The
discipline mirrors ``repro.obs``: **off must be free and bit-exact** — no
fault point sits inside a jitted program, so compiled programs are
byte-identical with ``REPRO_FAULTS`` unset, and the only host cost is the
module-level None check.

Sites (each raises or perturbs at a different detection layer):

    ``kernel.fallback``   kernels/ops dispatchers force the jnp reference
                          path (bit-identical by the parity contract)
    ``cap.exhaust``       core/coloring._run_with_retry raises
                          CapRetryExhausted (degradation-ladder trigger)
    ``ovf.exhaust``       dynamic/delta.apply_updates raises
                          OvfGrowthExhausted (degradation-ladder trigger)
    ``color.corrupt``     service commit path corrupts a stepped coloring
                          (caught by post-step verification -> rollback)
    ``service.step``      exception at the top of a per-tenant/mega step
                          (transactional rollback + retry/quarantine)
    ``service.submit``    exception in submit before enqueue (caller-visible)

Activation, most specific wins::

    REPRO_FAULTS="cap.exhaust"                        # every call fires
    REPRO_FAULTS="service.step:p=0.5:seed=7;ovf.exhaust:times=1"
    with faults.inject("color.corrupt:times=2:seed=3"):
        ...

Spec grammar: ``;``-separated sites, each ``name[:k=v]*`` with params
``p`` (fire probability, default 1), ``seed`` (per-site RNG seed, default
0), ``after`` (skip the first N eligible calls), ``times`` (fire at most K
times, default unlimited), ``k`` (payload count, e.g. corrupted vertices).
Firing is a pure function of (spec, call order): replaying the same
workload under the same spec fires at the same calls — chaos tests rely on
this to assert bit-identical double runs.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import zlib
from typing import Optional

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.resilience.errors import InjectedFault

KNOWN_SITES = ("kernel.fallback", "cap.exhaust", "ovf.exhaust",
               "color.corrupt", "service.step", "service.submit")

ENV_VAR = "REPRO_FAULTS"


@dataclasses.dataclass
class FaultPoint:
    """One armed site: firing policy + deterministic per-site RNG state."""

    site: str
    p: float = 1.0
    seed: int = 0
    after: int = 0                 # eligible-call warmup before any fire
    times: Optional[int] = None    # max fires (None = unlimited)
    k: int = 1                     # payload count (site-specific meaning)
    calls: int = 0
    fired: int = 0

    def __post_init__(self):
        # site-salted seed: two sites sharing seed=0 draw distinct streams
        self.rng = np.random.default_rng(
            (int(self.seed) << 32) ^ zlib.crc32(self.site.encode()))

    def draw(self) -> bool:
        self.calls += 1
        if self.calls <= self.after:
            return False
        if self.times is not None and self.fired >= self.times:
            return False
        hit = True if self.p >= 1.0 else bool(self.rng.random() < self.p)
        if hit:
            self.fired += 1
        return hit


def parse_spec(spec: str) -> dict[str, FaultPoint]:
    """``"site[:k=v]*[;site...]"`` -> {site: FaultPoint}; raises on unknown
    sites/params so a typo'd REPRO_FAULTS fails loudly, not silently off."""
    plan: dict[str, FaultPoint] = {}
    for part in filter(None, (s.strip() for s in spec.split(";"))):
        fields = part.split(":")
        site = fields[0].strip()
        if site not in KNOWN_SITES:
            raise ValueError(
                f"unknown fault site {site!r}; known: {list(KNOWN_SITES)}")
        kw: dict = {}
        for f in fields[1:]:
            key, _, val = f.partition("=")
            key = key.strip()
            if key == "p":
                kw["p"] = float(val)
            elif key in ("seed", "after", "times", "k"):
                kw[key] = int(val)
            else:
                raise ValueError(
                    f"unknown fault param {key!r} in {part!r}; "
                    f"known: p, seed, after, times, k")
        plan[site] = FaultPoint(site=site, **kw)
    return plan


# None = injection off (the fast path: one module-global None check per
# site visit).  Parsed once at import so a spec'd child process is armed
# before any engine code runs; tests re-arm via install()/inject().
_PLAN: Optional[dict[str, FaultPoint]] = None
_SPEC: Optional[str] = None


def _arm_from_env() -> None:
    global _PLAN, _SPEC
    spec = os.environ.get(ENV_VAR, "").strip()
    if spec:
        _PLAN, _SPEC = parse_spec(spec), spec


_arm_from_env()


def active() -> bool:
    """True iff any fault site is armed."""
    return _PLAN is not None


def spec() -> Optional[str]:
    """The currently-armed spec string (None when off)."""
    return _SPEC


def install(spec_: Optional[str]) -> None:
    """Arm ``spec_`` (replacing any current plan); ``None``/empty disarms."""
    global _PLAN, _SPEC
    if not spec_:
        _PLAN, _SPEC = None, None
    else:
        _PLAN, _SPEC = parse_spec(spec_), spec_


def reset() -> None:
    """Re-arm the current spec with fresh call/fire counters and RNG state —
    the next run sees the exact firing sequence of the first."""
    install(_SPEC)


@contextlib.contextmanager
def inject(spec_: Optional[str]):
    """Arm ``spec_`` for the scope; restores the previous plan on exit."""
    global _PLAN, _SPEC
    prev = (_PLAN, _SPEC)
    install(spec_)
    try:
        yield
    finally:
        _PLAN, _SPEC = prev


@contextlib.contextmanager
def suppress():
    """Disarm every fault for the scope (the chaos tests' fault-free
    reference runs live here); restores the previous plan on exit."""
    with inject(None):
        yield


def fires(site: str, **meta) -> bool:
    """Deterministically decide whether ``site`` fires at this call.

    Off (the production path) this is one None check.  On, the armed
    site's policy draws; a fire bumps ``resilience.fault{site=...}``.
    """
    if _PLAN is None:
        return False
    fp = _PLAN.get(site)
    if fp is None or not fp.draw():
        return False
    obs_metrics.counter("resilience.fault", site=site).inc()
    return True


def check(site: str, **meta) -> None:
    """Raise ``InjectedFault`` iff ``site`` fires (exception-type sites)."""
    if fires(site, **meta):
        raise InjectedFault(site, meta)


def param(site: str, name: str, default):
    """An armed site's payload param (e.g. ``k``); ``default`` when off."""
    if _PLAN is None:
        return default
    fp = _PLAN.get(site)
    return default if fp is None else getattr(fp, name, default)


def rng(site: str) -> np.random.Generator:
    """The armed site's deterministic RNG (payload decisions share the
    firing stream, so replays stay exact).  Only meaningful right after
    ``fires(site)`` returned True."""
    assert _PLAN is not None and site in _PLAN, site
    return _PLAN[site].rng
