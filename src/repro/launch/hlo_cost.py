"""Trip-count-aware cost model over post-optimization HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of its
trip count (verified on this jax/XLA build), which under-counts scanned
layers and chunked-attention loops by orders of magnitude.  XLA's loop
analysis leaves ``backend_config={"known_trip_count":{"n":"L"}}`` on every
``while`` op, so an honest per-device cost is recoverable by walking the
call graph with multipliers.

Model:
  flops  — 2 * result_elems * prod(lhs contracting dims) per ``dot``
           (+ convolution treated as dot-equivalent if present), summed over
           every computation reachable from ENTRY; computations called from
           a while body are scaled by the loop's known trip count.
           Elementwise/transcendental flops are ignored (dot-dominated
           workloads; consistent with roofline practice).
  bytes  — HBM traffic at the *schedule level*: for every op in a
           control-reachable computation (ENTRY, while bodies/conds,
           conditional branches, call targets — NOT fusion interiors),
           result bytes + resolvable operand bytes.  Tuple plumbing,
           bitcasts, parameters and constants are free.  Fusion interiors
           never touch HBM (that is what fusion means); their boundary
           (operands/results) is what's counted.
  collectives — same walk, restricted to collective ops, with ring factors
           (see analysis.parse_collectives) and trip-count multipliers.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_OP_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*)\[([0-9,]*)\]")


def _parse_op_line(line: str):
    """-> (name, type_str, opcode, rest) or None.

    Handles tuple result types containing ``/*index=N*/`` comments (which
    defeat naive regexes) by scanning to the matching paren."""
    m = _OP_NAME_RE.match(line)
    if not m:
        return None
    i = m.end()
    if i >= len(line):
        return None
    if line[i] == "(":                       # tuple type: match parens
        depth, j = 1, i + 1
        while j < len(line) and depth:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
            j += 1
        type_str = line[i:j]
    else:                                    # plain `dtype[dims]{layout}`
        j = i
        while j < len(line) and not line[j].isspace():
            j += 1
        type_str = line[i:j]
    m2 = _OPCODE_RE.match(line, j)
    if not m2:
        return None
    return m.group(1), type_str, m2.group(1), line[m2.end():]
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[\\\"=:{ ]+n[\\\":]+(\d+)')
_CALL_ATTR = re.compile(r"(?:body|condition|branch_computations|to_apply|calls)=")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    return sum(_shape_elems(dims) * _DTYPE_BYTES.get(dt, 4)
               for dt, dims in _SHAPE_RE.findall(type_str))


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    rest: str          # everything after the opening '('
    is_root: bool = False

    @property
    def operands(self):
        return _OPERAND_RE.findall(self.rest.split(")")[0])


def parse_hlo(hlo_text: str):
    """-> (computations: name -> [Op], entry_name)."""
    comps, entry = {}, None
    cur, cur_name = None, None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur_name = m.group(1)
                cur = []
                comps[cur_name] = cur
                if line.strip().startswith("ENTRY"):
                    entry = cur_name
                continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        parsed = _parse_op_line(line)
        if parsed:
            cur.append(_Op(*parsed, is_root="ROOT " in line[:12]))
    return comps, entry


def _called_comps(op: _Op):
    """Names of computations an op calls, tagged by mechanism."""
    out = []
    for attr in ("body", "condition", "to_apply", "calls"):
        m = re.search(attr + r"=%?([\w.\-]+)", op.rest)
        if m:
            out.append((attr, m.group(1)))
    m = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
    if m:
        for name in m.group(1).split(","):
            out.append(("branch", name.strip().lstrip("%")))
    return out


_COLL_FACTORS = {
    "all-reduce": lambda R, G: 2.0 * R * (G - 1) / G,
    "all-gather": lambda R, G: R * (G - 1) / G,
    "reduce-scatter": lambda R, G: float(R) * (G - 1),
    "all-to-all": lambda R, G: R * (G - 1) / G,
    "collective-permute": lambda R, G: float(R),
}
_FREE_OPS = {"tuple", "get-tuple-element", "bitcast", "parameter", "constant",
             "after-all", "reshape", "iota", "partition-id", "replica-id"}

# Bare elementwise/broadcast ops at schedule level: the TPU backend fuses
# these into neighbouring dots/fusions/reduces, so counting their operands
# as HBM traffic would double-bill nearly every tensor (the CPU backend we
# compile on fuses less aggressively).  Their traffic is attributed to the
# *consuming* counted op instead.
_FUSABLE_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "rsqrt", "sqrt", "cbrt", "negate", "abs", "sign", "floor",
    "ceil", "round-nearest-afz", "round-nearest-even", "select", "clamp",
    "compare", "and", "or", "xor", "not", "convert", "broadcast", "pad",
    "reverse", "real", "imag", "is-finite", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "popcnt", "map",
    "rng-bit-generator", "rng", "expm1", "log1p", "atan2", "remainder",
    "cosine", "sine", "tan", "erf", "exp",
}


def _group_size(rest: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return max(int(m.group(2)), 1)
    m = re.search(r"replica_groups=\{\{([0-9,]*)\}", rest)
    if m:
        return max(len([x for x in m.group(1).split(",") if x]), 1)
    return 1


class HloCost:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_hlo(hlo_text)
        self.types = {}              # (comp, op_name) -> type_str
        for cname, ops in self.comps.items():
            for op in ops:
                self.types[(cname, op.name)] = op.type_str
        self._flops_memo = {}
        self._bytes_memo = {}
        self._coll_memo = {}

    # -- flops ---------------------------------------------------------------

    def _dot_flops(self, cname: str, op: _Op) -> float:
        result_elems = sum(_shape_elems(d)
                           for _, d in _SHAPE_RE.findall(op.type_str))
        ops = _OPERAND_RE.findall(op.rest.split(")")[0])
        lhs_type = self.types.get((cname, ops[0])) if ops else None
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
        k = 1
        if lhs_type and m:
            dims_str = _SHAPE_RE.search(lhs_type)
            if dims_str:
                lhs_dims = [int(x) for x in dims_str.group(2).split(",") if x]
                for ci in m.group(1).split(","):
                    if ci and int(ci) < len(lhs_dims):
                        k *= lhs_dims[int(ci)]
        return 2.0 * result_elems * k

    def comp_flops(self, cname: str) -> float:
        if cname in self._flops_memo:
            return self._flops_memo[cname]
        self._flops_memo[cname] = 0.0     # cycle guard
        total = 0.0
        for op in self.comps.get(cname, []):
            if op.opcode in ("dot", "convolution"):
                total += self._dot_flops(cname, op)
            for mech, callee in _called_comps(op):
                mult = 1.0
                if op.opcode == "while" and mech == "body":
                    mult = float(self._trip(op))
                if op.opcode == "while" and mech == "condition":
                    mult = float(self._trip(op)) + 1
                total += mult * self.comp_flops(callee)
        self._flops_memo[cname] = total
        return total

    def _trip(self, op: _Op) -> int:
        m = _TRIP_RE.search(op.rest)
        return int(m.group(1)) if m else 1

    # -- bytes ----------------------------------------------------------------

    def _producer(self, cname: str, oname: str) -> Optional[_Op]:
        key = (cname, oname)
        if not hasattr(self, "_op_index"):
            self._op_index = {}
            for cn, ops in self.comps.items():
                for o in ops:
                    self._op_index[(cn, o.name)] = o
        return self._op_index.get(key)

    def _is_transparent_fusion(self, op: _Op) -> bool:
        """Fusions containing only converts/copies/layout ops.

        XLA-CPU's FloatNormalization wraps every bf16 tensor in
        convert-to-f32 fusions (no native bf16 on host); on TPU these fuse
        into their consumers and never touch HBM.  Billing them — or their
        f32 results as consumer operands — would double-count nearly every
        activation at 2x width."""
        if op.opcode != "fusion":
            return False
        key = ("transparent", op.name)
        if key in self._bytes_memo:
            return self._bytes_memo[key]
        callee = next((c for m, c in _called_comps(op) if m == "calls"), None)
        ok = False
        if callee in self.comps:
            ok = all(o.opcode in _FREE_OPS or o.opcode in _FUSABLE_OPS
                     or o.opcode in ("copy", "transpose")
                     for o in self.comps[callee])
        self._bytes_memo[key] = ok
        return ok

    def _operand_bytes(self, cname: str, oname: str, depth: int = 0) -> float:
        """Read traffic for one operand: 0 for values that never live in
        HBM (broadcast-of-scalar, iota, constants); transparent
        convert/copy fusions resolve through to their source operand."""
        prod = self._producer(cname, oname)
        if prod is not None and prod.opcode in ("iota", "constant"):
            return 0.0
        if prod is not None and prod.opcode == "broadcast":
            ops = prod.operands
            t = self.types.get((cname, ops[0])) if ops else None
            return _type_bytes(t) if t else 0.0
        if (prod is not None and depth < 4
                and self._is_transparent_fusion(prod) and prod.operands):
            return self._operand_bytes(cname, prod.operands[0], depth + 1)
        t = self.types.get((cname, oname))
        return _type_bytes(t) if t else 0.0

    def _fusion_bytes(self, cname: str, op: _Op) -> float:
        """Boundary traffic of a fusion: per-parameter reads (billed at the
        fused dynamic-slice/gather result size when the parameter is only
        sliced — scan bodies read ONE layer slice of a stacked array, not
        the stack) + result writes (billed at the update size when the root
        is a fused dynamic-update-slice)."""
        callee = None
        for mech, c in _called_comps(op):
            if mech == "calls":
                callee = c
        if callee is None or callee not in self.comps:
            b = _type_bytes(op.type_str)
            for oname in op.operands:
                b += self._operand_bytes(cname, oname)
            return b
        fops = self.comps[callee]
        by_name = {o.name: o for o in fops}
        # consumers of each value inside the fused computation
        consumers = {}
        for o in fops:
            for nm in o.operands:
                consumers.setdefault(nm, []).append(o)
        total = 0.0
        # parameter reads (billed through transparent producer fusions)
        outer_operands = op.operands
        for o in fops:
            if o.opcode != "parameter":
                continue
            cons = consumers.get(o.name, [])
            if cons and all(c.opcode in ("dynamic-slice", "gather")
                            for c in cons):
                total += sum(_type_bytes(c.type_str) for c in cons)
                continue
            m = re.search(r"parameter\((\d+)\)", o.opcode + "(" +
                          o.rest) or re.search(r"\((\d+)\)", o.rest)
            idx = int(m.group(1)) if m else None
            if idx is not None and idx < len(outer_operands):
                total += self._operand_bytes(cname, outer_operands[idx])
            else:
                total += _type_bytes(o.type_str)
        # result writes
        root = next((o for o in fops if o.is_root), fops[-1] if fops else None)
        if root is not None and root.opcode == "dynamic-update-slice" \
                and len(root.operands) >= 2:
            upd = by_name.get(root.operands[1])
            total += 2.0 * (_type_bytes(upd.type_str) if upd is not None
                            else _type_bytes(root.type_str))
        else:
            total += _type_bytes(op.type_str)
        return total

    def comp_bytes(self, cname: str) -> float:
        """Schedule-level HBM traffic of a control computation."""
        if cname in self._bytes_memo:
            return self._bytes_memo[cname]
        self._bytes_memo[cname] = 0.0
        total = 0.0
        for op in self.comps.get(cname, []):
            called = _called_comps(op)
            if op.opcode == "while":
                trip = float(self._trip(op))
                for mech, callee in called:
                    total += (trip if mech == "body" else trip + 1) \
                        * self.comp_bytes(callee)
                continue
            if op.opcode == "conditional":
                sub = [self.comp_bytes(c) for _, c in called]
                total += max(sub) if sub else 0.0
                continue
            if op.opcode == "call":
                total += sum(self.comp_bytes(c) for _, c in called)
                continue
            if op.opcode in _FREE_OPS or op.opcode in _FUSABLE_OPS:
                continue
            if op.opcode == "fusion":
                if self._is_transparent_fusion(op):
                    continue
                total += self._fusion_bytes(cname, op)
                continue
            if op.opcode == "dynamic-update-slice":
                # in-place region write: read+write the UPDATE, not the stack
                upd = (self.types.get((cname, op.operands[1]))
                       if len(op.operands) >= 2 else None)
                total += 2.0 * (_type_bytes(upd) if upd
                                else _type_bytes(op.type_str))
                continue
            if op.opcode == "dynamic-slice":
                total += 2.0 * _type_bytes(op.type_str)
                continue
            # boundary traffic of materializing ops: result + operands
            # (dot, reduce, copy, gather/scatter, collectives, sort, ...)
            b = _type_bytes(op.type_str)
            for oname in op.operands:
                b += self._operand_bytes(cname, oname)
            total += b
        self._bytes_memo[cname] = total
        return total

    # -- collectives -----------------------------------------------------------

    def comp_collectives(self, cname: str) -> dict:
        if cname in self._coll_memo:
            return self._coll_memo[cname]
        self._coll_memo[cname] = {k: {"bytes": 0.0, "count": 0.0}
                                  for k in _COLL_FACTORS}
        tot = {k: {"bytes": 0.0, "count": 0.0} for k in _COLL_FACTORS}
        for op in self.comps.get(cname, []):
            base = op.opcode.replace("-start", "")
            if base in _COLL_FACTORS and not op.opcode.endswith("-done"):
                R = _type_bytes(op.type_str)
                if op.opcode.endswith("-start"):
                    R /= 2.0              # start result aliases (operand, out)
                if "_promoted" in op.rest and "f32[" in op.type_str:
                    # XLA-CPU FloatNormalization promotes bf16 reductions to
                    # f32 (no native bf16 on host).  TPU runs them at source
                    # precision — bill the wire at bf16.
                    R /= 2.0
                G = _group_size(op.rest)
                tot[base]["bytes"] += _COLL_FACTORS[base](R, G)
                tot[base]["count"] += 1
            for mech, callee in _called_comps(op):
                mult = float(self._trip(op)) if (op.opcode == "while"
                                                 and mech == "body") else 1.0
                sub = self.comp_collectives(callee)
                for k in _COLL_FACTORS:
                    tot[k]["bytes"] += mult * sub[k]["bytes"]
                    tot[k]["count"] += mult * sub[k]["count"]
        self._coll_memo[cname] = tot
        return tot

    # -- public ----------------------------------------------------------------

    def totals(self) -> dict:
        coll = self.comp_collectives(self.entry)
        coll_total = sum(v["bytes"] for v in coll.values())
        coll_count = sum(v["count"] for v in coll.values())
        out = {
            "flops": self.comp_flops(self.entry),
            "bytes": self.comp_bytes(self.entry),
            "collectives": dict(coll, total_bytes=coll_total,
                                total_count=coll_count),
        }
        return out


def analyze_text(hlo_text: str) -> dict:
    return HloCost(hlo_text).totals()
