"""Multi-pod dry-run: lower + compile every (architecture x input shape) cell
on the production meshes and extract the roofline terms.

MUST be run as a standalone process (the device-count flag below has to land
before jax initializes — hence the env assignment before any other import).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out results/dryrun.jsonl
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback

import jax

from repro import configs
from repro.configs.common import shapes_for
from repro.launch import analysis as AN
from repro.launch import cells as CELLS
from repro.launch.mesh import make_production_mesh, n_chips


def model_flops_for(arch: str, shape: str) -> float:
    arch_def = configs.get(arch)
    shp = shapes_for(arch_def.family)[shape]
    if arch_def.family == "lm":
        cfg = arch_def.make_full()
        return AN.lm_model_flops(cfg, shp["kind"], shp["batch"],
                                 shp["seq_len"])
    if arch_def.family == "gnn":
        cfg = arch_def.make_full(d_in=shp["d_feat"],
                                 n_classes=shp["n_classes"])
        shapes, n_nodes = CELLS._gnn_batch_shapes(arch_def, shp)
        n_edges = shapes["src"][0]
        return AN.gnn_model_flops(arch, cfg, n_nodes, n_edges)
    cfg = arch_def.make_full()
    return AN.recsys_model_flops(cfg, shp["kind"], shp["batch"],
                                 shp.get("n_candidates", 0))


def _parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        out[k] = {"true": True, "false": False}.get(
            v.lower(), int(v) if v.lstrip("-").isdigit() else v)
    return out or None


def run_cell(arch: str, shape: str, multi_pod: bool,
             verbose: bool = True, overrides=None) -> dict:
    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = CELLS.build_cell(arch, shape, mesh, overrides=overrides)
    lowered = cell.lower(mesh)
    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower
    res = AN.analyze(compiled, n_chips(mesh),
                     model_flops=model_flops_for(arch, shape))
    res.update(arch=arch, shape=shape, kind=cell.kind,
               mesh="2x16x16" if multi_pod else "16x16",
               t_lower_s=round(t_lower, 2), t_compile_s=round(t_compile, 2),
               overrides=overrides, ok=True)
    if verbose:
        r = res["roofline"]
        peak = res["memory"].get("peak_bytes_per_device")
        peak_s = f" peak={peak / 2**30:.1f}GiB" if peak else ""
        print(f"[OK] {arch:24s} {shape:14s} {res['mesh']:7s} "
              f"tc={r['t_compute_s']:.3e} tm={r['t_memory_s']:.3e} "
              f"tx={r['t_collective_s']:.3e} -> {r['bottleneck']:10s}"
              f"{peak_s} (lower {t_lower:.0f}s compile {t_compile:.0f}s)",
              flush=True)
    return res


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=("no", "yes", "both"),
                    default="no")
    ap.add_argument("--out", default=None, help="append-mode jsonl")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already present in --out")
    ap.add_argument("--set", action="append", default=[], metavar="K=V",
                    help="model-config override (perf variants), repeatable")
    args = ap.parse_args()
    overrides = _parse_overrides(args.set)

    if args.all:
        grid = configs.all_cells()
    else:
        if not args.arch:
            raise SystemExit("--arch or --all required")
        shapes = ([args.shape] if args.shape else
                  list(shapes_for(configs.get(args.arch).family)))
        grid = [(args.arch, s) for s in shapes]
    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multi_pod]

    done = set()
    if args.out and args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("ok"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    n_fail = 0
    for arch, shape in grid:
        for mp in pods:
            mesh_name = "2x16x16" if mp else "16x16"
            if (arch, shape, mesh_name) in done:
                continue
            try:
                res = run_cell(arch, shape, mp, overrides=overrides)
            except Exception as e:
                n_fail += 1
                res = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "ok": False, "error": f"{type(e).__name__}: {e}"}
                print(f"[FAIL] {arch} {shape} {mesh_name}: {e}", flush=True)
                traceback.print_exc()
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(res) + "\n")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
