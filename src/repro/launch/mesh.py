"""Production meshes (TPU v5e numbers; DESIGN.md §4).

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax

# hardware constants (TPU v5e per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Mesh over whatever local devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))


def n_chips(mesh) -> int:
    return int(mesh.devices.size)


def batch_axes(mesh) -> tuple:
    """Axes a global-batch dimension shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
