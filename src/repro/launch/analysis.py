"""Roofline bookkeeping: HLO collective parsing + the three roofline terms.

Conventions (EXPERIMENTS.md §Roofline):
  * ``cost_analysis()`` of an SPMD-partitioned executable reports the
    per-device program -> compute/memory terms are per-chip seconds.
  * collective bytes = sum of operand sizes of every all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute in the
    post-optimization per-device HLO; divided by the per-chip ICI
    bandwidth this is a per-chip lower-bound wire time (ring/bidirectional
    factors are schedule-dependent and documented, not modeled).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*)\[([0-9,]*)\]")
_GROUPS_COMPACT_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_COMPACT_RE.search(line)
    if m:                                  # [n_groups, group_size]<=[N]
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:                                  # {{0,1,2,...},...}
        return max(len([x for x in m.group(1).split(",") if x]), 1)
    return 1


def parse_collectives(hlo_text: str) -> dict:
    """Per-device wire bytes + counts from post-optimization HLO text.

    Post-SPMD HLO prints per-device shapes; operands carry no types, so
    bytes are derived from the RESULT shape R and the group size G with the
    standard ring-schedule factors:
        all-reduce          2 * R * (G-1)/G     (reduce-scatter + all-gather)
        all-gather          R * (G-1)/G         (R = gathered result)
        reduce-scatter      R * (G-1)            (operand = R*G)
        all-to-all          R * (G-1)/G
        collective-permute  R
    """
    factors = {
        "all-reduce": lambda R, G: 2.0 * R * (G - 1) / G,
        "all-gather": lambda R, G: R * (G - 1) / G,
        "reduce-scatter": lambda R, G: float(R) * (G - 1),
        "all-to-all": lambda R, G: R * (G - 1) / G,
        "collective-permute": lambda R, G: float(R),
    }
    out = {k: {"bytes": 0.0, "count": 0} for k in factors}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or m.group(3) == "-done":   # count start/bare, skip done
            continue
        kind = m.group(2)
        R = sum(_shape_bytes(dt, dims)
                for dt, dims in _SHAPE_RE.findall(m.group(1)))
        G = _group_size(line)
        out[kind]["bytes"] += factors[kind](R, G)
        out[kind]["count"] += 1
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for v in out.values()
                             if isinstance(v, dict))
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    coll_bytes_per_device: float
    n_chips: int
    model_flops: float = 0.0         # 6*N*D style useful-FLOPs estimate

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> Optional[float]:
        total = self.flops_per_device * self.n_chips
        return (self.model_flops / total) if (self.model_flops and total) \
            else None

    @property
    def roofline_fraction(self) -> Optional[float]:
        """Fraction of the compute roofline achievable at the bound:
        useful model FLOPs / (chips * peak * bound-time)."""
        if not self.model_flops or self.t_bound <= 0:
            return None
        return self.model_flops / (self.n_chips * PEAK_FLOPS_BF16
                                   * self.t_bound)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(compiled, n_chips: int, model_flops: float = 0.0) -> dict:
    """Roofline terms via the trip-count-aware HLO walker (hlo_cost.py).

    ``cost_analysis()`` is recorded as a cross-check but NOT used for the
    terms: it counts while bodies once, under-costing scanned layers."""
    from repro.launch import hlo_cost
    cost = compiled.cost_analysis()
    if isinstance(cost, list):            # some backends return [dict]
        cost = cost[0]
    walk = hlo_cost.analyze_text(compiled.as_text())
    coll = walk["collectives"]
    roof = Roofline(flops_per_device=float(walk["flops"]),
                    hbm_bytes_per_device=float(walk["bytes"]),
                    coll_bytes_per_device=float(coll["total_bytes"]),
                    n_chips=n_chips, model_flops=model_flops)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            if hasattr(ma, k):
                mem[k] = int(getattr(ma, k))
        mem["peak_bytes_per_device"] = (
            mem.get("argument_size_in_bytes", 0)
            + mem.get("output_size_in_bytes", 0)
            + mem.get("temp_size_in_bytes", 0)
            - mem.get("alias_size_in_bytes", 0))
    except Exception as e:                # CPU backend may not support it
        mem["error"] = str(e)
    xcheck = {"xla_flops": float(cost.get("flops", 0.0)),
              "xla_bytes": float(cost.get("bytes accessed", 0.0))}
    return {"roofline": roof.as_dict(), "collectives": coll, "memory": mem,
            "xla_cost_crosscheck": xcheck}


# --------------------------------------------------------------------------
# useful-FLOPs (MODEL_FLOPS) estimates per cell
# --------------------------------------------------------------------------

def lm_model_flops(cfg, kind: str, batch: int, seq_len: int) -> float:
    """Useful FLOPs: 6*N*D (train) / 2*N*D (inference) linear term plus the
    ideal causal attention term (2*B*L^2*H*Dh per layer fwd, x3 train)."""
    n_active = cfg.n_active_params()
    h_dh = cfg.n_heads * cfg.head_dim
    if kind == "train":
        attn = 6.0 * cfg.n_layers * batch * seq_len ** 2 * h_dh * 0.5
        return 6.0 * n_active * batch * seq_len + attn
    if kind == "prefill":
        attn = 2.0 * cfg.n_layers * batch * seq_len ** 2 * h_dh * 0.5
        return 2.0 * n_active * batch * seq_len + attn
    # decode: one token per request against a seq_len cache
    attn = 4.0 * cfg.n_layers * batch * seq_len * h_dh
    return 2.0 * n_active * batch + attn


def gnn_model_flops(arch: str, cfg, n_nodes: int, n_edges: int,
                    train: bool = True) -> float:
    if arch == "gat-cora":
        per_l = 2 * n_nodes * cfg.d_in * cfg.n_heads * cfg.d_hidden \
            + 4 * n_edges * cfg.n_heads * cfg.d_hidden
        f = cfg.n_layers * per_l
    elif arch == "meshgraphnet":
        d = cfg.d_hidden
        per_l = 2 * n_edges * (3 * d) * d + 2 * n_edges * d * d \
            + 2 * n_nodes * (2 * d) * d + 2 * n_nodes * d * d
        f = cfg.n_layers * per_l
    elif arch == "gatedgcn":
        d = cfg.d_hidden
        f = cfg.n_layers * (2 * 3 * n_nodes * d * d + 2 * 2 * n_edges * d * d)
    else:                                     # nequip
        C = cfg.channels
        n_paths = len(cfg.paths)
        # per edge per path: C * (2l1+1)(2l2+1)(2l3+1) MACs ~ C*27 at l_max=2
        f = cfg.n_layers * n_edges * n_paths * C * 27 * 2 \
            + cfg.n_layers * 2 * n_nodes * 2 * C * C * 9
    return (3.0 if train else 1.0) * f


def recsys_model_flops(cfg, kind: str, batch: int,
                       n_candidates: int = 0) -> float:
    d = cfg.d_x0
    cross = cfg.n_cross_layers * 2 * d * d
    mlp, d_in = 0, d
    for h in cfg.mlp_dims:
        mlp += 2 * d_in * h
        d_in = h
    per_ex = cross + mlp + cfg.n_sparse * cfg.embed_dim  # + bag gather adds
    if kind == "retrieval":
        return per_ex + 2.0 * n_candidates * cfg.mlp_dims[-1]
    return (3.0 if kind == "train" else 1.0) * batch * per_ex
