"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs REAL training on the local devices (reduced/smoke configs on CPU; the
full configs are for the dry-run meshes).  Wires together: config registry ->
data pipeline -> jitted train step -> checkpointing -> watchdog.

Fault-tolerance wiring (works the same on a real cluster):
  * checkpoint every --ckpt-every steps (async, atomic) + data-stream state;
  * crash/restart: rerun the same command; it resumes from LATEST
    (bitwise-identical stream continuation — counter-based RNG);
  * straggler watchdog: if a step exceeds --step-timeout x the trailing
    median, the launcher aborts with exit code 75 so the job manager
    relaunches from LATEST (on multi-host TPU a hung collective never
    returns; timeout-and-relaunch is the standard mitigation);
  * elastic restart: checkpoints hold full logical arrays — a different
    device count on restart just re-shards (training/elastic.py).
"""
from __future__ import annotations

import argparse
import functools
import statistics
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import pipeline as DP
from repro.models import transformer as TF
from repro.models import recsys as RS
from repro.training.optimizer import OptimizerConfig
from repro.training import train_loop as TL


def build_lm(arch_def, smoke: bool, batch: int, seq_len: int):
    cfg = arch_def.make_smoke() if smoke else arch_def.make_full()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    stream = DP.TokenStream(batch=batch, seq_len=seq_len, vocab=cfg.vocab)
    loss = functools.partial(TF.train_step_loss, cfg=cfg)
    return params, stream, lambda p, b: loss(p, batch=b)


def build_recsys(arch_def, smoke: bool, batch: int):
    cfg = arch_def.make_smoke() if smoke else arch_def.make_full()
    params = RS.dcnv2_init(jax.random.PRNGKey(0), cfg)
    stream = DP.RecsysStream(batch=batch, n_dense=cfg.n_dense,
                             n_sparse=cfg.n_sparse, vocabs=cfg.vocabs,
                             max_hots=cfg.max_hots)
    return params, stream, lambda p, b: RS.ctr_loss(p, cfg, b)


def build_gnn(arch_def, smoke: bool, batch: int):
    from repro.graphs.generators import mesh2d
    from repro.launch.cells import _gnn_loss_fn
    from repro.models import gnn as GNN
    from repro.models import equivariant as EQ
    model = arch_def.extras["model"]
    if model == "nequip":
        cfg = arch_def.make_smoke()
        stream = DP.MoleculeStream(n_nodes=10, n_edges=24, batch=batch,
                                   n_species=cfg.n_species, d_feat=0)
        b0 = next(stream)
        n_nodes = b0["species"].shape[0]
        params = EQ.nequip_init(jax.random.PRNGKey(0), cfg)

        def loss(p, b):
            return EQ.energy_loss(p, cfg, b)
        return params, stream, loss
    cfg = arch_def.make_smoke()
    g = mesh2d(24, 24)
    stream = DP.FullGraphStream(g, d_feat=cfg.d_in,
                                n_classes=getattr(cfg, "n_classes",
                                                  getattr(cfg, "d_out", 3)),
                                pad_edges_to=1024)
    init = {"gat": GNN.gat_init, "mgn": GNN.mgn_init,
            "gatedgcn": GNN.gatedgcn_init}[model]
    params = init(jax.random.PRNGKey(0), cfg)
    shp = {"mode": "full", "d_feat": cfg.d_in, "n_classes": 3}
    n_nodes = g.n_vertices + 1
    loss_fn = _gnn_loss_fn(arch_def, shp, cfg, n_nodes)

    def loss(p, b):
        if model == "mgn" and "edge_feats" not in b:
            b = dict(b, edge_feats=jnp.zeros((b["src"].shape[0], 4),
                                             jnp.float32))
        return loss_fn(p, b)
    return params, stream, loss


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--full", action="store_true",
                    help="full config (needs real accelerators)")
    ap.add_argument("--step-timeout", type=float, default=10.0,
                    help="abort (exit 75) if a step exceeds this many x the "
                         "trailing-median step time (straggler watchdog)")
    args = ap.parse_args(argv)

    arch_def = configs.get(args.arch)
    smoke = not args.full
    if arch_def.family == "lm":
        params, stream, loss = build_lm(arch_def, smoke, args.batch,
                                        args.seq_len)
    elif arch_def.family == "recsys":
        params, stream, loss = build_recsys(arch_def, smoke, args.batch)
    else:
        params, stream, loss = build_gnn(arch_def, smoke, args.batch)

    opt_cfg = OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                              total_steps=args.steps)
    loop_cfg = TL.TrainLoopConfig(
        total_steps=args.steps, microbatches=args.microbatches,
        ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir, log_every=5)

    times = []

    def watchdog(m):
        print(f"  step {m['step']:5d} loss {m['loss']:.4f} "
              f"({m['sec_per_step']:.3f}s/step)", flush=True)
        times.append(m["sec_per_step"])
        if len(times) >= 5:
            med = statistics.median(times[-20:])
            if times[-1] > args.step_timeout * med:
                print(f"WATCHDOG: step took {times[-1]:.1f}s "
                      f"(> {args.step_timeout}x median {med:.1f}s); "
                      "exiting 75 for relaunch-from-LATEST", file=sys.stderr)
                raise SystemExit(75)

    to_dev = lambda b: jax.tree.map(jnp.asarray, b)
    params, _, hist = TL.run(loss, params, stream, opt_cfg, loop_cfg,
                             to_device=to_dev, on_metrics=watchdog)
    if hist:
        print(f"final loss {hist[-1]['loss']:.4f} after {args.steps} steps")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
