"""Cell builders: (architecture x input shape) -> lowerable jitted program.

A *cell* is one entry of the 40-cell dry-run grid.  ``build_cell`` returns a
``Cell`` whose ``lower(mesh)`` produces ``jax.jit(step).lower(*abstract)``
with every argument a ShapeDtypeStruct carrying a NamedSharding — no real
allocation ever happens (the full configs are exercised only this way).

Step kinds per family (configs/common.py shape tables):
  lm.train      full update step: loss -> grad -> AdamW (params+opt donated)
  lm.prefill    tokens -> (last logits, per-layer caches)
  lm.decode     one token against a seq_len KV/latent cache
  gnn.train     full update step over COO edges (full/sampled/batched modes)
  recsys.train  full update step (CTR loss)
  recsys.serve  batched scoring;  recsys.retrieval  1 query vs 1M candidates
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.common import shapes_for
from repro.launch import sharding as SH
from repro.launch.mesh import batch_axes
from repro.models import equivariant as EQ
from repro.models import gnn as GNN
from repro.models import recsys as RS
from repro.models import transformer as TF
from repro.training.optimizer import OptimizerConfig, adamw_update
from repro.graphs.sampler import union_caps


EDGE_PAD = 8192      # GNN edge arrays pad to this multiple (even sharding)


def _sds(shape, dtype, mesh, spec):
    spec = SH.sanitize_spec(spec, shape, mesh)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _abstract_tree(tree, mesh, rule):
    """ShapeDtypeStructs (with shardings) for every leaf of a shape tree."""
    flat, tdef = jax.tree_util.tree_flatten_with_path(tree)
    out = [jax.ShapeDtypeStruct(
        l.shape, l.dtype,
        sharding=NamedSharding(mesh, SH.sanitize_spec(
            rule(jax.tree_util.keystr(p), l), l.shape, mesh)))
        for p, l in flat]
    return jax.tree_util.tree_unflatten(tdef, out)


def _abstract_opt(params_abs, mesh, rule):
    def f32_like(x, spec_rule_path):
        return x
    mu = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32,
                                       sharding=l.sharding), params_abs)
    return {"mu": mu, "nu": mu,
            "step": jax.ShapeDtypeStruct((), jnp.int32,
                                         sharding=NamedSharding(mesh, P()))}


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    step: Callable          # jit-able
    abstract_args: tuple    # ShapeDtypeStructs with shardings
    donate: tuple = ()
    static_notes: str = ""

    def lower(self, mesh: Mesh):
        with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") \
                else mesh:
            jitted = jax.jit(self.step, donate_argnums=self.donate)
            return jitted.lower(*self.abstract_args)


OPT = OptimizerConfig(lr=3e-4, warmup_steps=100, total_steps=10_000)


# ==========================================================================
# LM cells
# ==========================================================================

def _constrain(tree, mesh, rule):
    """with_sharding_constraint every leaf to its rule spec (weight-gather
    idiom: storage -> compute layout; grads transpose to reduce-scatter)."""
    def one(path, leaf):
        spec = SH.sanitize_spec(rule(jax.tree_util.keystr(path), leaf),
                                leaf.shape, mesh)
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, spec))
    return jax.tree_util.tree_map_with_path(one, tree)


def _lm_train_cell(arch, shp, mesh, cfg, microbatches: int = 1) -> Cell:
    B, L = shp["batch"], shp["seq_len"]
    params_abs = _abstract_tree(
        jax.eval_shape(lambda k: TF.init_params(k, cfg),
                       jax.random.PRNGKey(0)),
        mesh, SH.lm_param_spec)
    opt_abs = _abstract_opt(params_abs, mesh, SH.lm_param_spec)
    bspec = SH.lm_batch_spec(mesh)
    batch_abs = {"tokens": _sds((B, L), jnp.int32, mesh, bspec),
                 "labels": _sds((B, L), jnp.int32, mesh, bspec)}

    def loss_of(p, b):
        if cfg.fsdp_inner:          # per-layer gather inside the scan body
            p_tp = dict(p, embed=_constrain(p["embed"], mesh,
                                            SH.lm_param_spec_tp))
        else:                       # whole-tree gather at step start
            p_tp = _constrain(p, mesh, SH.lm_param_spec_tp)
        return TF.train_step_loss(p_tp, cfg, b)

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            # gradient accumulation: peak activations / microbatches.
            # Microbatches are SLICES of the sharded batch dim (size B/M
            # stays divisible by the data axes) — a (M, B/M, ...) reshape
            # would break the batch sharding (M < mesh data size).
            # STATIC slice offsets — traced (fori/scan) offsets defeat
            # GSPMD's alignment proof and it replicates the whole batch
            # (measured 16x cost blowup); an unrolled python loop keeps
            # every microbatch slice sharded exactly like its parent.
            mb_size = B // microbatches
            grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            loss = jnp.float32(0)
            for i in range(microbatches):
                mb = jax.tree.map(
                    lambda x: jax.lax.slice_in_dim(
                        x, i * mb_size, (i + 1) * mb_size, axis=0), batch)
                l, g = jax.value_and_grad(loss_of)(params, mb)
                grads = jax.tree.map(lambda a, x: a + x.astype(jnp.float32),
                                     grads, g)
                loss = loss + l
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
        params, opt_state, m = adamw_update(OPT, params, grads, opt_state)
        m["loss"] = loss
        return params, opt_state, m

    return Cell(arch, "train", "train", step,
                (params_abs, opt_abs, batch_abs), donate=(0, 1))


def _lm_prefill_cell(arch, shp, mesh, cfg) -> Cell:
    B, L = shp["batch"], shp["seq_len"]
    params_abs = _abstract_tree(                   # inference: pure TP
        jax.eval_shape(lambda k: TF.init_params(k, cfg),
                       jax.random.PRNGKey(0)),
        mesh, SH.lm_param_spec_tp)
    tokens_abs = _sds((B, L), jnp.int32, mesh, SH.lm_batch_spec(mesh))

    def step(params, tokens):
        return TF.prefill(params, cfg, tokens)

    return Cell(arch, "prefill", "prefill", step, (params_abs, tokens_abs))


def _lm_decode_cell(arch, shp, mesh, cfg) -> Cell:
    B, S = shp["batch"], shp["seq_len"]
    params_abs = _abstract_tree(                   # inference: pure TP
        jax.eval_shape(lambda k: TF.init_params(k, cfg),
                       jax.random.PRNGKey(0)),
        mesh, SH.lm_param_spec_tp)
    cache_shapes = jax.eval_shape(
        lambda: TF.make_empty_cache(cfg, B, S))
    cspec = SH.lm_cache_spec(mesh, cfg.attn_type, B, cfg.n_kv_heads)
    cache_abs = {k: jax.ShapeDtypeStruct(
        v.shape, v.dtype, sharding=NamedSharding(mesh, cspec[k]))
        for k, v in cache_shapes.items()}
    b_axes = batch_axes(mesh)
    bspec = P(b_axes) if B >= int(np.prod([mesh.shape[a] for a in b_axes])) \
        else P()
    tok_abs = _sds((B,), jnp.int32, mesh, bspec)
    len_abs = _sds((B,), jnp.int32, mesh, bspec)

    def step(params, token, cache, length):
        return TF.decode_step(params, cfg, token, cache, length)

    return Cell(arch, "decode", "decode", step,
                (params_abs, tok_abs, cache_abs, len_abs), donate=(2,))


# ==========================================================================
# GNN cells
# ==========================================================================

def _gnn_batch_shapes(arch_def, shp) -> dict:
    """Shape dict for one GNN cell's batch.

    Every graph gets one SINK padding node appended (index N-1) and edge
    arrays padded to a multiple of EDGE_PAD with sink->sink self-loops, so
    edge arrays shard evenly over the whole mesh and padding can never
    pollute real nodes (same trick as sampler.union_pad)."""
    mode = shp["mode"]
    model = arch_def.extras["model"]
    d_feat = shp["d_feat"]
    if mode == "full":
        N, E = shp["n_nodes"] + 1, shp["n_edges"]
        B = None
    elif mode == "sampled":
        caps = union_caps(shp["batch_nodes"],
                          tuple(reversed(shp["fanouts"])))
        N = caps[-1] + 1
        E = sum(c * f for c, f in zip(caps[:-1],
                                      tuple(reversed(shp["fanouts"]))))
        B = shp["batch_nodes"]
    else:                                     # batched molecules
        B = shp["batch"]
        N, E = B * shp["n_nodes"] + 1, B * shp["n_edges"]
    E = -(-E // EDGE_PAD) * EDGE_PAD
    out = {"src": (E,), "dst": (E,), "feats": (N, d_feat)}
    if mode != "batched":                     # batched target = energy
        out["labels"] = (B,) if mode == "sampled" else (N,)
    if model == "mgn":
        out["edge_feats"] = (E, 4)
    if model == "nequip":
        out["positions"] = (N, 3)
        out["species"] = (N,)
    if mode == "batched":
        out["graph_id"] = (N,)
        out["energy"] = (B,)
    if mode == "full":
        out["train_mask"] = (N,)
    return out, N


def _gnn_loss_fn(arch_def, shp, cfg, n_nodes):
    model = arch_def.extras["model"]
    mode = shp["mode"]

    def forward(params, batch):
        if model == "gat":
            out = GNN.gat_apply(params, cfg, batch["feats"], batch["src"],
                                batch["dst"], n_nodes)
        elif model == "mgn":
            out = GNN.mgn_apply(params, cfg, batch["feats"],
                                batch["edge_feats"], batch["src"],
                                batch["dst"], n_nodes)
        elif model == "gatedgcn":
            out = GNN.gatedgcn_apply(params, cfg, batch["feats"],
                                     batch["src"], batch["dst"], n_nodes)
        elif model == "nequip":
            e = EQ.nequip_apply(params, cfg, batch["species"],
                                batch["positions"], batch["src"],
                                batch["dst"], n_nodes,
                                scalar_feats=batch.get("feats"))
            return e[:, None]                     # (N, 1) scalar head
        else:
            raise ValueError(model)
        return out

    def loss(params, batch):
        out = forward(params, batch)
        if mode == "batched":
            e_graph = jax.ops.segment_sum(out.mean(-1), batch["graph_id"],
                                          batch["energy"].shape[0])
            return ((e_graph - batch["energy"]) ** 2).mean()
        if model == "nequip":                     # regression head elsewhere
            tgt = (batch["labels"] % 2).astype(jnp.float32)
            pred = out[: tgt.shape[0], 0]
            return ((pred - tgt) ** 2).mean()
        n_lab = batch["labels"].shape[0]
        logits = out[:n_lab]
        mask = batch.get("train_mask")
        mask = mask[:n_lab] if mask is not None else None
        return GNN.node_classification_loss(logits, batch["labels"], mask)

    return loss


def _gnn_halo_train_cell(arch, shp_name, shp, mesh, arch_def,
                         boundary_frac: float = 0.10) -> Cell:
    """Halo-exchange GatedGCN (shard_map): nodes block-partitioned, only
    boundary features exchanged (paper's replicated->halo trade, §Perf B).

    Shapes assume a block partition with ``boundary_frac`` of each shard's
    nodes on the boundary (mesh/ogb-class graphs; the real plan comes from
    core/partition.build_halo at run time)."""
    from jax.experimental.shard_map import shard_map
    cfg = arch_def.make_full(d_in=shp["d_feat"], n_classes=shp["n_classes"])
    D = int(np.prod(list(mesh.shape.values())))
    axes = tuple(mesh.axis_names)
    N, E = shp["n_nodes"], shp["n_edges"]
    n_loc = -(-N // (D * 128)) * 128
    e_loc = -(-E // (D * 512)) * 512
    max_b = max(128, int(n_loc * boundary_frac) // 128 * 128)
    max_g = max_b                                 # symmetric estimate
    init = GNN.gatedgcn_init
    params_abs = _abstract_tree(
        jax.eval_shape(lambda k: init(k, cfg), jax.random.PRNGKey(0)),
        mesh, SH.gnn_param_spec)
    opt_abs = _abstract_opt(params_abs, mesh, SH.gnn_param_spec)
    shard = P(axes)
    batch_abs = {
        "feats": _sds((D * n_loc, cfg.d_in), jnp.float32, mesh, shard),
        "src": _sds((D * e_loc,), jnp.int32, mesh, shard),
        "dst": _sds((D * e_loc,), jnp.int32, mesh, shard),
        "boundary": _sds((D * max_b,), jnp.int32, mesh, shard),
        "ghost_flat": _sds((D * max_g,), jnp.int32, mesh, shard),
        "labels": _sds((D * n_loc,), jnp.int32, mesh, shard),
        "train_mask": _sds((D * n_loc,), jnp.float32, mesh, shard),
    }

    local_loss = functools.partial(GNN.gatedgcn_halo_loss, cfg=cfg,
                                   axis_names=axes, n_shards=D)
    sharded_loss = shard_map(
        lambda p, b: local_loss(p, batch=b),
        mesh=mesh,
        in_specs=(P(), {k: shard for k in batch_abs}),
        out_specs=P(), check_rep=False)

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: sharded_loss(p, batch))(params)
        params, opt_state, m = adamw_update(OPT, params, grads, opt_state)
        m["loss"] = loss
        return params, opt_state, m

    return Cell(arch, shp_name, "train", step,
                (params_abs, opt_abs, batch_abs), donate=(0, 1),
                static_notes=f"halo boundary_frac={boundary_frac}")


def _gnn_train_cell(arch, shp_name, shp, mesh, arch_def,
                    overrides: Optional[dict] = None) -> Cell:
    gnn_opts = dict(overrides or {})           # cell-level gnn knobs
    if gnn_opts.pop("halo", False):
        if arch_def.extras["model"] != "gatedgcn" or shp["mode"] != "full":
            raise ValueError("halo variant: gatedgcn full-graph cells only")
        return _gnn_halo_train_cell(
            arch, shp_name, shp, mesh, arch_def,
            boundary_frac=float(gnn_opts.pop("boundary_frac", 0.10)))
    cfg = arch_def.make_full(d_in=shp["d_feat"], n_classes=shp["n_classes"])
    model = arch_def.extras["model"]
    init = {"gat": GNN.gat_init, "mgn": GNN.mgn_init,
            "gatedgcn": GNN.gatedgcn_init,
            "nequip": EQ.nequip_init}[model]
    params_abs = _abstract_tree(
        jax.eval_shape(lambda k: init(k, cfg), jax.random.PRNGKey(0)),
        mesh, SH.gnn_param_spec)
    opt_abs = _abstract_opt(params_abs, mesh, SH.gnn_param_spec)
    shapes, n_nodes = _gnn_batch_shapes(arch_def, shp)
    espec = SH.gnn_edge_spec(mesh)
    batch_abs = {}
    for k, s in shapes.items():
        if k in ("src", "dst"):
            batch_abs[k] = _sds(s, jnp.int32, mesh, espec)
        elif k == "edge_feats":
            batch_abs[k] = _sds(s, jnp.float32, mesh,
                                P(espec[0] if espec else None))
        elif k in ("labels", "species", "graph_id"):
            batch_abs[k] = _sds(s, jnp.int32, mesh, P())
        else:
            batch_abs[k] = _sds(s, jnp.float32, mesh, P())
    loss_fn = _gnn_loss_fn(arch_def, shp, cfg, n_nodes)

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, m = adamw_update(OPT, params, grads, opt_state)
        m["loss"] = loss
        return params, opt_state, m

    return Cell(arch, shp_name, "train", step,
                (params_abs, opt_abs, batch_abs), donate=(0, 1))


# ==========================================================================
# RecSys cells
# ==========================================================================

def _recsys_cells(arch, shp_name, shp, mesh, arch_def) -> Cell:
    cfg = arch_def.make_full()
    params_abs = _abstract_tree(
        jax.eval_shape(lambda k: RS.dcnv2_init(k, cfg),
                       jax.random.PRNGKey(0)),
        mesh, SH.recsys_param_spec)
    bspec = P(batch_axes(mesh))
    kind = shp["kind"]
    B = shp["batch"]
    dense_abs = _sds((B, cfg.n_dense), jnp.float32, mesh,
                     bspec if B >= 32 else P())
    sparse_abs = _sds((B, cfg.n_sparse, cfg.max_hots), jnp.int32, mesh,
                      bspec if B >= 32 else P())

    if kind == "train":
        opt_abs = _abstract_opt(params_abs, mesh, SH.recsys_param_spec)
        batch_abs = {"dense": dense_abs, "sparse": sparse_abs,
                     "labels": _sds((B,), jnp.int32, mesh, bspec)}

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: RS.ctr_loss(p, cfg, batch))(params)
            params, opt_state, m = adamw_update(OPT, params, grads, opt_state)
            m["loss"] = loss
            return params, opt_state, m

        return Cell(arch, shp_name, "train", step,
                    (params_abs, opt_abs, batch_abs), donate=(0, 1))

    if kind == "serve":
        batch_abs = {"dense": dense_abs, "sparse": sparse_abs}

        def step(params, batch):
            return RS.predict(params, cfg, batch)

        return Cell(arch, shp_name, "serve", step, (params_abs, batch_abs))

    # retrieval: 1 query vs n_candidates
    NC = shp["n_candidates"]
    cand_abs = _sds((NC, cfg.mlp_dims[-1]), jnp.float32, mesh,
                    P(tuple(mesh.axis_names)))

    def step(params, dense, sparse, cand):
        return RS.retrieval_scores(params, cfg, dense, sparse, cand,
                                   top_k=100)

    return Cell(arch, shp_name, "retrieval", step,
                (params_abs, dense_abs, sparse_abs, cand_abs))


# ==========================================================================
# entry point
# ==========================================================================

def build_cell(arch: str, shape: str, mesh: Mesh,
               overrides: Optional[dict] = None) -> Cell:
    """``overrides``: model-config fields to replace (perf-knob variants for
    the §Perf hillclimb, e.g. {"wire_barrier": True})."""
    arch_def = configs.get(arch)
    shp = dict(shapes_for(arch_def.family)[shape])
    if arch_def.family == "lm":
        overrides = dict(overrides or {})
        microbatches = int(overrides.pop("microbatches", 1))
        moe_ep = overrides.pop("moe_ep", False)
        cfg = arch_def.make_full()
        if moe_ep and cfg.moe is not None:   # EP: experts x capacity shard
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe,
                                             ep_axes=("model", "data")))
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        if getattr(cfg, "act_shard", False) and not cfg.act_batch_axes:
            cfg = dataclasses.replace(cfg, act_batch_axes=batch_axes(mesh))
        if getattr(cfg, "fsdp_inner", False):
            cfg = dataclasses.replace(cfg,
                                      model_axis_size=mesh.shape["model"])
        if shp["kind"] == "train":
            return _lm_train_cell(arch, shp, mesh, cfg,
                                  microbatches=microbatches)
        if shp["kind"] == "prefill":
            return _lm_prefill_cell(arch, shp, mesh, cfg)
        return _lm_decode_cell(arch, shp, mesh, cfg)
    if arch_def.family == "gnn":
        return _gnn_train_cell(arch, shape, shp, mesh, arch_def,
                               overrides=overrides)
    return _recsys_cells(arch, shape, shp, mesh, arch_def)
