"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Spins up the batched ServeEngine on a (smoke) LM config and runs a request
stream through it — the runnable end-to-end serving path (deliverable (b));
the full-config serving shapes are exercised via the dry-run cells.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer as TF
from repro.serving.serve_loop import Request, ServeEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args(argv)

    arch_def = configs.get(args.arch)
    if arch_def.family != "lm":
        raise SystemExit("serving applies to LM archs")
    cfg = arch_def.make_smoke()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, batch=args.batch, max_len=args.max_len)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, rng.integers(4, 32)),
                    max_new_tokens=args.max_new_tokens)
            for _ in range(args.requests)]
    t0 = time.perf_counter()
    eng.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"served {len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, batch={args.batch})")
    assert all(r.done for r in reqs)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
