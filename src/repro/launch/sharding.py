"""Logical sharding rules: param-path -> PartitionSpec, per family.

Conventions (DESIGN.md §4):
  * LM params: 2-D sharded — last dim over ``model`` (TP), second-to-last
    over ``data`` (FSDP); stacked layer params carry a leading L axis.
    Embedding (vocab, d) -> (model, data).  MoE expert stacks
    (L, E, d, f) -> experts over ``model`` (EP), d over ``data``.
  * Optimizer state mirrors its param.
  * GNN params: replicated (tiny); edge arrays sharded over every mesh axis.
  * RecSys: embedding tables row-sharded over ``model``; MLP TP over
    ``model``; everything else replicated.
  * The ``pod`` axis never shards params (pure data parallel across pods).
"""
from __future__ import annotations

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop axis assignments that do not divide a dimension evenly.

    For a dim assigned a tuple of axes, trailing axes are dropped first
    (e.g. 1M rows over ('data','model')=256 -> ('data',)=16 when 1M % 256).
    jax.jit rejects uneven input shardings, and published configs have
    non-round dims (minicpm3 vocab=73448, DCN d_x0=429).
    """
    if spec is None:
        return P()
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, ent in zip(shape, entries):
        if ent is None:
            out.append(None)
            continue
        axes = list(ent) if isinstance(ent, tuple) else [ent]
        while axes:
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            if dim % prod == 0:
                break
            axes.pop()
        out.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes
                                                      else None))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


# --------------------------------------------------------------------------
# LM
# --------------------------------------------------------------------------

def lm_param_spec(path: str, leaf) -> P:
    """STORAGE sharding: FSDP over ``data`` x TP over ``model``."""
    nd = getattr(leaf, "ndim", 0)
    if "embed" in path and nd == 2:               # (vocab, d)
        return P("model", "data")
    if "['layers']" in path:
        if nd == 4:                               # (L, E, d, f) MoE experts
            return P(None, "model", "data", None)
        if nd == 3:                               # (L, d_in, d_out)
            return P(None, "data", "model")
        return P()                                # (L, d) norms etc.
    return P()


def lm_param_spec_tp(path: str, leaf) -> P:
    """COMPUTE sharding: pure TP — what matmuls should run under.

    Weight contraction dims are NEVER sharded: GSPMD otherwise reshards
    activations to full batch (measured on the 16x16 mesh).  The train step
    all-gathers FSDP storage into this layout per step (weight-gather idiom;
    grad transpose = reduce-scatter back to storage).
    Orientation is path-based: down/out projections contract on dim -2.
    """
    nd = getattr(leaf, "ndim", 0)
    if "embed" in path and nd == 2:               # (vocab, d) vocab-sharded
        return P("model", None)
    if "['layers']" in path:
        down = ("w_down" in path) or ("wo" in path)
        if nd == 4:                               # (L, E, d, f): EP over E
            return P(None, "model", None, None)
        if nd == 3:
            if "router" in path:
                return P()
            return P(None, "model", None) if down else P(None, None, "model")
        return P()
    return P()


def lm_batch_spec(mesh: Mesh) -> P:
    return P(batch_axes(mesh))


def lm_cache_spec(mesh: Mesh, attn_type: str, batch: int, n_kv: int) -> dict:
    """Decode-cache specs. Sequence dim shards over ``model`` (flash-decoding
    style partial softmax); batch over data axes — unless batch < data size,
    then sequence takes every axis."""
    b_axes = batch_axes(mesh)
    b_size = int(np.prod([mesh.shape[a] for a in b_axes]))
    if batch >= b_size:
        seq_axes, bat = ("model",), b_axes
    else:                                          # long_500k: batch=1
        seq_axes, bat = b_axes + ("model",), ()
    if attn_type == "mla":
        return {"c_kv": P(None, bat or None, seq_axes, None),
                "k_rope": P(None, bat or None, seq_axes, None)}
    return {"k": P(None, bat or None, None, seq_axes, None),
            "v": P(None, bat or None, None, seq_axes, None)}


# --------------------------------------------------------------------------
# GNN
# --------------------------------------------------------------------------

def gnn_param_spec(path: str, leaf) -> P:
    return P()                                     # replicated (small)


def gnn_edge_spec(mesh: Mesh) -> P:
    """Edges shard over the whole mesh (graph parallelism)."""
    return P(tuple(mesh.axis_names))


# --------------------------------------------------------------------------
# RecSys
# --------------------------------------------------------------------------

def recsys_param_spec(path: str, leaf) -> P:
    nd = getattr(leaf, "ndim", 0)
    if "tables" in path and nd == 2:               # (V, embed_dim)
        return P("model", None)
    if "mlp_w" in path and nd == 2:                # (d_in, d_h) TP
        return P(None, "model")
    return P()


PARAM_RULES = {"lm": lm_param_spec, "gnn": gnn_param_spec,
               "recsys": recsys_param_spec}


# --------------------------------------------------------------------------
# tree helpers
# --------------------------------------------------------------------------

def tree_shardings(tree, mesh: Mesh, rule):
    import jax
    flat, tdef = jax.tree_util.tree_flatten_with_path(tree)
    out = [NamedSharding(mesh, sanitize_spec(
        rule(jax.tree_util.keystr(p), l), l.shape, mesh))
           for p, l in flat]
    return jax.tree_util.tree_unflatten(tdef, out)


def opt_state_shardings(params_sharding, mesh: Mesh):
    """Optimizer state mirrors params; the step counter is replicated."""
    import jax
    return {"mu": params_sharding,
            "nu": params_sharding,
            "step": NamedSharding(mesh, P())}
