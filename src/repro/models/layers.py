"""Transformer building blocks: RMSNorm, RoPE (on-the-fly), GQA attention with
optional qk-norm, chunked (blockwise-softmax) attention, SwiGLU, embedding and
vocab-sharded-safe cross entropy.

Pure-functional: params are nested dicts of jnp arrays; init fns take a
jax.random key.  Logical sharding axes are attached by the launcher
(launch/sharding.py) via param-path rules, not here.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _init_dense(key, d_in, d_out, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else (1.0 / np.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rmsnorm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * params["scale"]).astype(dt)


# --------------------------------------------------------------------------
# RoPE — computed on the fly from position ids (no precomputed table; long
# contexts would otherwise hold a (max_pos, d) cos/sin buffer in HBM)
# --------------------------------------------------------------------------

def rope(x, positions, theta: float = 10000.0):
    """x: (..., L, D) with D even; positions: (..., L) int32."""
    D = x.shape[-1]
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., L, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention cores
# --------------------------------------------------------------------------

def chunked_attention(q, k, v, *, causal: bool, q_offset=0,
                      chunk_q: int = 1024, chunk_k: int = 1024,
                      repeat_kv: bool = True, flash_bwd: bool = False):
    """Memory-efficient blockwise-softmax attention (pure jnp, autodiff-safe).

    q: (B, Hq, Lq, D); k, v: (B, Hkv, Lk, D).  q_offset: absolute position of
    q[...,0] minus that of k[...,0] (decode: Lk - Lq).  Scores materialize
    only per (chunk_q x chunk_k) tile -> O(L) memory.

    GQA handling: with ``repeat_kv`` (default) K/V are repeated to Hq heads
    so EVERY tensor keeps a single head axis — under tensor parallelism the
    head axis then shards cleanly even when Hkv < mesh model size; the
    grouped (B, Hkv, G, ...) form forces GSPMD into involuntary full
    rematerialization (measured: ~50x collective-bytes blowup on the 16x16
    mesh).  repeat_kv=False keeps the memory-optimal grouped form for
    single-device runs.
    """
    B, Hq, Lq, D = q.shape
    _, Hkv, Lk, _ = k.shape
    scale = 1.0 / np.sqrt(D)
    if repeat_kv and Hkv != Hq:
        k = jnp.repeat(k, Hq // Hkv, axis=1)
        v = jnp.repeat(v, Hq // Hkv, axis=1)
        Hkv = Hq
    G = Hq // Hkv
    cq = min(chunk_q, Lq)
    ck = min(chunk_k, Lk)
    nq, nk = -(-Lq // cq), -(-Lk // ck)
    Lq_p, Lk_p = nq * cq, nk * ck
    if flash_bwd and Hkv == Hq and Lq_p == Lq and Lk_p == Lk:
        # custom-VJP path: O(L) residuals, FA-2 backward schedule
        fa = _make_flash_attention(causal, int(q_offset), cq, ck)
        return fa(q, k, v)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, Lq_p - Lq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Lk_p - Lk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Lk_p - Lk), (0, 0)))
    qp = qp.reshape(B, Hkv, G, nq, cq, D)
    kp = kp.reshape(B, Hkv, nk, ck, D)
    vp = vp.reshape(B, Hkv, nk, ck, D)

    def q_block(carry_qi, qb):
        # qb: (B, Hkv, G, cq, D).  Loop indices (qi, kj) ride the CARRY, not
        # scan xs: as xs-arrays XLA hoists the per-tile causal masks out of
        # the loop into an (nq x nk x B x cq x ck) stack (measured 268MB/layer
        # on the 16x16 mesh); carried scalars cannot be precomputed.
        def kv_step(carry, inputs):
            acc, m, l, kj = carry
            kb, vb = inputs
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            rows = carry_qi * cq + jnp.arange(cq)
            cols = kj * ck + jnp.arange(ck)
            ok = cols[None, :] < Lk
            if causal:
                ok = ok & (cols[None, :] <= rows[:, None] + q_offset)
            s = jnp.where(ok[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (acc, m_new, l_new, kj + 1), None

        acc0 = jnp.zeros((B, Hkv, G, cq, D), jnp.float32)
        m0 = jnp.full((B, Hkv, G, cq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, cq), jnp.float32)
        ks = (jnp.moveaxis(kp, 2, 0), jnp.moveaxis(vp, 2, 0))
        (acc, m, l, _), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0, jnp.int32(0)), ks)
        return acc / jnp.maximum(l, 1e-30)[..., None]

    def q_step(carry, qb):
        qi = carry
        return qi + 1, q_block(qi, qb)

    _, outs = jax.lax.scan(q_step, jnp.int32(0), jnp.moveaxis(qp, 3, 0))
    out = jnp.moveaxis(outs, 0, 3).reshape(B, Hq, Lq_p, D)[:, :, :Lq]
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# chunked attention with FLASH BACKWARD (custom VJP)
#
# Plain autodiff through the blockwise-softmax scan saves the probability
# tiles of EVERY (q-block, kv-block) pair — an O(L^2) residual stack that
# measured 17GB/layer/device on the qwen3-32b train_4k cell.  The custom
# VJP saves only (q, k, v, out, lse) = O(L) and recomputes tiles inside the
# backward loops (FlashAttention-2 schedule): pass 1 accumulates dQ over kv
# blocks, pass 2 accumulates dK/dV over q blocks.
# --------------------------------------------------------------------------

import functools as _functools


def _fa_fwd_chunked(q, k, v, causal, q_offset, cq, ck, scale):
    """Forward chunked pass returning (out, lse); all heads = Hq."""
    B, H, Lq, D = q.shape
    Lk = k.shape[2]
    nq, nk = Lq // cq, Lk // ck
    qp = q.reshape(B, H, nq, cq, D)
    kp = k.reshape(B, H, nk, ck, D)
    vp = v.reshape(B, H, nk, ck, D)

    def q_step(qi, qb):
        def kv_step(carry, inputs):
            acc, m, l, kj = carry
            kb, vb = inputs
            s = jnp.einsum("bhqd,bhkd->bhqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                rows = qi * cq + jnp.arange(cq)
                cols = kj * ck + jnp.arange(ck)
                ok = cols[None, :] <= rows[:, None] + q_offset
                s = jnp.where(ok[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (acc, m_new, l_new, kj + 1), None

        acc0 = jnp.zeros((B, H, cq, D), jnp.float32)
        m0 = jnp.full((B, H, cq), -1e30, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        ks = (jnp.moveaxis(kp, 2, 0), jnp.moveaxis(vp, 2, 0))
        (acc, m, l, _), _ = jax.lax.scan(kv_step, (acc0, m0, l0,
                                                   jnp.int32(0)), ks)
        l = jnp.maximum(l, 1e-30)
        return acc / l[..., None], m + jnp.log(l)

    def q_scan(carry, qb):
        qi = carry
        o, lse = q_step(qi, qb)
        return qi + 1, (o, lse)

    _, (outs, lses) = jax.lax.scan(q_scan, jnp.int32(0),
                                   jnp.moveaxis(qp, 2, 0))
    out = jnp.moveaxis(outs, 0, 2).reshape(B, H, Lq, D)
    lse = jnp.moveaxis(lses, 0, 2).reshape(B, H, Lq)
    return out.astype(q.dtype), lse


@_functools.lru_cache(maxsize=None)
def _make_flash_attention(causal: bool, q_offset: int, cq: int, ck: int):
    @jax.custom_vjp
    def fa(q, k, v):
        scale = 1.0 / np.sqrt(q.shape[-1])
        out, _ = _fa_fwd_chunked(q, k, v, causal, q_offset, cq, ck, scale)
        return out

    def fa_fwd(q, k, v):
        scale = 1.0 / np.sqrt(q.shape[-1])
        out, lse = _fa_fwd_chunked(q, k, v, causal, q_offset, cq, ck, scale)
        return out, (q, k, v, out, lse)

    def fa_bwd(res, do):
        q, k, v, out, lse = res
        B, H, Lq, D = q.shape
        Lk = k.shape[2]
        scale = 1.0 / np.sqrt(D)
        nq, nk = Lq // cq, Lk // ck
        qp = jnp.moveaxis(q.reshape(B, H, nq, cq, D), 2, 0)
        kp = jnp.moveaxis(k.reshape(B, H, nk, ck, D), 2, 0)
        vp = jnp.moveaxis(v.reshape(B, H, nk, ck, D), 2, 0)
        dop = jnp.moveaxis(do.reshape(B, H, nq, cq, D), 2, 0)
        lsep = jnp.moveaxis(lse.reshape(B, H, nq, cq), 2, 0)
        Drow = (do.astype(jnp.float32) * out.astype(jnp.float32)).sum(-1)
        Dp = jnp.moveaxis(Drow.reshape(B, H, nq, cq), 2, 0)

        def tile(qi, kj, qb, kb, lse_b):
            s = jnp.einsum("bhqd,bhkd->bhqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                rows = qi * cq + jnp.arange(cq)
                cols = kj * ck + jnp.arange(ck)
                ok = cols[None, :] <= rows[:, None] + q_offset
                s = jnp.where(ok[None, None], s, -1e30)
            return jnp.exp(s - lse_b[..., None])        # (B,H,cq,ck)

        # pass 1: dQ, streaming over kv blocks per q block
        def dq_qstep(qi, inputs):
            qb, dob, lse_b, D_b = inputs

            def kv_step(carry, kv):
                dq, kj = carry
                kb, vb = kv
                p = tile(qi, kj, qb, kb, lse_b)
                dp = jnp.einsum("bhqd,bhkd->bhqk", dob, vb,
                                preferred_element_type=jnp.float32)
                ds = p * (dp - D_b[..., None])
                dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds.astype(kb.dtype),
                                     kb,
                                     preferred_element_type=jnp.float32)
                return (dq, kj + 1), None

            dq0 = jnp.zeros((B, H, cq, D), jnp.float32)
            (dq, _), _ = jax.lax.scan(kv_step, (dq0, jnp.int32(0)), (kp, vp))
            return dq * scale

        def dq_scan(carry, inputs):
            qi = carry
            return qi + 1, dq_qstep(qi, inputs)

        _, dqs = jax.lax.scan(dq_scan, jnp.int32(0), (qp, dop, lsep, Dp))
        dq = jnp.moveaxis(dqs, 0, 2).reshape(B, H, Lq, D).astype(q.dtype)

        # pass 2: dK/dV, streaming over q blocks per kv block
        def dkv_kstep(kj, kb, vb):
            def q_step(carry, inputs):
                dk, dv, qi = carry
                qb, dob, lse_b, D_b = inputs
                p = tile(qi, kj, qb, kb, lse_b)
                dv = dv + jnp.einsum("bhqk,bhqd->bhkd", p.astype(dob.dtype),
                                     dob,
                                     preferred_element_type=jnp.float32)
                dp = jnp.einsum("bhqd,bhkd->bhqk", dob, vb,
                                preferred_element_type=jnp.float32)
                ds = p * (dp - D_b[..., None])
                dk = dk + jnp.einsum("bhqk,bhqd->bhkd", ds.astype(qb.dtype),
                                     qb,
                                     preferred_element_type=jnp.float32)
                return (dk, dv, qi + 1), None

            dk0 = jnp.zeros((B, H, ck, D), jnp.float32)
            dv0 = jnp.zeros((B, H, ck, D), jnp.float32)
            (dk, dv, _), _ = jax.lax.scan(q_step, (dk0, dv0, jnp.int32(0)),
                                          (qp, dop, lsep, Dp))
            return dk * scale, dv

        def dkv_scan(carry, kv):
            kj = carry
            kb, vb = kv
            dk, dv = dkv_kstep(kj, kb, vb)
            return kj + 1, (dk, dv)

        _, (dks, dvs) = jax.lax.scan(dkv_scan, jnp.int32(0), (kp, vp))
        dk = jnp.moveaxis(dks, 0, 2).reshape(B, H, Lk, D).astype(k.dtype)
        dv = jnp.moveaxis(dvs, 0, 2).reshape(B, H, Lk, D).astype(v.dtype)
        return dq, dk, dv

    fa.defvjp(fa_fwd, fa_bwd)
    return fa


def decode_attention(q, k, v, length=None, repeat_kv: bool = True,
                     seq_axis=None, extra_slot: bool = True):
    """Single-token decode: q (B, Hq, 1, D) vs cache k,v (B, Hkv, S, D).

    Plain softmax over the cache — O(S) memory; with the cache sequence dim
    sharded, GSPMD turns the max/sum reductions into the flash-decoding
    partial-softmax collectives.  ``length`` (B,) masks cache slots >= length
    (fixed-capacity caches).

    GQA: like chunked_attention, K/V are repeated to Hq on the (replicated)
    head dim by default — the grouped (B, Hkv, G, ...) reshape cannot be
    sharded when Hkv < model-axis size and forces a full per-layer cache
    reshard (the 'involuntary full rematerialization' SPMD path).
    """
    B, Hq, _, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    scale = 1.0 / np.sqrt(D)
    if isinstance(seq_axis, str) and "," in seq_axis:
        seq_axis = tuple(seq_axis.split(","))
    if seq_axis is not None:
        # flash-decoding schedule, forced: replicate the (tiny) q so the
        # grouped (B, Hkv, G) reshape carries no sharding at all, and keep
        # the (huge) cache sequence-sharded — GSPMD otherwise all-gathers
        # or reshards the cache per layer to match q's head sharding.
        from jax.sharding import PartitionSpec as P
        q = jax.lax.with_sharding_constraint(q, P())
    elif repeat_kv and Hkv != Hq:
        k = jnp.repeat(k, Hq // Hkv, axis=1)
        v = jnp.repeat(v, Hq // Hkv, axis=1)
        Hkv = Hq
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if seq_axis is not None:
        # pin the scores to sequence sharding: the SPMD solver otherwise
        # picks (head x Dh) contraction sharding for the QK einsum, which
        # drags the cache into an involuntary full reshard
        from jax.sharding import PartitionSpec as P
        s = jax.lax.with_sharding_constraint(
            s, P(None, None, None, seq_axis))
    if length is not None:
        idx = jnp.arange(S)[None, None, None, :]
        ln = length[:, None, None, None]
        # slots < length are valid; with extra_slot the appended (concat)
        # current-token slot at S-1 is too.  The write-then-attend decode
        # path passes extra_slot=False with length already incremented —
        # the cache keeps its power-of-two S and stays evenly sharded
        # (a concat to S+1 is unshardable: full cache all-gather).
        mask = ((idx < ln) | (idx == S - 1)) if extra_slot else (idx < ln)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bhsd->bhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Hq, 1, D).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA attention layer (qwen3 / phi / qwen2-moe style) with optional qk-norm
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    rope_theta: float = 10000.0


def gqa_init(key, cfg: AttnConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": _init_dense(ks[0], d, H * Dh, dtype),
        "wk": _init_dense(ks[1], d, Hkv * Dh, dtype),
        "wv": _init_dense(ks[2], d, Hkv * Dh, dtype),
        "wo": _init_dense(ks[3], H * Dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(Dh)
        p["k_norm"] = rmsnorm_init(Dh)
    return p


def gqa_project_qkv(params, cfg: AttnConfig, x, positions):
    """x: (B, L, d) -> q (B, H, L, Dh), k/v (B, Hkv, L, Dh), roped."""
    B, L, _ = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, L, H, Dh)
    k = (x @ params["wk"]).reshape(B, L, Hkv, Dh)
    v = (x @ params["wv"]).reshape(B, L, Hkv, Dh)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    q = jnp.moveaxis(q, 1, 2)
    k = jnp.moveaxis(k, 1, 2)
    v = jnp.moveaxis(v, 1, 2)
    q = rope(q, positions[:, None, :], cfg.rope_theta)
    k = rope(k, positions[:, None, :], cfg.rope_theta)
    return q, k, v


def gqa_attend(params, cfg: AttnConfig, x, positions, *, causal=True,
               kv_cache=None, cache_length=None, chunk_q=1024, chunk_k=1024,
               flash_bwd=False, decode_seq_axis=None):
    """Returns (out (B, L, d), new_kv) — new_kv is (k, v) to append.

    kv_cache: fixed-capacity (k, v) of shape (B, Hkv, S, Dh); cache_length
    (B,) marks valid entries.  The current step's k/v are appended virtually
    (concat) so the token attends to itself without a prior cache write.
    """
    B, L, _ = x.shape
    q, k, v = gqa_project_qkv(params, cfg, x, positions)
    if kv_cache is not None:
        ck, cv = kv_cache            # (B, Hkv, S, Dh)
        S = ck.shape[2]
        if decode_seq_axis is not None:
            # replicate the one-token k/v BEFORE concat with the
            # sequence-sharded cache: concat of mismatched shardings makes
            # GSPMD reshard the whole cache (involuntary full remat).
            from jax.sharding import PartitionSpec as P
            k = jax.lax.with_sharding_constraint(k, P())
            v = jax.lax.with_sharding_constraint(v, P())
        k_full = jnp.concatenate([ck, k], axis=2)
        v_full = jnp.concatenate([cv, v], axis=2)
        if L == 1:
            eff_len = (cache_length if cache_length is not None
                       else jnp.full((B,), S, jnp.int32))
            # decode_attention treats the final (appended) slot as always valid
            o = decode_attention(q, k_full, v_full, length=eff_len,
                                 seq_axis=decode_seq_axis)
        else:
            o = chunked_attention(q, k_full, v_full, causal=causal,
                                  q_offset=S, chunk_q=chunk_q,
                                  chunk_k=chunk_k, flash_bwd=flash_bwd)
    else:
        o = chunked_attention(q, k, v, causal=causal, q_offset=0,
                              chunk_q=chunk_q, chunk_k=chunk_k,
                              flash_bwd=flash_bwd)
    o = jnp.moveaxis(o, 1, 2).reshape(B, L, cfg.n_heads * cfg.head_dim)
    return o @ params["wo"], (k, v)


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------

def swiglu_init(key, d_model, d_ff, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _init_dense(ks[0], d_model, d_ff, dtype),
        "w_up": _init_dense(ks[1], d_model, d_ff, dtype),
        "w_down": _init_dense(ks[2], d_ff, d_model, dtype),
    }


def swiglu(params, x):
    return (jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])) \
        @ params["w_down"]


# --------------------------------------------------------------------------
# embedding + loss
# --------------------------------------------------------------------------

def embedding_init(key, vocab, d_model, dtype=jnp.bfloat16):
    return {"table": (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)}


def embed(params, tokens):
    return params["table"][tokens]


def unembed(params, x):
    """Tied unembedding: (B, L, d) @ (d, vocab)."""
    return x @ params["table"].T.astype(x.dtype)


def cross_entropy(logits, labels, ignore_id: int = -1):
    """Mean token NLL; safe when the vocab axis is sharded (logsumexp's
    max/sum reduce across shards via GSPMD collectives)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].clip(0), axis=-1)[..., 0]
    mask = labels != ignore_id
    nll = (lse - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)
