"""DCN-v2 (arXiv:2008.13535): deep & cross network for CTR / ranking.

Substrate built from scratch per the assignment notes: JAX has no native
EmbeddingBag, so multi-hot sparse fields are looked up with ``jnp.take`` and
reduced with ``jax.ops.segment_sum``-equivalent masked sums — the
EmbeddingBag(sum/mean) contract.  Embedding tables are the hot path: rows are
sharded over the ``model`` mesh axis by the launcher, so the lookup lowers to
GSPMD gather + all-to-all (the TPU analogue of FBGEMM's TBE kernel).

Three entry points mirror the assigned shapes:
  ctr_loss(params, cfg, batch)         train_batch / serve shapes (BCE)
  predict(params, cfg, batch)          serve_p99 / serve_bulk scoring
  retrieval_scores(params, cfg, ...)   1 query vs n_candidates (two-tower dot)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _init_dense


@dataclasses.dataclass(frozen=True)
class DCNv2Config:
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 16
    vocab_sizes: tuple = ()            # per-field rows; default 1e6 each
    n_cross_layers: int = 3
    mlp_dims: tuple = (1024, 1024, 512)
    cross_rank: int = 0                # 0 = full-rank W (paper default DCN-v2)
    max_hots: int = 1                  # multi-hot width per sparse field
    structure: str = "stacked"         # stacked | parallel (paper fig.2)

    @property
    def vocabs(self) -> tuple:
        return self.vocab_sizes or tuple([1_000_000] * self.n_sparse)

    @property
    def d_x0(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim


def dcnv2_init(key, cfg: DCNv2Config, dtype=jnp.float32):
    ks = jax.random.split(key, 4 + cfg.n_sparse + cfg.n_cross_layers
                          + len(cfg.mlp_dims))
    d = cfg.d_x0
    p = {
        # one table per sparse field (row counts differ -> list, not stack)
        "tables": [
            (jax.random.normal(ks[i], (v, cfg.embed_dim)) * 0.01).astype(dtype)
            for i, v in enumerate(cfg.vocabs)
        ],
        "cross": [],
        "mlp_w": [], "mlp_b": [],
    }
    base = cfg.n_sparse
    for i in range(cfg.n_cross_layers):
        k = ks[base + i]
        if cfg.cross_rank:
            k1, k2 = jax.random.split(k)
            p["cross"].append({
                "u": _init_dense(k1, d, cfg.cross_rank, dtype),
                "v": _init_dense(k2, cfg.cross_rank, d, dtype),
                "b": jnp.zeros((d,), dtype)})
        else:
            p["cross"].append({"w": _init_dense(k, d, d, dtype),
                               "b": jnp.zeros((d,), dtype)})
    base += cfg.n_cross_layers
    d_in = d
    for i, h in enumerate(cfg.mlp_dims):
        p["mlp_w"].append(_init_dense(ks[base + i], d_in, h, dtype))
        p["mlp_b"].append(jnp.zeros((h,), dtype))
        d_in = h
    d_logit = (cfg.mlp_dims[-1] + d if cfg.structure == "parallel"
               else cfg.mlp_dims[-1])
    p["w_logit"] = _init_dense(ks[base + len(cfg.mlp_dims)], d_logit, 1, dtype)
    p["b_logit"] = jnp.zeros((1,), dtype)
    return p


# --------------------------------------------------------------------------
# EmbeddingBag: take + masked segment reduction (JAX-native construction)
# --------------------------------------------------------------------------

def embedding_bag(table, idx, mode: str = "sum"):
    """table: (V, D); idx: (B, H) int32, -1 padded -> (B, D).

    The per-field bag: gather all H hot rows, mask pads, reduce.  For H == 1
    this degenerates to a plain row gather (no reduction lowered).
    """
    V = table.shape[0]
    if idx.ndim == 1:
        idx = idx[:, None]
    mask = (idx >= 0)
    rows = jnp.take(table, jnp.clip(idx, 0, V - 1), axis=0)     # (B, H, D)
    rows = rows * mask[..., None].astype(rows.dtype)
    out = rows.sum(axis=1)
    if mode == "mean":
        out = out / jnp.maximum(mask.sum(axis=1, keepdims=True), 1)
    return out


def build_x0(params, cfg: DCNv2Config, dense, sparse_idx):
    """dense: (B, n_dense) float; sparse_idx: (B, n_sparse[, max_hots]) int."""
    if sparse_idx.ndim == 2:
        sparse_idx = sparse_idx[..., None]
    embs = [embedding_bag(params["tables"][f], sparse_idx[:, f])
            for f in range(cfg.n_sparse)]
    return jnp.concatenate([dense] + embs, axis=-1)             # (B, d_x0)


# --------------------------------------------------------------------------
# cross network + deep tower
# --------------------------------------------------------------------------

def cross_layer(lp, x0, x):
    if "u" in lp:                                   # low-rank DCN-v2 variant
        wx = (x @ lp["u"]) @ lp["v"]
    else:
        wx = x @ lp["w"]
    return x0 * (wx + lp["b"]) + x


def dcnv2_forward(params, cfg: DCNv2Config, dense, sparse_idx):
    x0 = build_x0(params, cfg, dense, sparse_idx)
    x = x0
    for lp in params["cross"]:
        x = cross_layer(lp, x0, x)
    h = x
    for w, b in zip(params["mlp_w"], params["mlp_b"]):
        h = jax.nn.relu(h @ w + b)
    if cfg.structure == "parallel":
        h = jnp.concatenate([h, x], axis=-1)
    return (h @ params["w_logit"] + params["b_logit"])[..., 0]  # (B,)


def predict(params, cfg: DCNv2Config, batch):
    return jax.nn.sigmoid(dcnv2_forward(params, cfg, batch["dense"],
                                        batch["sparse"]))


def ctr_loss(params, cfg: DCNv2Config, batch):
    """Binary cross entropy on click labels (B,)."""
    logits = dcnv2_forward(params, cfg, batch["dense"], batch["sparse"])
    y = batch["labels"].astype(jnp.float32)
    logits = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


# --------------------------------------------------------------------------
# retrieval: 1 query vs n_candidates (two-tower reuse of the same tables)
# --------------------------------------------------------------------------

def retrieval_scores(params, cfg: DCNv2Config, query_dense, query_sparse,
                     cand_emb, top_k: int = 100):
    """Score one query against a candidate matrix.

    query_dense: (1, n_dense); query_sparse: (1, n_sparse[, H]);
    cand_emb: (n_cand, d_q) candidate-tower embeddings (precomputed offline).
    Returns (scores (n_cand,), top-k values, top-k indices) — batched dot,
    never a loop; with candidates sharded over the mesh, GSPMD runs the
    partial top-k per shard and merges.
    """
    x0 = build_x0(params, cfg, query_dense, query_sparse)
    h = x0
    for w, b in zip(params["mlp_w"], params["mlp_b"]):
        h = jax.nn.relu(h @ w + b)                              # (1, d_q)
    q = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)
    scores = (cand_emb @ q[0]).astype(jnp.float32)              # (n_cand,)
    top_v, top_i = jax.lax.top_k(scores, top_k)
    return scores, top_v, top_i


def make_candidate_tower(params, cfg: DCNv2Config, dense, sparse_idx):
    """Offline candidate embeddings through the same deep tower."""
    x0 = build_x0(params, cfg, dense, sparse_idx)
    h = x0
    for w, b in zip(params["mlp_w"], params["mlp_b"]):
        h = jax.nn.relu(h @ w + b)
    return h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)


def n_params(cfg: DCNv2Config) -> int:
    d = cfg.d_x0
    emb = sum(v * cfg.embed_dim for v in cfg.vocabs)
    cross = cfg.n_cross_layers * (
        (2 * d * cfg.cross_rank if cfg.cross_rank else d * d) + d)
    mlp, d_in = 0, d
    for h in cfg.mlp_dims:
        mlp += d_in * h + h
        d_in = h
    return emb + cross + mlp + d_in + 1
