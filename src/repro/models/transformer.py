"""Config-driven decoder-only LM covering the five assigned architectures:
dense GQA (+ optional qk-norm), MLA, and MoE (+ shared experts) variants.

Layers are stacked (leading ``n_layers`` axis) and applied with
``jax.lax.scan`` so 64-layer models compile as one layer body; activation
rematerialization is a config flag.  Three entry points:

  train_step_loss(params, batch)                -> scalar loss
  prefill(params, tokens)                       -> (logits_last, caches)
  decode_step(params, token, caches, length)    -> (logits, updated caches)

Caches are fixed-capacity; decode writes the step's K/V (or MLA latents) at
position ``length``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    attn_type: str = "gqa"              # gqa | mla
    qk_norm: bool = False
    rope_theta: float = 10000.0
    moe: Optional[MOE.MoEConfig] = None
    mla: Optional[MLA.MLAConfig] = None
    dtype: str = "bfloat16"
    remat: bool = True
    chunk_q: int = 1024
    chunk_k: int = 1024
    # perf knobs (EXPERIMENTS.md §Perf):
    # wire_barrier: optimization_barrier after each block's output dot so
    # XLA cannot hoist the f32 convert above the TP partial-sum all-reduce
    # (keeps the wire at bf16 — measured 2x collective-bytes otherwise).
    wire_barrier: bool = False
    # act_shard: Megatron-style sequence parallelism for the residual
    # stream — layer-boundary activations (and hence the remat-saved
    # residuals) are sharded over the model axis on the sequence dim;
    # GSPMD turns the TP all-reduce into reduce-scatter + all-gather.
    act_shard: bool = False
    act_batch_axes: tuple = ()          # set by the launcher per mesh
    # flash_bwd: custom-VJP chunked attention (FA-2 backward schedule) —
    # O(L) residuals instead of autodiff's O(L^2) tile stacks.
    flash_bwd: bool = False
    # decode_seq_axis: force the flash-decoding schedule (q replicated,
    # cache sequence-sharded over this mesh axis) in decode attention.
    decode_seq_axis: Optional[str] = None
    # decode_write_then_attend: write the step's K/V into the fixed cache
    # BEFORE attention (no concat to S+1 -> cache stays evenly sharded).
    decode_write_then_attend: bool = False
    # fsdp_inner: all-gather FSDP-sharded layer weights INSIDE the layer
    # scan body (per layer) instead of the whole stack at step start —
    # peak weight memory drops n_layers-fold; grad transpose becomes a
    # per-layer reduce-scatter.  Requires a mesh context (launcher sets
    # model_axis_size for the divisibility guard).
    fsdp_inner: bool = False
    model_axis_size: int = 0

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def attn_cfg(self) -> L.AttnConfig:
        return L.AttnConfig(self.d_model, self.n_heads, self.n_kv_heads,
                            self.head_dim, self.qk_norm, self.rope_theta)

    def n_params(self) -> int:
        """Total parameter count (for MODEL_FLOPS bookkeeping)."""
        d, H, Hkv, Dh = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        if self.attn_type == "mla":
            m = self.mla
            attn = (d * m.q_lora_rank
                    + m.q_lora_rank * H * (m.qk_nope_dim + m.qk_rope_dim)
                    + d * m.kv_lora_rank + d * m.qk_rope_dim
                    + m.kv_lora_rank * H * (m.qk_nope_dim + m.v_head_dim)
                    + H * m.v_head_dim * d)
        else:
            attn = d * H * Dh + 2 * d * Hkv * Dh + H * Dh * d
        if self.moe:
            E = self.moe.n_experts
            ffn = E * 3 * d * self.moe.d_ff_expert + d * E
            if self.moe.n_shared:
                d_sh = self.moe.d_ff_shared or self.moe.d_ff_expert * self.moe.n_shared
                ffn += 3 * d * d_sh
        else:
            ffn = 3 * d * self.d_ff
        return self.n_layers * (attn + ffn + 2 * d) + self.vocab * d + d

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.moe:
            return self.n_params()
        d = self.d_model
        full = self.n_params()
        E, k = self.moe.n_experts, self.moe.top_k
        expert_p = 3 * d * self.moe.d_ff_expert
        return full - self.n_layers * (E - k) * expert_p


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_params(key, cfg: TransformerConfig):
    dt = cfg.jdtype
    k_emb, k_layers, k_final = jax.random.split(key, 3)

    def layer_init(k):
        ka, kf = jax.random.split(k)
        p = {"ln1": L.rmsnorm_init(cfg.d_model),
             "ln2": L.rmsnorm_init(cfg.d_model)}
        if cfg.attn_type == "mla":
            p["attn"] = MLA.mla_init(ka, cfg.mla, dt)
        else:
            p["attn"] = L.gqa_init(ka, cfg.attn_cfg(), dt)
        if cfg.moe:
            p["ffn"] = MOE.moe_init(kf, cfg.d_model, cfg.moe, dt)
        else:
            p["ffn"] = L.swiglu_init(kf, cfg.d_model, cfg.d_ff, dt)
        return p

    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(layer_init)(layer_keys)
    return {
        "embed": L.embedding_init(k_emb, cfg.vocab, cfg.d_model, dt),
        "layers": stacked,
        "final_norm": L.rmsnorm_init(cfg.d_model),
    }


# --------------------------------------------------------------------------
# forward (scan over stacked layers)
# --------------------------------------------------------------------------

def _barrier(cfg, h):
    return jax.lax.optimization_barrier(h) if cfg.wire_barrier else h


def _shard_act(cfg: TransformerConfig, x):
    """Sequence-parallel residual stream (requires a mesh context)."""
    if not cfg.act_shard:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(cfg.act_batch_axes or None, "model", None)
    return jax.lax.with_sharding_constraint(x, spec)


def _constrain_layer_tp(cfg: TransformerConfig, lp):
    """Per-layer FSDP gather: force each (sliced) layer param to its pure-TP
    compute layout; the data-axis dim all-gathers here, per layer."""
    if not cfg.fsdp_inner:
        return lp
    from jax.sharding import PartitionSpec as P
    ms = cfg.model_axis_size or 1

    def one(path, leaf):
        ps = jax.tree_util.keystr(path)
        nd = leaf.ndim
        down = ("w_down" in ps) or ("wo" in ps)
        if nd == 3 and leaf.shape[0] % ms == 0:        # (E, d, f) experts
            spec = P("model", None, None)
        elif nd == 2 and "router" not in ps:
            if down and leaf.shape[0] % ms == 0:
                spec = P("model", None)
            elif not down and leaf.shape[1] % ms == 0:
                spec = P(None, "model")
            else:
                spec = P()
        else:
            spec = P()
        return jax.lax.with_sharding_constraint(leaf, spec)

    return jax.tree_util.tree_map_with_path(one, lp)


def _layer_fwd(cfg: TransformerConfig, lp, x, positions, aux):
    lp = _constrain_layer_tp(cfg, lp)
    h, _ = (_attend(cfg, lp, L.rmsnorm(lp["ln1"], x), positions))
    x = _shard_act(cfg, x + _barrier(cfg, h))
    if cfg.moe:
        B, Lq, d = x.shape
        y, a = MOE.moe_apply(lp["ffn"], cfg.moe,
                             L.rmsnorm(lp["ln2"], x).reshape(B * Lq, d))
        x = x + _barrier(cfg, y.reshape(B, Lq, d))
        aux = aux + a
    else:
        x = x + _barrier(cfg, L.swiglu(lp["ffn"], L.rmsnorm(lp["ln2"], x)))
    return _shard_act(cfg, x), aux


def _attend(cfg, lp, xn, positions, kv_cache=None, cache_length=None):
    if cfg.attn_type == "mla":
        if kv_cache is not None and xn.shape[1] == 1:
            return MLA.mla_attend_decode(lp["attn"], cfg.mla, xn, positions,
                                         kv_cache, cache_length)
        return MLA.mla_attend_prefill(lp["attn"], cfg.mla, xn, positions,
                                      chunk_q=cfg.chunk_q, chunk_k=cfg.chunk_k,
                                      flash_bwd=cfg.flash_bwd)
    return L.gqa_attend(lp["attn"], cfg.attn_cfg(), xn, positions,
                        kv_cache=kv_cache, cache_length=cache_length,
                        chunk_q=cfg.chunk_q, chunk_k=cfg.chunk_k,
                        flash_bwd=cfg.flash_bwd,
                        decode_seq_axis=cfg.decode_seq_axis)


def forward(params, cfg: TransformerConfig, tokens):
    """tokens (B, L) -> logits (B, L, vocab), aux loss."""
    B, Lq = tokens.shape
    x = L.embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(Lq)[None], (B, Lq))

    def body(carry, lp):
        x, aux = carry
        x, aux = _layer_fwd(cfg, lp, x, positions, aux)
        return (x, aux), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)),
                               params["layers"])
    x = L.rmsnorm(params["final_norm"], x)
    return L.unembed(params["embed"], x), aux


def train_step_loss(params, cfg: TransformerConfig, batch):
    logits, aux = forward(params, cfg, batch["tokens"])
    return L.cross_entropy(logits, batch["labels"]) + aux


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def make_empty_cache(cfg: TransformerConfig, batch: int, max_len: int):
    dt = cfg.jdtype
    if cfg.attn_type == "mla":
        m = cfg.mla
        return {
            "c_kv": jnp.zeros((cfg.n_layers, batch, max_len, m.kv_lora_rank), dt),
            "k_rope": jnp.zeros((cfg.n_layers, batch, max_len, m.qk_rope_dim), dt),
        }
    return {
        "k": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, max_len,
                        cfg.head_dim), dt),
        "v": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads, max_len,
                        cfg.head_dim), dt),
    }


def prefill(params, cfg: TransformerConfig, tokens):
    """tokens (B, L) -> (last-position logits (B, vocab), caches filled to L).

    Caches are returned at exactly length L; the serve loop re-homes them into
    its fixed-capacity buffers.
    """
    B, Lq = tokens.shape
    x = L.embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(Lq)[None], (B, Lq))

    def body(x, lp):
        h, kv = _attend(cfg, lp, L.rmsnorm(lp["ln1"], x), positions)
        x = x + h
        if cfg.moe:
            Bq, Lq2, d = x.shape
            y, _ = MOE.moe_apply(lp["ffn"], cfg.moe,
                                 L.rmsnorm(lp["ln2"], x).reshape(Bq * Lq2, d))
            x = x + y.reshape(Bq, Lq2, d)
        else:
            x = x + L.swiglu(lp["ffn"], L.rmsnorm(lp["ln2"], x))
        return x, kv

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, caches = jax.lax.scan(body_fn, x, params["layers"])
    x = L.rmsnorm(params["final_norm"], x[:, -1:])
    logits = L.unembed(params["embed"], x)[:, 0]
    if cfg.attn_type == "mla":
        cache = {"c_kv": caches[0], "k_rope": caches[1]}
    else:
        cache = {"k": caches[0], "v": caches[1]}
    return logits, cache


def decode_step(params, cfg: TransformerConfig, token, cache, length):
    """token (B,) int32; cache dict of (n_layers, ...); length (B,) current
    valid cache entries. Returns (logits (B, vocab), updated cache)."""
    B = token.shape[0]
    x = L.embed(params["embed"], token[:, None])
    positions = length[:, None]

    cache_keys = list(cache.keys())

    def body_write_then_attend(x, scanned):
        """Sharding-friendly decode: write this step's K/V (or latents)
        into the fixed cache FIRST, then attend over the unmodified-shape
        cache.  A concat to S+1 slots makes S odd and unshardable — GSPMD
        then all-gathers the whole cache per layer (60GB/step measured on
        qwen3-1.7b decode_32k)."""
        lp, layer_cache = scanned
        lp = _constrain_layer_tp(cfg, lp)
        xn = L.rmsnorm(lp["ln1"], x)
        from jax.sharding import PartitionSpec as P
        rep = (lambda t: jax.lax.with_sharding_constraint(t, P())) \
            if cfg.decode_seq_axis is not None else (lambda t: t)
        if cfg.attn_type == "mla":
            c_new, kr_new = MLA.mla_latents(lp["attn"], cfg.mla, xn,
                                            positions)
            c_new = rep(c_new)
            kr_new = rep(kr_new)
            c_kv = _write_at(layer_cache["c_kv"], c_new[:, 0], length, 1)
            k_rope = _write_at(layer_cache["k_rope"], kr_new[:, 0], length, 1)
            h, _ = MLA.mla_attend_decode(
                lp["attn"], cfg.mla, xn, positions, (c_kv, k_rope),
                length + 1, prewritten=True, seq_axis=cfg.decode_seq_axis)
            upd = {"c_kv": c_kv, "k_rope": k_rope}
        else:
            acfg = cfg.attn_cfg()
            q, k, v = L.gqa_project_qkv(lp["attn"], acfg, xn, positions)
            k = rep(k)
            v = rep(v)
            ck = _write_at(layer_cache["k"], k[:, :, 0], length, 2)
            cv = _write_at(layer_cache["v"], v[:, :, 0], length, 2)
            o = L.decode_attention(q, ck, cv, length=length + 1,
                                   seq_axis=cfg.decode_seq_axis,
                                   extra_slot=False)
            o = jnp.moveaxis(o, 1, 2).reshape(
                x.shape[0], 1, acfg.n_heads * acfg.head_dim)
            h = o @ lp["attn"]["wo"]
            upd = {"k": ck, "v": cv}
        x = x + h
        if cfg.moe:
            y, _ = MOE.moe_apply(lp["ffn"], cfg.moe,
                                 L.rmsnorm(lp["ln2"], x).reshape(B, -1))
            x = x + y.reshape(B, 1, -1)
        else:
            x = x + L.swiglu(lp["ffn"], L.rmsnorm(lp["ln2"], x))
        return x, upd

    def body(x, scanned):
        lp, layer_cache = scanned
        if cfg.attn_type == "mla":
            kvc = (layer_cache["c_kv"], layer_cache["k_rope"])
        else:
            kvc = (layer_cache["k"], layer_cache["v"])
        h, new = _attend(cfg, lp, L.rmsnorm(lp["ln1"], x), positions,
                         kv_cache=kvc, cache_length=length)
        x = x + h
        if cfg.moe:
            y, _ = MOE.moe_apply(lp["ffn"], cfg.moe,
                                 L.rmsnorm(lp["ln2"], x).reshape(B, -1))
            x = x + y.reshape(B, 1, -1)
        else:
            x = x + L.swiglu(lp["ffn"], L.rmsnorm(lp["ln2"], x))
        # write this step's kv/latents at position `length` per batch row.
        # The one-token update is REPLICATED first when the cache sequence
        # dim is sharded: its natural (head x Dh) TP sharding would
        # otherwise make GSPMD reshard the entire cache around the write
        # ('involuntary full rematerialization', 60GB/step measured).
        upd = {}
        for key, new_v in zip(cache_keys, new):
            buf = layer_cache[key]
            if cfg.decode_seq_axis is not None:
                from jax.sharding import PartitionSpec as P
                new_v = jax.lax.with_sharding_constraint(new_v, P())
            if cfg.attn_type == "mla":
                # (B, 1, r) -> write at [b, length[b]]
                upd[key] = _write_at(buf, new_v[:, 0], length, axis=1)
            else:
                # (B, Hkv, 1, Dh) -> write at [b, :, length[b]]
                upd[key] = _write_at(buf, new_v[:, :, 0], length, axis=2)
        return x, upd

    body_fn = (body_write_then_attend if cfg.decode_write_then_attend
               else body)
    x, new_cache = jax.lax.scan(body_fn, x, (params["layers"], cache))
    x = L.rmsnorm(params["final_norm"], x)
    logits = L.unembed(params["embed"], x)[:, 0]
    return logits, new_cache


def _write_at(buf, val, length, axis: int):
    """Write val (B, ...) into buf (B, ..., S, ...) at index length[b].

    Implemented as a one-hot mask select rather than a vmapped
    dynamic-update-slice: the batched scatter that vmap produces defeats
    GSPMD's sequence-dim partitioning of the cache (measured: a full f32
    cache all-gather per layer, 60GB/decode-step); the select keeps every
    shard local — each shard only commits the position it owns."""
    S = buf.shape[axis]
    idx = jnp.clip(length, 0, S - 1)
    shape = [1] * buf.ndim
    shape[axis] = S
    pos = jnp.arange(S).reshape(shape)                   # (1,..,S,..,1)
    sel = pos == idx.reshape((-1,) + (1,) * (buf.ndim - 1))
    val = jnp.expand_dims(val, axis)                     # (B, ..., 1, ...)
    return jnp.where(sel, val.astype(buf.dtype), buf)
