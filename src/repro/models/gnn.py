"""GNN architectures: GAT, MeshGraphNet, GatedGCN.

Message passing is built on ``jax.ops.segment_sum/max`` over COO edge lists —
the JAX-native scatter idiom (no sparse formats needed).  Full-graph cells
(cora, ogb_products) use COO; sampled-minibatch cells use the sampler's
per-layer ELL blocks via the same segment ops on flattened (dst, slot) pairs.
Batched small graphs (molecule) are flattened block-diagonally by the data
pipeline, so they are just another COO problem.

Coloring hook (the paper's technique, DESIGN.md §5): ``edge_schedule`` may
carry a coloring-derived edge ordering; aggregation is then performed
color-class by color-class, which makes accumulation order deterministic and
conflict-free — the TPU analogue of the paper's motivating use (safe parallel
execution of irregular updates).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _init_dense


# --------------------------------------------------------------------------
# primitives
# --------------------------------------------------------------------------

def mlp_init(key, dims, dtype=jnp.float32, layernorm=False):
    ks = jax.random.split(key, len(dims) - 1)
    p = {"w": [], "b": []}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        p["w"].append(_init_dense(ks[i], a, b, dtype))
        p["b"].append(jnp.zeros((b,), dtype))
    if layernorm:
        p["ln_scale"] = jnp.ones((dims[-1],), jnp.float32)
        p["ln_bias"] = jnp.zeros((dims[-1],), jnp.float32)
    return p


def mlp_apply(p, x, act=jax.nn.relu):
    n = len(p["w"])
    for i in range(n):
        x = x @ p["w"][i] + p["b"][i]
        if i < n - 1:
            x = act(x)
    if "ln_scale" in p:
        mu = x.mean(-1, keepdims=True)
        var = x.var(-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + 1e-6) * p["ln_scale"] + p["ln_bias"]
    return x


def segment_softmax(scores, seg_ids, n_segments):
    """Softmax over edges grouped by destination (numerically stable)."""
    smax = jax.ops.segment_max(scores, seg_ids, n_segments)
    smax = jnp.where(jnp.isfinite(smax), smax, 0.0)
    e = jnp.exp(scores - smax[seg_ids])
    ssum = jax.ops.segment_sum(e, seg_ids, n_segments)
    return e / jnp.maximum(ssum[seg_ids], 1e-16)


# --------------------------------------------------------------------------
# GAT  (arXiv:1710.10903) — SDDMM-style edge scores + segment softmax
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GATConfig:
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    d_in: int = 1433
    n_classes: int = 7
    final_heads: int = 1          # final layer averages heads


def gat_init(key, cfg: GATConfig):
    ks = jax.random.split(key, cfg.n_layers)
    layers = []
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        H = cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        k1, k2, k3 = jax.random.split(ks[i], 3)
        layers.append({
            "w": _init_dense(k1, d_in, H * d_out),
            "a_src": (jax.random.normal(k2, (H, d_out)) * 0.1),
            "a_dst": (jax.random.normal(k3, (H, d_out)) * 0.1),
        })
        d_in = d_out * (1 if last else H)
    return {"layers": layers}


def gat_apply(params, cfg: GATConfig, feats, src, dst, n_nodes):
    x = feats
    for i, lp in enumerate(params["layers"]):
        last = i == len(params["layers"]) - 1
        H = cfg.n_heads
        d_out = lp["w"].shape[1] // H
        h = (x @ lp["w"]).reshape(-1, H, d_out)
        e = (jax.nn.leaky_relu(
            (h[src] * lp["a_src"]).sum(-1) + (h[dst] * lp["a_dst"]).sum(-1),
            0.2))                                        # (E, H)
        alpha = jax.vmap(lambda s: segment_softmax(s, dst, n_nodes),
                         in_axes=1, out_axes=1)(e)
        msg = h[src] * alpha[..., None]
        agg = jax.ops.segment_sum(msg, dst, n_nodes)      # (N, H, d_out)
        x = agg.mean(1) if last else jax.nn.elu(agg.reshape(n_nodes, H * d_out))
    return x


# --------------------------------------------------------------------------
# MeshGraphNet (arXiv:2010.03409) — encode-process-decode with edge state
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MGNConfig:
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_in: int = 3
    d_edge_in: int = 4
    d_out: int = 3


def _mlp_dims(d_in, d_h, n_hidden):
    return [d_in] + [d_h] * n_hidden + [d_h]


def mgn_init(key, cfg: MGNConfig):
    ks = jax.random.split(key, cfg.n_layers * 2 + 3)
    d = cfg.d_hidden
    p = {
        "node_enc": mlp_init(ks[0], _mlp_dims(cfg.d_in, d, cfg.mlp_layers - 1),
                             layernorm=True),
        "edge_enc": mlp_init(ks[1], _mlp_dims(cfg.d_edge_in, d,
                                              cfg.mlp_layers - 1),
                             layernorm=True),
        "decoder": mlp_init(ks[2], [d] * cfg.mlp_layers + [cfg.d_out]),
        "blocks": [],
    }
    for i in range(cfg.n_layers):
        p["blocks"].append({
            "edge_mlp": mlp_init(ks[3 + 2 * i], _mlp_dims(3 * d, d,
                                                          cfg.mlp_layers - 1),
                                 layernorm=True),
            "node_mlp": mlp_init(ks[4 + 2 * i], _mlp_dims(2 * d, d,
                                                          cfg.mlp_layers - 1),
                                 layernorm=True),
        })
    return p


def mgn_apply(params, cfg: MGNConfig, feats, edge_feats, src, dst, n_nodes):
    h = mlp_apply(params["node_enc"], feats)
    e = mlp_apply(params["edge_enc"], edge_feats)
    for blk in params["blocks"]:
        e = e + mlp_apply(blk["edge_mlp"],
                          jnp.concatenate([e, h[src], h[dst]], -1))
        agg = jax.ops.segment_sum(e, dst, n_nodes)
        h = h + mlp_apply(blk["node_mlp"], jnp.concatenate([h, agg], -1))
    return mlp_apply(params["decoder"], h)


# --------------------------------------------------------------------------
# GatedGCN (arXiv:1711.07553 / benchmarking-gnns 2003.00982)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GatedGCNConfig:
    n_layers: int = 16
    d_hidden: int = 70
    d_in: int = 1433
    d_out: int = 7


def gatedgcn_init(key, cfg: GatedGCNConfig):
    ks = jax.random.split(key, cfg.n_layers + 2)
    d = cfg.d_hidden
    p = {"embed": _init_dense(ks[0], cfg.d_in, d),
         "readout": _init_dense(ks[1], d, cfg.d_out), "blocks": []}
    for i in range(cfg.n_layers):
        k = jax.random.split(ks[2 + i], 5)
        p["blocks"].append({n: _init_dense(k[j], d, d)
                            for j, n in enumerate("ABCDE")})
    return p


def gatedgcn_apply(params, cfg: GatedGCNConfig, feats, src, dst, n_nodes):
    h = feats @ params["embed"]
    e = jnp.zeros((src.shape[0], cfg.d_hidden), h.dtype)
    for blk in params["blocks"]:
        e_new = e + h[src] @ blk["D"] + h[dst] @ blk["E"]
        eta = jax.nn.sigmoid(e_new)
        msg = eta * (h[src] @ blk["B"])
        denom = jax.ops.segment_sum(eta, dst, n_nodes) + 1e-6
        agg = jax.ops.segment_sum(msg, dst, n_nodes) / denom
        h_new = h @ blk["A"] + agg
        h = h + jax.nn.relu(_bn_free_norm(h_new))
        e = e_new
    return h @ params["readout"]


def _bn_free_norm(x):
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6)


# --------------------------------------------------------------------------
# GatedGCN with HALO EXCHANGE (shard_map) — the paper's partition/boundary
# insight applied to full-graph training (EXPERIMENTS.md §Perf cell B).
#
# Replicated-feature GNN training all-reduces a full (N, d) partial sum per
# layer per direction (measured 109 GB wire on gatedgcn x ogb_products).
# With nodes block-partitioned (partition.py) each shard owns its dst
# scatter entirely; only BOUNDARY node features cross shards, via one
# all-gather of (max_b, d) per layer — wire shrinks by the boundary
# fraction, exactly the replicated->halo trade of core/distributed.py.
# --------------------------------------------------------------------------


def gatedgcn_halo_apply(params, cfg, feats_loc, src_loc, dst_loc, boundary,
                        ghost_flat, axis_names, n_shards: int):
    """Per-shard GatedGCN forward (call under shard_map).

    feats_loc: (n_loc, d_in) owned nodes' features
    src_loc:   (E_loc,) local slot [0, n_loc) or ghost slot n_loc+g
    dst_loc:   (E_loc,) local slot (every edge's dst is owned)
    boundary:  (max_b,) local slots this shard must publish (-1 pad)
    ghost_flat:(max_g,) index into the gathered (D*max_b,) boundary payload
    """
    n_loc = feats_loc.shape[0]
    max_b = boundary.shape[0]
    max_g = ghost_flat.shape[0]
    d = cfg.d_hidden

    def exchange(h):
        b_idx = jnp.clip(boundary, 0, n_loc - 1)
        payload = jnp.where((boundary >= 0)[:, None], h[b_idx], 0.0)
        allp = jax.lax.all_gather(payload, axis_names, tiled=True)
        allp = allp.reshape(n_shards * max_b, d)
        g_idx = jnp.clip(ghost_flat, 0, n_shards * max_b - 1)
        ghosts = jnp.where((ghost_flat >= 0)[:, None], allp[g_idx], 0.0)
        return jnp.concatenate([h, ghosts], axis=0)      # (n_loc+max_g, d)

    h = feats_loc @ params["embed"]
    e = jnp.zeros((src_loc.shape[0], d), h.dtype)
    for blk in params["blocks"]:
        tab = exchange(h)                                # 1 collective/layer
        hs, hd = tab[src_loc], h[dst_loc]
        e_new = e + hs @ blk["D"] + hd @ blk["E"]
        eta = jax.nn.sigmoid(e_new)
        msg = eta * (hs @ blk["B"])
        denom = jax.ops.segment_sum(eta, dst_loc, n_loc) + 1e-6
        agg = jax.ops.segment_sum(msg, dst_loc, n_loc) / denom
        h_new = h @ blk["A"] + agg
        h = h + jax.nn.relu(_bn_free_norm(h_new))
        e = e_new
    return h @ params["readout"]


def gatedgcn_halo_loss(params, cfg, batch, axis_names, n_shards: int):
    """Mean node-classification loss over shards (psum-normalized)."""
    logits = gatedgcn_halo_apply(
        params, cfg, batch["feats"], batch["src"], batch["dst"],
        batch["boundary"], batch["ghost_flat"], axis_names, n_shards)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None].clip(0), 1)[:, 0]
    mask = batch["train_mask"]
    s = jax.lax.psum((nll * mask).sum(), axis_names)
    n = jax.lax.psum(mask.sum(), axis_names)
    return s / jnp.maximum(n, 1.0)


# --------------------------------------------------------------------------
# losses (per task kind)
# --------------------------------------------------------------------------

def node_classification_loss(logits, labels, mask=None):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    nll = -jnp.take_along_axis(logp, labels[:, None].clip(0), 1)[:, 0]
    if mask is None:
        mask = labels >= 0
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)


def node_regression_loss(pred, target, mask=None):
    se = ((pred - target) ** 2).sum(-1)
    if mask is not None:
        return (se * mask).sum() / jnp.maximum(mask.sum(), 1)
    return se.mean()


# --------------------------------------------------------------------------
# coloring-scheduled aggregation (the paper's technique plugged into GNNs)
# --------------------------------------------------------------------------

def colored_segment_sum(msg, dst, n_nodes, edge_color, n_colors: int):
    """Aggregate messages color-class by color-class.

    ``edge_color`` comes from coloring the line-graph-lite (edges conflicting
    iff same dst); within a color every dst appears once, so each class is a
    conflict-free scatter — deterministic accumulation order independent of
    edge permutation, the paper's dependency-analysis use-case.
    """
    out = jnp.zeros((n_nodes,) + msg.shape[1:], msg.dtype)

    def body(c, out):
        m = (edge_color == c)[:, None]
        return out + jax.ops.segment_sum(msg * m, dst, n_nodes)

    return jax.lax.fori_loop(0, n_colors, body, out)
