"""Multi-head Latent Attention (MLA, DeepSeek-V2 / MiniCPM3).

Queries and keys/values are low-rank compressed; the decode KV cache stores
only the (kv_lora_rank + qk_rope) latent per token — ~16x smaller than the
equivalent dense GQA cache, which is what makes the long_500k decode cell
cheap.  Decode uses the absorbed formulation (q projected into latent space,
attention runs entirely over the compressed cache); prefill/train materialize
per-head K/V for MXU-friendly blockwise attention.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import (_init_dense, chunked_attention, rmsnorm,
                                 rmsnorm_init, rope)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64
    rope_theta: float = 10000.0


def mla_init(key, cfg: MLAConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 8)
    d, H = cfg.d_model, cfg.n_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "w_dq": _init_dense(ks[0], d, r_q, dtype),
        "q_norm": rmsnorm_init(r_q),
        "w_uq": _init_dense(ks[1], r_q, H * (dn + dr), dtype),
        "w_dkv": _init_dense(ks[2], d, r_kv, dtype),
        "kv_norm": rmsnorm_init(r_kv),
        "w_uk": _init_dense(ks[3], r_kv, H * dn, dtype),
        "w_uv": _init_dense(ks[4], r_kv, H * dv, dtype),
        "w_kr": _init_dense(ks[5], d, dr, dtype),
        "w_o": _init_dense(ks[6], H * dv, d, dtype),
    }


def mla_latents(params, cfg: MLAConfig, x, positions):
    """Compressed KV latents for caching: (c_kv (B,L,r), k_rope (B,L,dr))."""
    c_kv = rmsnorm(params["kv_norm"], x @ params["w_dkv"])
    k_r = rope(x @ params["w_kr"], positions, cfg.rope_theta)
    return c_kv, k_r


def _queries(params, cfg: MLAConfig, x, positions):
    B, L, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    c_q = rmsnorm(params["q_norm"], x @ params["w_dq"])
    q = (c_q @ params["w_uq"]).reshape(B, L, H, dn + dr)
    q_n, q_r = q[..., :dn], q[..., dn:]
    q_r = rope(jnp.moveaxis(q_r, 1, 2), positions[:, None, :], cfg.rope_theta)
    return jnp.moveaxis(q_n, 1, 2), q_r      # (B, H, L, dn), (B, H, L, dr)


def mla_attend_prefill(params, cfg: MLAConfig, x, positions, *, causal=True,
                       chunk_q=1024, chunk_k=1024, flash_bwd=False):
    """Materialized path for train/prefill. Returns (out, (c_kv, k_rope))."""
    B, L, _ = x.shape
    H, dn, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim
    q_n, q_r = _queries(params, cfg, x, positions)
    c_kv, k_r = mla_latents(params, cfg, x, positions)
    k_n = jnp.moveaxis((c_kv @ params["w_uk"]).reshape(B, L, H, dn), 1, 2)
    v = jnp.moveaxis((c_kv @ params["w_uv"]).reshape(B, L, H, dv), 1, 2)
    # concat nope+rope per head; shared k_rope broadcast across heads
    q = jnp.concatenate([q_n, q_r], axis=-1)
    k = jnp.concatenate(
        [k_n, jnp.broadcast_to(k_r[:, None], (B, H, L, cfg.qk_rope_dim))],
        axis=-1)
    # pad v to q/k head_dim so one attention call handles both (slice after)
    o = chunked_attention(q, k, jnp.pad(v, ((0, 0),) * 3 + ((0, q.shape[-1] - dv),)),
                          causal=causal, chunk_q=chunk_q, chunk_k=chunk_k,
                          flash_bwd=flash_bwd)
    o = o[..., :dv]
    o = jnp.moveaxis(o, 1, 2).reshape(B, L, H * dv)
    return o @ params["w_o"], (c_kv, k_r)


def mla_attend_decode(params, cfg: MLAConfig, x, positions, cache, length,
                      prewritten: bool = False, seq_axis=None):
    """Absorbed decode: x (B, 1, d) against latent cache.

    cache: (c_kv (B, S, r), k_rope (B, S, dr)); length: (B,) valid entries.
    Returns (out (B, 1, d), (c_kv_new (B,1,r), k_rope_new (B,1,dr))).

    prewritten=True: the caller already wrote this step's latents into the
    cache (write-then-attend; ``length`` includes them) — no concat, so the
    cache keeps its power-of-two S and stays evenly sequence-sharded.
    """
    B = x.shape[0]
    H, dn, dr, dv, r = (cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                        cfg.v_head_dim, cfg.kv_lora_rank)
    c_cache, kr_cache = cache              # (B, S, r), (B, S, dr)
    S = c_cache.shape[1]
    q_n, q_r = _queries(params, cfg, x, positions)   # (B,H,1,dn),(B,H,1,dr)
    # absorb W_uk into the query: q_c[h] = q_n[h] @ W_uk[h]^T  -> latent space
    w_uk = params["w_uk"].reshape(r, H, dn)
    q_c = jnp.einsum("bhd,rhd->bhr", q_n[:, :, 0], w_uk)       # (B, H, r)
    if prewritten:
        c_new, kr_new = None, None
        c_all, kr_all = c_cache, kr_cache
        S_eff = S
    else:
        # this step's own latent — appended virtually so the token attends
        # to itself without a prior cache write
        c_new, kr_new = mla_latents(params, cfg, x, positions)  # (B,1,r/dr)
        c_all = jnp.concatenate([c_cache, c_new], axis=1)       # (B, S+1, r)
        kr_all = jnp.concatenate([kr_cache, kr_new], axis=1)
        S_eff = S + 1
    if isinstance(seq_axis, str) and "," in seq_axis:
        seq_axis = tuple(seq_axis.split(","))
    if seq_axis is not None:
        from jax.sharding import PartitionSpec as P
        q_c = jax.lax.with_sharding_constraint(q_c, P())
    scale = 1.0 / jnp.sqrt(jnp.float32(dn + dr))
    s = (jnp.einsum("bhr,bsr->bhs", q_c, c_all,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhd,bsd->bhs", q_r[:, :, 0], kr_all,
                      preferred_element_type=jnp.float32)) * scale
    if seq_axis is not None:
        from jax.sharding import PartitionSpec as P
        s = jax.lax.with_sharding_constraint(s, P(None, None, seq_axis))
    idx = jnp.arange(S_eff)[None, None, :]
    mask = (idx < length[:, None, None])
    if not prewritten:
        mask = mask | (idx == S)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhs,bsr->bhr", p.astype(c_all.dtype), c_all,
                     preferred_element_type=jnp.float32)       # (B, H, r)
    w_uv = params["w_uv"].reshape(r, H, dv)
    o = jnp.einsum("bhr,rhd->bhd", o_c.astype(x.dtype), w_uv)
    o = o.reshape(B, 1, H * dv)
    return o @ params["w_o"], (c_new, kr_new)
