"""NequIP (arXiv:2101.03164): E(3)-equivariant interatomic potential.

Irrep features are dicts ``{l: (N, C, 2l+1)}`` (uniform multiplicity C per
order l, l <= l_max).  The interaction block follows the paper:

  message_ij = sum over CG paths (l1, l2 -> l3):
               R_path(|r_ij|) * CG[(l1 m1)(l2 m2)(l3 m3)] *
               h_j^{l1 c m1} * Y^{l2 m2}(r_ij / |r_ij|)
  h_i^{l3}  <- self_linear(h_i) + dst-aggregated messages   (segment_sum)
  gate      : l=0 channels -> silu; l>0 channels scaled by sigmoid(scalar gate)

Real spherical harmonics and real Clebsch-Gordan coupling coefficients are
built numerically at trace time (host, numpy): complex CG via the Racah
formula, rotated into the real basis with the standard unitary U^l.
Equivariance is asserted by tests/test_equivariant.py under random rotations.

TPU adaptation notes: the CG contraction is an einsum over (C, 2l1+1, 2l2+1)
tiles — dense, MXU-friendly; gather/scatter is the same segment_sum idiom as
the other GNNs (kernel regime #3 of the taxonomy, O(L^3) paths at l_max=2 is
tiny — the hot spot is the per-edge einsum batch).
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn import mlp_init, mlp_apply
from repro.models.layers import _init_dense


# --------------------------------------------------------------------------
# real spherical harmonics (cartesian, l <= 2), unit-normalized inputs
# --------------------------------------------------------------------------

def spherical_harmonics(vec, l_max: int):
    """vec: (..., 3) unit vectors -> dict {l: (..., 2l+1)} real SH values.

    Component ordering follows m = -l..l in the real basis.
    """
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    out = {0: jnp.full(vec.shape[:-1] + (1,), 0.5 / math.sqrt(math.pi),
                       vec.dtype)}
    if l_max >= 1:
        c1 = math.sqrt(3.0 / (4.0 * math.pi))
        out[1] = c1 * jnp.stack([y, z, x], axis=-1)
    if l_max >= 2:
        c = math.sqrt(15.0 / (4.0 * math.pi))
        c20 = math.sqrt(5.0 / (16.0 * math.pi))
        out[2] = jnp.stack([
            c * x * y,
            c * y * z,
            c20 * (3 * z * z - 1.0),
            c * x * z,
            (c / 2.0) * (x * x - y * y),
        ], axis=-1)
    if l_max >= 3:
        raise NotImplementedError("l_max <= 2")
    return out


# --------------------------------------------------------------------------
# real Clebsch-Gordan coupling coefficients (host-side numpy, cached)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _cg_complex(j1: int, j2: int, j3: int) -> np.ndarray:
    """Complex CG <j1 m1 j2 m2 | j3 m3> as (2j1+1, 2j2+1, 2j3+1) (Racah)."""
    f = math.factorial
    out = np.zeros((2 * j1 + 1, 2 * j2 + 1, 2 * j3 + 1))
    for i1, m1 in enumerate(range(-j1, j1 + 1)):
        for i2, m2 in enumerate(range(-j2, j2 + 1)):
            m3 = m1 + m2
            if abs(m3) > j3:
                continue
            i3 = m3 + j3
            pre = math.sqrt(
                (2 * j3 + 1) * f(j3 + j1 - j2) * f(j3 - j1 + j2)
                * f(j1 + j2 - j3) / f(j1 + j2 + j3 + 1))
            pre *= math.sqrt(f(j3 + m3) * f(j3 - m3) * f(j1 - m1)
                             * f(j1 + m1) * f(j2 - m2) * f(j2 + m2))
            s = 0.0
            for k in range(0, j1 + j2 - j3 + 1):
                denom_args = (k, j1 + j2 - j3 - k, j1 - m1 - k,
                              j2 + m2 - k, j3 - j2 + m1 + k, j3 - j1 - m2 + k)
                if any(a < 0 for a in denom_args):
                    continue
                s += (-1.0) ** k / np.prod([f(a) for a in denom_args])
            out[i1, i2, i3] = pre * s
    return out


@functools.lru_cache(maxsize=None)
def _real_basis_U(l: int) -> np.ndarray:
    """U s.t. |l m_real> = sum_m U[m_real, m] |l m_complex> (Condon-Shortley)."""
    dim = 2 * l + 1
    U = np.zeros((dim, dim), dtype=np.complex128)
    for mr in range(-l, l + 1):
        i = mr + l
        if mr == 0:
            U[i, l] = 1.0
        elif mr > 0:
            U[i, -mr + l] = 1.0 / math.sqrt(2)
            U[i, mr + l] = (-1.0) ** mr / math.sqrt(2)
        else:
            am = -mr
            U[i, -am + l] = 1j / math.sqrt(2)
            U[i, am + l] = -1j * (-1.0) ** am / math.sqrt(2)
    return U


@functools.lru_cache(maxsize=None)
def real_cg(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis coupling tensor w (2l1+1, 2l2+1, 2l3+1); may be zero."""
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1))
    C = _cg_complex(l1, l2, l3).astype(np.complex128)
    U1, U2, U3 = _real_basis_U(l1), _real_basis_U(l2), _real_basis_U(l3)
    w = np.einsum("am,bn,co,mno->abc", U1, U2, U3.conj(), C)
    # the real-basis coupling is real or purely imaginary per (l1+l2+l3) parity
    if np.abs(w.imag).max() > np.abs(w.real).max():
        w = w.imag
    else:
        w = w.real
    w[np.abs(w) < 1e-12] = 0.0
    return np.ascontiguousarray(w)


# --------------------------------------------------------------------------
# radial basis
# --------------------------------------------------------------------------

def bessel_basis(r, n_rbf: int, cutoff: float):
    """Sine-Bessel radial basis with smooth polynomial cutoff (NequIP eq. 8)."""
    r = jnp.maximum(r, 1e-9)
    n = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    b = math.sqrt(2.0 / cutoff) * jnp.sin(
        n * math.pi * r[..., None] / cutoff) / r[..., None]
    # p=6 polynomial envelope (smooth to 2nd derivative at r=cutoff)
    u = jnp.clip(r / cutoff, 0.0, 1.0)
    env = 1.0 - 28 * u**6 + 48 * u**7 - 21 * u**8
    return b * env[..., None]


# --------------------------------------------------------------------------
# config + init
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    n_layers: int = 5
    channels: int = 32          # multiplicity per irrep order
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 16
    d_scalar_in: int = 0        # optional extra l=0 scalar inputs (non-mol shapes)
    radial_hidden: int = 64

    @property
    def paths(self):
        """All allowed (l_in, l_filter, l_out) CG paths, l_filter/out <= l_max."""
        ps = []
        for l1 in range(self.l_max + 1):
            for l2 in range(self.l_max + 1):
                for l3 in range(abs(l1 - l2), min(l1 + l2, self.l_max) + 1):
                    if np.abs(real_cg(l1, l2, l3)).max() > 0:
                        ps.append((l1, l2, l3))
        return tuple(ps)


def nequip_init(key, cfg: NequIPConfig, dtype=jnp.float32):
    C = cfg.channels
    n_paths = len(cfg.paths)
    ks = jax.random.split(key, 4 + cfg.n_layers)
    p = {
        "species_embed": (jax.random.normal(ks[0], (cfg.n_species, C))
                          * 0.5).astype(dtype),
        "readout1": _init_dense(ks[1], C, C // 2, dtype),
        "readout2": _init_dense(ks[2], C // 2, 1, dtype),
        "layers": [],
    }
    if cfg.d_scalar_in:
        p["scalar_embed"] = _init_dense(ks[3], cfg.d_scalar_in, C, dtype)
    for i in range(cfg.n_layers):
        k = jax.random.split(ks[4 + i], 4 + 2 * (cfg.l_max + 1))
        layer = {
            # radial MLP -> one weight per (path, channel)
            "radial": mlp_init(k[0], [cfg.n_rbf, cfg.radial_hidden,
                                      n_paths * C], dtype),
            # per-l self-interaction + post-message linear
            "self": [_init_dense(k[1 + l], C, C, dtype)
                     for l in range(cfg.l_max + 1)],
            "post": [_init_dense(k[2 + cfg.l_max + l], C, C, dtype)
                     for l in range(cfg.l_max + 1)],
            # scalar gates for l>0 channels
            "gate": _init_dense(k[3 + 2 * cfg.l_max], C, cfg.l_max * C, dtype),
        }
        p["layers"].append(layer)
    return p


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _interaction(lp, cfg: NequIPConfig, feats, sh, rbf_w, src, dst, n_nodes):
    """One NequIP interaction block. feats: {l: (N, C, 2l+1)}."""
    C = cfg.channels
    # per-edge, per-path radial weights
    msgs = {l: 0.0 for l in range(cfg.l_max + 1)}
    for pi, (l1, l2, l3) in enumerate(cfg.paths):
        w = jnp.asarray(real_cg(l1, l2, l3), feats[0].dtype)   # (d1, d2, d3)
        hj = feats[l1][src]                                    # (E, C, d1)
        y = sh[l2]                                             # (E, d2)
        r = rbf_w[:, pi, :]                                    # (E, C)
        m = jnp.einsum("ecx,ey,xyz->ecz", hj, y, w)            # (E, C, d3)
        msgs[l3] = msgs[l3] + m * r[..., None]
    out = {}
    for l in range(cfg.l_max + 1):
        agg = jax.ops.segment_sum(msgs[l], dst, n_nodes) \
            if not isinstance(msgs[l], float) else jnp.zeros_like(feats[l])
        selfi = jnp.einsum("ncx,cd->ndx", feats[l], lp["self"][l])
        h = selfi + jnp.einsum("ncx,cd->ndx", agg, lp["post"][l])
        out[l] = h
    # gate nonlinearity
    scal = out[0][..., 0]                                      # (N, C)
    gates = jax.nn.sigmoid(scal @ lp["gate"])                  # (N, l_max*C)
    new = {0: jax.nn.silu(scal)[..., None]}
    for l in range(1, cfg.l_max + 1):
        g = gates[:, (l - 1) * C: l * C]
        new[l] = out[l] * g[..., None]
    # residual on scalars (NequIP resnet-style update)
    new[0] = new[0] + feats[0]
    return new


def nequip_apply(params, cfg: NequIPConfig, species, positions, src, dst,
                 n_nodes, scalar_feats=None, node_mask=None):
    """Per-node energy contributions.

    species: (N,) int32; positions: (N, 3); src/dst: (E,) edges (messages
    flow src -> dst); scalar_feats: optional (N, d_scalar_in).
    Returns per-node scalar energy (N,).
    """
    C = cfg.channels
    h0 = params["species_embed"][jnp.clip(species, 0, cfg.n_species - 1)]
    if scalar_feats is not None and "scalar_embed" in params:
        h0 = h0 + scalar_feats @ params["scalar_embed"]
    feats = {0: h0[..., None]}
    for l in range(1, cfg.l_max + 1):
        feats[l] = jnp.zeros((n_nodes, C, 2 * l + 1), h0.dtype)

    rel = positions[src] - positions[dst]                      # (E, 3)
    dist = jnp.sqrt((rel * rel).sum(-1) + 1e-12)
    unit = rel / dist[..., None]
    sh = spherical_harmonics(unit, cfg.l_max)
    rbf = bessel_basis(dist, cfg.n_rbf, cfg.cutoff)            # (E, n_rbf)
    edge_valid = (src >= 0) & (dst >= 0)
    dst_safe = jnp.where(edge_valid, dst, 0)

    n_paths = len(cfg.paths)
    for lp in params["layers"]:
        rw = mlp_apply(lp["radial"], rbf, act=jax.nn.silu)
        rw = rw.reshape(-1, n_paths, C)
        rw = rw * edge_valid[:, None, None]
        feats = _interaction(lp, cfg, feats, sh, rw, src, dst_safe, n_nodes)

    e = jax.nn.silu(feats[0][..., 0] @ params["readout1"]) @ params["readout2"]
    e = e[..., 0]
    if node_mask is not None:
        e = e * node_mask
    return e


def energy_and_forces(params, cfg: NequIPConfig, species, positions, src, dst,
                      n_nodes, **kw):
    def etot(pos):
        return nequip_apply(params, cfg, species, pos, src, dst,
                            n_nodes, **kw).sum()
    e, neg_f = jax.value_and_grad(etot)(positions)
    return e, -neg_f


def energy_loss(params, cfg: NequIPConfig, batch, force_weight: float = 1.0):
    """MSE on energies (+ forces when labels present). batch holds flattened
    block-diagonal molecule graphs: species, positions, src, dst, graph_id,
    energy (G,), optional forces (N, 3), node_mask."""
    n_nodes = batch["species"].shape[0]
    if "forces" in batch:
        e_node, f = energy_and_forces(
            params, cfg, batch["species"], batch["positions"], batch["src"],
            batch["dst"], n_nodes, node_mask=batch.get("node_mask"))
        fl = ((f - batch["forces"]) ** 2).sum(-1)
        if batch.get("node_mask") is not None:
            fl = fl * batch["node_mask"]
        floss = force_weight * fl.mean()
    else:
        e_node = nequip_apply(
            params, cfg, batch["species"], batch["positions"], batch["src"],
            batch["dst"], n_nodes, scalar_feats=batch.get("scalar_feats"),
            node_mask=batch.get("node_mask"))
        floss = 0.0
    n_graphs = batch["energy"].shape[0]
    e_graph = jax.ops.segment_sum(e_node, batch["graph_id"], n_graphs)
    return ((e_graph - batch["energy"]) ** 2).mean() + floss
