"""Mixture-of-Experts FFN: top-k router + capacity-based scatter dispatch
(+ optional shared experts, Qwen-MoE style).

Dispatch is scatter/gather based (no (T, E, C) one-hot einsum): tokens are
ranked within their chosen expert via a cumsum over the (T, E) assignment
one-hot, scattered into an (E, C, d) buffer, processed with a batched
per-expert SwiGLU einsum, and gathered back.  With tokens sharded over
``data`` and experts over ``model``, GSPMD lowers the scatter/gather pair to
the all-to-all dispatch/combine of expert parallelism.  Tokens beyond
capacity are dropped (contribute zero), standard GShard semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import _init_dense, swiglu


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared experts, always-on (Qwen2-MoE)
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    n_experts_padded: Optional[int] = None   # pad for even model-axis sharding
    # EP dispatch sharding (§Perf): experts over axis 0, CAPACITY over
    # axis 1.  Without the capacity axis, every data rank re-computes the
    # full global capacity of its model-rank's experts (measured 16x
    # redundant expert GEMMs on phi3.5-moe train_4k).
    ep_axes: Optional[tuple] = None          # e.g. ("model", "data")

    @property
    def e_pad(self) -> int:
        return self.n_experts_padded or self.n_experts


def moe_init(key, d_model: int, cfg: MoEConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 5)
    E, F = cfg.e_pad, cfg.d_ff_expert
    p = {
        "router": _init_dense(ks[0], d_model, E, jnp.float32),
        "w_gate": jax.vmap(lambda k: _init_dense(k, d_model, F, dtype))(
            jax.random.split(ks[1], E)),
        "w_up": jax.vmap(lambda k: _init_dense(k, d_model, F, dtype))(
            jax.random.split(ks[2], E)),
        "w_down": jax.vmap(lambda k: _init_dense(k, F, d_model, dtype))(
            jax.random.split(ks[3], E)),
    }
    if cfg.n_shared:
        d_sh = cfg.d_ff_shared or cfg.d_ff_expert * cfg.n_shared
        sk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": _init_dense(sk[0], d_model, d_sh, dtype),
            "w_up": _init_dense(sk[1], d_model, d_sh, dtype),
            "w_down": _init_dense(sk[2], d_sh, d_model, dtype),
        }
    return p


def moe_apply(params, cfg: MoEConfig, x):
    """x: (T, d) -> (out (T, d), aux_loss scalar)."""
    T, d = x.shape
    E, k = cfg.e_pad, cfg.top_k
    logits = (x.astype(jnp.float32) @ params["router"])
    if E > cfg.n_experts:  # mask padding experts out of routing
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                 # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert via cumsum ranking
    onehot = jax.nn.one_hot(eidx, E, dtype=jnp.int32)     # (T, k, E)
    flat = onehot.reshape(T * k, E)
    pos = jnp.cumsum(flat, axis=0) - flat                 # exclusive rank
    pos = (pos * flat).sum(-1).reshape(T, k)              # (T, k)
    cap = max(1, int(cfg.capacity_factor * T * k / cfg.n_experts))
    keep = pos < cap

    # scatter tokens into (E, cap, d)
    def _ep(t):
        if cfg.ep_axes is None:
            return t
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(
            t, P(cfg.ep_axes[0], cfg.ep_axes[1], None))

    buf = jnp.zeros((E, cap, d), x.dtype)
    e_safe = jnp.where(keep, eidx, 0)
    p_safe = jnp.where(keep, pos, 0)
    xk = jnp.broadcast_to(x[:, None, :], (T, k, d))
    buf = _ep(buf.at[e_safe.reshape(-1), p_safe.reshape(-1)].add(
        (xk * keep[..., None]).reshape(T * k, d)))

    # batched per-expert SwiGLU (experts x capacity sharded: true EP)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    y = _ep(jnp.einsum("ecf,efd->ecd", h, params["w_down"]))

    # gather back + combine
    out_k = y[e_safe, p_safe]                             # (T, k, d)
    out = (out_k * (gate * keep)[..., None].astype(out_k.dtype)).sum(axis=1)

    if cfg.n_shared:
        out = out + swiglu(params["shared"], x)

    # switch-style load-balance aux loss (over real experts only)
    me = probs[:, :cfg.n_experts].mean(axis=0)
    ce = (jax.nn.one_hot(eidx[:, 0], E)[:, :cfg.n_experts]).mean(axis=0)
    aux = cfg.router_aux_weight * cfg.n_experts * (me * ce).sum()
    return out, aux
