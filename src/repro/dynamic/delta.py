"""Batched edge insert/delete against the device-resident ELL+overflow
encoding (DESIGN.md §7.1).

The mutable graph lives on device as the same two structures the coloring
passes consume: a fixed-shape ``(n_pad, W)`` ELL slot table (FILL = empty
slot) and a fixed-capacity COO overflow buffer for edges that do not fit
their row (capped-width hubs, or rows filled up by later inserts).  Both
arrays keep fixed shapes across update batches, so a handful of jit
compilations serve the whole stream:

  * delete (u,v): clear every slot equal to v in row u (and u in row v),
    and every overflow slot holding (u,v) or (v,u).  Cleared slots become
    FILL holes that later inserts re-use.
  * insert (u,v): no-op if the edge is already present (ELL row or
    overflow); otherwise write into the first FILL slot, spilling to the
    first FILL overflow slots when the row is full.  If the overflow
    buffer is full the wave reports failure and the host doubles the
    buffer (amortized, like vector growth) and re-applies — application
    is idempotent.

Everything is *vectorized*, never per-edge sequential: overflow membership
(delete targets, insert presence) is a lexicographic binary search over
sorted (src, dst) pairs, and ELL mutations are grouped host-side into
**waves** whose target rows are unique, so each wave is a single
conflict-free gather/mutate/scatter over ``(delta_cap, W)`` tiles.  Wave
count equals the largest per-row multiplicity in the batch (1–4 for
random batches).  Re-inserting a present edge — ELL- or
overflow-resident — is a no-op, so upsert-style streams do not grow the
encoding.

Wave *planning* (host-side numpy: chunking, wave grouping, FILL padding,
touched-mask accumulation) is factored into ``plan_updates`` so the
megabatched multi-tenant path (``dynamic/megabatch.py``, DESIGN.md §13) can
build per-tenant plans and dispatch them through the ``_mega_*`` batched
kernels — one ``vmap``-ed device call applies wave j of every tenant in a
slot class.  An all-FILL wave is a no-op through every kernel, which is what
lets tenants with fewer waves ride a longer batch for free.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.csr import CSRGraph, FILL, ell_to_edges, from_edges
from repro.resilience import faults
from repro.resilience.errors import OvfGrowthExhausted


# --------------------------------------------------------------------------
# wave kernels (fixed (delta_cap,) shapes); the _impl bodies are plain
# functions so they can be jitted per-tenant AND vmapped across a
# megabatch slot axis without retracing tricks
# --------------------------------------------------------------------------

_SENTINEL = jnp.int32(2147483647)                   # sorts after any id


def _pair_member(qs, qd, s_sorted, d_sorted):
    """found[i] = (qs[i], qd[i]) ∈ sorted pair list.  Vectorized
    lexicographic binary search; pairs stay as two int32 arrays — a fused
    s*n+d key overflows int32 past 2^15 vertices and x64 is disabled."""
    nb = s_sorted.shape[0]
    lo = jnp.zeros_like(qs)
    hi = jnp.full_like(qs, nb)
    # lower_bound over nb+1 candidate positions: ceil(log2(nb+1)) halvings,
    # covered by nb.bit_length() for every nb (static trip count)
    for _ in range(max(nb, 1).bit_length()):
        mid = (lo + hi) // 2
        ms, md = s_sorted[mid], d_sorted[mid]
        less = (ms < qs) | ((ms == qs) & (md < qd))
        lo = jnp.where(less, mid + 1, lo)
        hi = jnp.where(less, hi, mid)
    loc = jnp.clip(lo, 0, nb - 1)
    return (lo < nb) & (s_sorted[loc] == qs) & (d_sorted[loc] == qd)


def _lexsorted(s, d):
    order = jnp.lexsort((d, s))
    return s[order], d[order]


def _delete_overflow_impl(osrc, odst, dels):
    """Clear every overflow slot matching a delete pair (either direction).

    One vectorized membership test: delete pairs (both directions) are
    lexsorted and each overflow slot runs a lexicographic binary search.
    """
    valid_d = (dels[:, 0] >= 0) & (dels[:, 1] >= 0)
    ds = jnp.where(valid_d[:, None], dels, _SENTINEL)  # sentinels sort last
    s_sorted, d_sorted = _lexsorted(
        jnp.concatenate([ds[:, 0], ds[:, 1]]),
        jnp.concatenate([ds[:, 1], ds[:, 0]]))
    dead = ((osrc >= 0) & (odst >= 0)
            & _pair_member(osrc, odst, s_sorted, d_sorted))
    return jnp.where(dead, FILL, osrc), jnp.where(dead, FILL, odst)


def _delete_ell_wave_impl(ell, a, b):
    """Clear slots == b[i] in row a[i]; rows unique within the wave."""
    n_pad = ell.shape[0]
    asafe = jnp.clip(a, 0, n_pad - 1)
    rows = ell[asafe]
    rows = jnp.where((b[:, None] >= 0) & (rows == b[:, None]), FILL, rows)
    aw = jnp.where(a >= 0, asafe, n_pad)            # drop padded entries
    return ell.at[aw].set(rows, mode="drop")


def _sort_overflow_impl(osrc, odst):
    """Sorted-presence snapshot of the overflow buffer (FILL slots pushed
    past the end as sentinels).  The sort is by far the most expensive step
    of an insert (XLA sort over a buffer orders of magnitude bigger than a
    wave), and one snapshot per *batch* suffices: ``plan_updates`` dedups
    directed pairs, so no wave ever queries a pair that an earlier wave of
    the same batch spilled."""
    olive = (osrc >= 0) & (odst >= 0)
    return _lexsorted(jnp.where(olive, osrc, _SENTINEL),
                      jnp.where(olive, odst, _SENTINEL))


def _insert_wave_impl(ell, osrc, odst, s_sorted, d_sorted, a, b):
    """Insert b[i] into row a[i] (rows unique within the wave), spilling
    row-full entries to distinct free overflow slots.  ``s_sorted`` /
    ``d_sorted`` is the batch's overflow presence snapshot
    (``_sort_overflow_impl``).  Returns (ell, osrc, odst, fail):
    fail = some spill found no free slot."""
    n_pad, W = ell.shape
    ncap = osrc.shape[0]
    k = a.shape[0]
    valid = (a >= 0) & (b >= 0)
    asafe = jnp.clip(a, 0, n_pad - 1)
    rows = ell[asafe]
    # presence = ELL row ∪ overflow buffer: without the overflow side an
    # upsert-style stream re-inserting an overflow-resident edge would
    # append a duplicate slot per batch and grow the buffer without bound
    present = ((rows == b[:, None]).any(axis=1)
               | _pair_member(a, b, s_sorted, d_sorted))
    slot = jnp.argmax(rows == FILL, axis=1)         # first free slot (or 0)
    free = jnp.take_along_axis(rows, slot[:, None], 1)[:, 0] == FILL
    do_ell = valid & ~present & free
    aw = jnp.where(do_ell, asafe, n_pad)
    ell = ell.at[aw, slot].set(b, mode="drop")
    # spills: j-th spilling entry takes the j-th free overflow slot
    spill = valid & ~present & ~free
    freeslots = jnp.nonzero(osrc == FILL, size=k, fill_value=ncap)[0]
    rank = jnp.cumsum(spill) - 1
    oidx = jnp.where(spill, freeslots[jnp.clip(rank, 0, k - 1)], ncap)
    osrc = osrc.at[oidx].set(a, mode="drop")
    odst = odst.at[oidx].set(b, mode="drop")
    fail = (spill & (oidx >= ncap)).any()
    return ell, osrc, odst, fail


_delete_overflow = jax.jit(_delete_overflow_impl)
_delete_ell_wave = jax.jit(_delete_ell_wave_impl)
_sort_overflow = jax.jit(_sort_overflow_impl)
_insert_wave = jax.jit(_insert_wave_impl)

# Batched variants: one device dispatch applies wave j of every tenant in a
# megabatch slot class (leading axis = slot).  The per-slot bodies are the
# exact per-tenant kernels, so a megabatched wave is bit-identical to N
# per-tenant waves; an all-FILL slot row is a no-op (dynamic/megabatch.py).
_mega_delete_overflow = jax.jit(jax.vmap(_delete_overflow_impl))
_mega_delete_ell_wave = jax.jit(jax.vmap(
    lambda ell, w: _delete_ell_wave_impl(ell, w[:, 0], w[:, 1])))
_mega_sort_overflow = jax.jit(jax.vmap(_sort_overflow_impl))
_mega_insert_wave = jax.jit(jax.vmap(
    lambda ell, osrc, odst, ss, ds, w: _insert_wave_impl(
        ell, osrc, odst, ss, ds, w[:, 0], w[:, 1])))


# --------------------------------------------------------------------------
# host orchestration
# --------------------------------------------------------------------------

def _pad_pairs_np(pairs: np.ndarray, cap: int) -> np.ndarray:
    out = np.full((cap, 2), FILL, dtype=np.int32)
    out[:len(pairs)] = pairs
    return out


def _dedup_pairs(p: np.ndarray) -> np.ndarray:
    """Unique rows of a non-negative (k, 2) int32 array, lexicographically
    sorted — equivalent to ``np.unique(p, axis=0)`` but on a fused int64
    key (axis-0 unique goes through a void view and is ~10x slower, which
    matters at service rates where planning is per tenant per batch)."""
    key = (p[:, 0].astype(np.int64) << 32) | p[:, 1].astype(np.int64)
    _, idx = np.unique(key, return_index=True)
    return p[idx]


def empty_wave(cap: int) -> np.ndarray:
    """An all-FILL (cap, 2) wave — a no-op through every wave kernel (used
    to pad shorter tenants inside a megabatch)."""
    return np.full((cap, 2), FILL, dtype=np.int32)


def _waves(pairs: np.ndarray, cap: int):
    """Split directed (k, 2) pairs into FILL-padded (cap, 2) waves whose
    first columns (target rows) are unique within each wave."""
    if len(pairs) == 0:
        return
    a = pairs[:, 0]
    order = np.argsort(a, kind="stable")
    sa = a[order]
    first = np.concatenate([[True], sa[1:] != sa[:-1]])
    group_start = np.maximum.accumulate(
        np.where(first, np.arange(len(sa)), 0))
    rank = np.arange(len(sa)) - group_start       # occurrence # within row
    for w in range(int(rank.max()) + 1 if len(rank) else 0):
        sel = order[rank == w]
        for lo in range(0, len(sel), cap):
            yield _pad_pairs_np(pairs[sel[lo:lo + cap]], cap)


@dataclasses.dataclass(frozen=True)
class UpdatePlan:
    """Host-side wave plan of one update batch (relabeled-space ids).

    The plan is the deterministic product of ``plan_updates`` — the SAME
    plan drives the per-tenant ``apply_updates`` loop and the megabatched
    dispatch, which is what makes the two paths bit-identical by
    construction.  All waves are FILL-padded ``(delta_cap, 2)`` int32.
    """

    ovf_del: tuple    # overflow-delete chunks (undirected pairs)
    ell_del: tuple    # ELL delete waves (directed, unique rows per wave)
    ins: tuple        # insert waves (directed, unique rows per wave)
    touched: np.ndarray             # (n_pad,) bool repair seed mask

    @property
    def n_ops(self) -> int:
        return len(self.ovf_del) + len(self.ell_del) + len(self.ins)


def plan_updates(ins: np.ndarray, dels: np.ndarray, delta_cap: int,
                 n_pad: int) -> UpdatePlan:
    """Plan a delete-then-insert batch into fixed-shape device waves."""
    ins = np.asarray(ins, dtype=np.int32).reshape(-1, 2)
    dels = np.asarray(dels, dtype=np.int32).reshape(-1, 2)

    ovf_del = []
    ell_del = []
    if len(dels):
        for lo in range(0, len(dels), delta_cap):
            ovf_del.append(_pad_pairs_np(dels[lo:lo + delta_cap], delta_cap))
        dd = np.concatenate([dels, dels[:, ::-1]])
        dd = _dedup_pairs(dd)                     # idempotent clears
        ell_del.extend(_waves(dd, delta_cap))

    ins_waves = []
    if len(ins):
        ii = np.concatenate([ins, ins[:, ::-1]])
        ii = ii[ii[:, 0] != ii[:, 1]]             # drop self-loops
        # dedup directed pairs: besides shaving waves, this is what lets the
        # overflow presence snapshot be taken ONCE per batch — no wave can
        # re-query a pair an earlier wave of the same batch spilled
        ii = _dedup_pairs(ii)
        ins_waves.extend(_waves(ii, delta_cap))

    touched = np.zeros((n_pad,), bool)
    for e in (ins, dels):
        if len(e):
            touched[e.ravel()] = True
    return UpdatePlan(ovf_del=tuple(ovf_del), ell_del=tuple(ell_del),
                      ins=tuple(ins_waves), touched=touched)


def _rank_waves_group(pairs: np.ndarray, slots: np.ndarray, n_slots: int,
                      cap: int) -> np.ndarray:
    """Fused-across-slots equivalent of ``_dedup_pairs`` + ``_waves``:
    directed ``pairs`` tagged with ``slots`` ids come out as ONE
    ``(n_waves, n_slots, cap, 2)`` FILL-padded tensor whose slice
    ``[:, b]`` is bit-identical to ``_waves(_dedup_pairs(pairs of b), cap)``
    — same dedup order (lex by (a, b)), same occurrence-rank partition,
    same over-``cap`` chunk splitting — built with a handful of O(total)
    numpy ops instead of a sort + partition per slot.
    """
    if len(pairs) == 0:
        return np.zeros((0, n_slots, cap, 2), np.int32)
    # dedup per slot + lex sort by (slot, a, b) on one fused int64 key
    q = ((slots.astype(np.int64) << 48)
         | (pairs[:, 0].astype(np.int64) << 24)
         | pairs[:, 1].astype(np.int64))
    uq = np.unique(q)
    s = (uq >> 48).astype(np.int64)
    a = ((uq >> 24) & 0xFFFFFF).astype(np.int32)
    b = (uq & 0xFFFFFF).astype(np.int32)
    m = len(uq)
    idx = np.arange(m)

    def group_pos(key):
        first = np.empty(m, bool)
        first[0] = True
        np.not_equal(key[1:], key[:-1], out=first[1:])
        start = np.maximum.accumulate(np.where(first, idx, 0))
        return first, idx - start

    # rank = occurrence # of row a within its slot (same-row entries must
    # land in different waves)
    _, rank = group_pos(uq >> 24)
    # position within the (slot, rank) group decides over-cap chunking.
    # Ranks interleave in (slot, a, b) order, so group by (slot, rank) with
    # a stable sort — stability keeps the (a, b) order within each group,
    # matching the scalar ``_waves`` emission exactly
    srk = (s << 24) | rank
    order = np.argsort(srk, kind="stable")
    s, a, b, srk = s[order], a[order], b[order], srk[order]
    g_first, pos = group_pos(srk)
    # wave ordinal: ranks in order, each rank's chunks sequentially —
    # groups are already slot-major / rank-minor, so a per-slot running
    # chunk count reproduces the scalar emission order
    gidx = np.cumsum(g_first) - 1                  # entry -> group index
    sizes = np.bincount(gidx)
    nch = -(sizes // -cap)                         # chunks per group
    cum = np.cumsum(nch) - nch                     # global chunk prefix
    group_slot = s[g_first]
    g_range = np.arange(len(sizes))
    slot_first = np.empty(len(sizes), bool)
    slot_first[0] = True
    np.not_equal(group_slot[1:], group_slot[:-1], out=slot_first[1:])
    slot_base = cum[np.maximum.accumulate(np.where(slot_first, g_range, 0))]
    wave = (cum - slot_base)[gidx] + pos // cap

    n_waves = int(wave.max()) + 1
    out = np.full((n_waves, n_slots, cap, 2), FILL, np.int32)
    out[wave, s, pos % cap, 0] = a
    out[wave, s, pos % cap, 1] = b
    return out


def plan_group(batches, delta_cap: int, n_pad: int, directed: bool = False):
    """Vectorized ``plan_updates`` over a whole slot class for ONE batch
    round.  ``batches[b]`` is slot b's relabeled ``(ins, dels)`` pair of
    (k, 2) int32 arrays (empty arrays for a no-op slot).  Returns numpy
    ``(ovf_w, ell_w, ins_w, touched)`` — three ``(n_waves, n_slots,
    delta_cap, 2)`` wave tensors and a ``(n_slots, n_pad)`` bool repair
    seed mask — where every slot's slices are bit-identical to its own
    ``plan_updates`` waves.  Collapsing the per-slot sorts into fused-key
    passes is a several-fold planning speedup at megabatch tenant counts.

    ``directed=True`` (the sharded engine, slot = shard) takes each pair as
    an already-directed (row, target-slot) mutation and skips the reversal:
    a cross-shard edge's two directions live in *different* slots' batches,
    so reversing here would fabricate row mutations for vertices the shard
    does not own.  Self-pairs are still dropped from insert waves but still
    seed ``touched`` — identical to the undirected path's treatment of
    self-loop inserts.
    """
    n_slots = len(batches)
    touched = np.zeros((n_slots, n_pad), bool)
    for bi, (ins, dels) in enumerate(batches):
        for e in (ins, dels):
            if len(e):
                touched[bi, np.ravel(e)] = True

    # overflow deletes: raw undirected pairs chunked per slot
    n_ovf = max((-(len(d) // -delta_cap)) for _, d in batches)
    ovf_w = np.full((n_ovf, n_slots, delta_cap, 2), FILL, np.int32)
    for bi, (_, dels) in enumerate(batches):
        for j in range(0, len(dels), delta_cap):
            ovf_w[j // delta_cap, bi, :len(dels[j:j + delta_cap])] = \
                dels[j:j + delta_cap]

    def fused(kind):
        ps, ss = [], []
        for bi, (ins, dels) in enumerate(batches):
            e = ins if kind == "ins" else dels
            if not len(e):
                continue
            d = np.asarray(e) if directed else np.concatenate([e, e[:, ::-1]])
            if kind == "ins":
                d = d[d[:, 0] != d[:, 1]]          # drop self-loops
            ps.append(d)
            ss.append(np.full((len(d),), bi, np.int64))
        if not ps:
            return np.zeros((0, n_slots, delta_cap, 2), np.int32)
        return _rank_waves_group(np.concatenate(ps), np.concatenate(ss),
                                 n_slots, delta_cap)

    return ovf_w, fused("dels"), fused("ins"), touched


def apply_updates(ell, osrc, odst, ins: np.ndarray, dels: np.ndarray,
                  delta_cap: int, max_grows=None):
    """Apply (k, 2) delete-then-insert batches (relabeled-space host arrays).

    Returns (ell, osrc, odst, touched, n_grows): ``touched`` is an (n_pad,)
    bool device mask of the endpoints of every update (the repair seed set),
    ``n_grows`` counts overflow-buffer doublings performed.  ``max_grows``
    bounds the doublings per batch (None: unbounded, the legacy behavior);
    exhaustion raises ``OvfGrowthExhausted`` *before* mutating anything
    further, which the degradation ladder (DESIGN.md §14) catches.
    """
    if faults.fires("ovf.exhaust"):
        raise OvfGrowthExhausted(grows=0, budget=max_grows,
                                 cap=int(osrc.shape[0]), forced=True)
    plan = plan_updates(ins, dels, delta_cap, ell.shape[0])
    for wave in plan.ovf_del:
        osrc, odst = _delete_overflow(osrc, odst, jnp.asarray(wave))
    for wave in plan.ell_del:
        ell = _delete_ell_wave(ell, jnp.asarray(wave[:, 0]),
                               jnp.asarray(wave[:, 1]))
    grows = 0
    if plan.ins:
        ss, ds = _sort_overflow(osrc, odst)       # once per batch
    for wave in plan.ins:
        a = jnp.asarray(wave[:, 0])
        b = jnp.asarray(wave[:, 1])
        while True:
            ell2, osrc2, odst2, fail = _insert_wave(ell, osrc, odst,
                                                    ss, ds, a, b)
            if not bool(fail):
                ell, osrc, odst = ell2, osrc2, odst2
                break
            # overflow full: grow and re-apply the wave (idempotent).  The
            # grown buffer holds this wave's partial spills, so the snapshot
            # must be retaken — re-applying against the stale one would
            # duplicate the entries that did land
            if max_grows is not None and grows >= max_grows:
                raise OvfGrowthExhausted(grows=grows, budget=max_grows,
                                         cap=int(osrc2.shape[0]))
            osrc, odst = grow_overflow(osrc2, odst2)
            ell = ell2
            grows += 1
            ss, ds = _sort_overflow(osrc, odst)
    return ell, osrc, odst, jnp.asarray(plan.touched), grows


def apply_updates_mega(ell_b, osrc_b, odst_b, plans, delta_cap: int):
    """Apply one ``UpdatePlan`` per slot in lockstep (DESIGN.md §13).

    ``ell_b``/``osrc_b``/``odst_b`` carry a leading slot axis; ``plans`` is
    one plan per slot (shorter tenants are padded with no-op FILL waves up
    to the longest plan).  Each wave index is ONE device dispatch for the
    whole slot class.  Unlike ``apply_updates`` there is no grow-and-retry:
    a slot whose insert wave finds the overflow buffer full raises its
    ``fail`` flag and the caller escapes that slot to the per-tenant path —
    growing in place would change the slot's buffer shape and force a
    batch-wide recompile.

    Returns (ell_b, osrc_b, odst_b, fail) with ``fail`` a host bool array.
    """
    pad = empty_wave(delta_cap)

    def stacked(kind: str, j: int):
        ws = [getattr(p, kind)[j] if j < len(getattr(p, kind)) else pad
              for p in plans]
        return jnp.asarray(np.stack(ws))

    for j in range(max(len(p.ovf_del) for p in plans)):
        osrc_b, odst_b = _mega_delete_overflow(osrc_b, odst_b,
                                               stacked("ovf_del", j))
    for j in range(max(len(p.ell_del) for p in plans)):
        ell_b = _mega_delete_ell_wave(ell_b, stacked("ell_del", j))
    fail = np.zeros((len(plans),), bool)
    n_ins = max(len(p.ins) for p in plans)
    if n_ins:
        ss_b, ds_b = _mega_sort_overflow(osrc_b, odst_b)  # once per batch
    for j in range(n_ins):
        ell_b, osrc_b, odst_b, fail_j = _mega_insert_wave(
            ell_b, osrc_b, odst_b, ss_b, ds_b, stacked("ins", j))
        fail |= np.asarray(fail_j)
    return ell_b, osrc_b, odst_b, fail


def grow_overflow(osrc, odst, factor: int = 2):
    """Double the overflow buffer (FILL-padded).  One recompile per growth."""
    cap = osrc.shape[0]
    extra = jnp.full((max(cap, 8) * (factor - 1),), FILL, jnp.int32)
    return jnp.concatenate([osrc, extra]), jnp.concatenate([odst, extra])


def overflow_load(osrc) -> int:
    """Live (non-FILL) overflow slots."""
    return int((np.asarray(osrc) >= 0).sum())


def state_to_csr(state) -> CSRGraph:
    """Decode a dynamic coloring state back to a host CSRGraph (original
    ids).  Sharded states carry their own slot-space decoder (``to_csr``,
    dynamic/sharded.py) — duck-typed here so every state consumer (service
    verification, the degradation ladder's ``updated_graph``) stays
    engine-agnostic."""
    if hasattr(state, "to_csr"):
        return state.to_csr()
    edges = ell_to_edges(state.ell, state.n, state.ovf_src, state.ovf_dst)
    return from_edges(state.n, state.inv_perm[edges], symmetrize=False)
