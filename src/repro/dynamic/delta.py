"""Batched edge insert/delete against the device-resident ELL+overflow
encoding (DESIGN.md §7.1).

The mutable graph lives on device as the same two structures the coloring
passes consume: a fixed-shape ``(n_pad, W)`` ELL slot table (FILL = empty
slot) and a fixed-capacity COO overflow buffer for edges that do not fit
their row (capped-width hubs, or rows filled up by later inserts).  Both
arrays keep fixed shapes across update batches, so a handful of jit
compilations serve the whole stream:

  * delete (u,v): clear every slot equal to v in row u (and u in row v),
    and every overflow slot holding (u,v) or (v,u).  Cleared slots become
    FILL holes that later inserts re-use.
  * insert (u,v): no-op if the edge is already present (ELL row or
    overflow); otherwise write into the first FILL slot, spilling to the
    first FILL overflow slots when the row is full.  If the overflow
    buffer is full the wave reports failure and the host doubles the
    buffer (amortized, like vector growth) and re-applies — application
    is idempotent.

Everything is *vectorized*, never per-edge sequential: overflow membership
(delete targets, insert presence) is a lexicographic binary search over
sorted (src, dst) pairs, and ELL mutations are grouped host-side into
**waves** whose target rows are unique, so each wave is a single
conflict-free gather/mutate/scatter over ``(delta_cap, W)`` tiles.  Wave
count equals the largest per-row multiplicity in the batch (1–4 for
random batches).  Re-inserting a present edge — ELL- or
overflow-resident — is a no-op, so upsert-style streams do not grow the
encoding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.csr import CSRGraph, FILL, ell_to_edges, from_edges


# --------------------------------------------------------------------------
# jitted kernels (fixed (delta_cap,) wave shapes)
# --------------------------------------------------------------------------

_SENTINEL = jnp.int32(2147483647)                   # sorts after any id


def _pair_member(qs, qd, s_sorted, d_sorted):
    """found[i] = (qs[i], qd[i]) ∈ sorted pair list.  Vectorized
    lexicographic binary search; pairs stay as two int32 arrays — a fused
    s*n+d key overflows int32 past 2^15 vertices and x64 is disabled."""
    nb = s_sorted.shape[0]
    lo = jnp.zeros_like(qs)
    hi = jnp.full_like(qs, nb)
    # lower_bound over nb+1 candidate positions: ceil(log2(nb+1)) halvings,
    # covered by nb.bit_length() for every nb (static trip count)
    for _ in range(max(nb, 1).bit_length()):
        mid = (lo + hi) // 2
        ms, md = s_sorted[mid], d_sorted[mid]
        less = (ms < qs) | ((ms == qs) & (md < qd))
        lo = jnp.where(less, mid + 1, lo)
        hi = jnp.where(less, hi, mid)
    loc = jnp.clip(lo, 0, nb - 1)
    return (lo < nb) & (s_sorted[loc] == qs) & (d_sorted[loc] == qd)


def _lexsorted(s, d):
    order = jnp.lexsort((d, s))
    return s[order], d[order]


@jax.jit
def _delete_overflow(osrc, odst, dels):
    """Clear every overflow slot matching a delete pair (either direction).

    One vectorized membership test: delete pairs (both directions) are
    lexsorted and each overflow slot runs a lexicographic binary search.
    """
    valid_d = (dels[:, 0] >= 0) & (dels[:, 1] >= 0)
    ds = jnp.where(valid_d[:, None], dels, _SENTINEL)  # sentinels sort last
    s_sorted, d_sorted = _lexsorted(
        jnp.concatenate([ds[:, 0], ds[:, 1]]),
        jnp.concatenate([ds[:, 1], ds[:, 0]]))
    dead = ((osrc >= 0) & (odst >= 0)
            & _pair_member(osrc, odst, s_sorted, d_sorted))
    return jnp.where(dead, FILL, osrc), jnp.where(dead, FILL, odst)


@jax.jit
def _delete_ell_wave(ell, a, b):
    """Clear slots == b[i] in row a[i]; rows unique within the wave."""
    n_pad = ell.shape[0]
    asafe = jnp.clip(a, 0, n_pad - 1)
    rows = ell[asafe]
    rows = jnp.where((b[:, None] >= 0) & (rows == b[:, None]), FILL, rows)
    aw = jnp.where(a >= 0, asafe, n_pad)            # drop padded entries
    return ell.at[aw].set(rows, mode="drop")


@jax.jit
def _insert_wave(ell, osrc, odst, a, b):
    """Insert b[i] into row a[i] (rows unique within the wave), spilling
    row-full entries to distinct free overflow slots.  Returns
    (ell, osrc, odst, fail): fail = some spill found no free slot."""
    n_pad, W = ell.shape
    ncap = osrc.shape[0]
    k = a.shape[0]
    valid = (a >= 0) & (b >= 0)
    asafe = jnp.clip(a, 0, n_pad - 1)
    rows = ell[asafe]
    # presence = ELL row ∪ overflow buffer: without the overflow side an
    # upsert-style stream re-inserting an overflow-resident edge would
    # append a duplicate slot per batch and grow the buffer without bound
    olive = (osrc >= 0) & (odst >= 0)
    s_sorted, d_sorted = _lexsorted(jnp.where(olive, osrc, _SENTINEL),
                                    jnp.where(olive, odst, _SENTINEL))
    present = ((rows == b[:, None]).any(axis=1)
               | _pair_member(a, b, s_sorted, d_sorted))
    slot = jnp.argmax(rows == FILL, axis=1)         # first free slot (or 0)
    free = jnp.take_along_axis(rows, slot[:, None], 1)[:, 0] == FILL
    do_ell = valid & ~present & free
    aw = jnp.where(do_ell, asafe, n_pad)
    ell = ell.at[aw, slot].set(b, mode="drop")
    # spills: j-th spilling entry takes the j-th free overflow slot
    spill = valid & ~present & ~free
    freeslots = jnp.nonzero(osrc == FILL, size=k, fill_value=ncap)[0]
    rank = jnp.cumsum(spill) - 1
    oidx = jnp.where(spill, freeslots[jnp.clip(rank, 0, k - 1)], ncap)
    osrc = osrc.at[oidx].set(a, mode="drop")
    odst = odst.at[oidx].set(b, mode="drop")
    fail = (spill & (oidx >= ncap)).any()
    return ell, osrc, odst, fail


# --------------------------------------------------------------------------
# host orchestration
# --------------------------------------------------------------------------

def _pad_pairs(pairs: np.ndarray, cap: int) -> jnp.ndarray:
    out = np.full((cap, 2), FILL, dtype=np.int32)
    out[:len(pairs)] = pairs
    return jnp.asarray(out)


def _waves(pairs: np.ndarray, cap: int):
    """Split directed (k, 2) pairs into FILL-padded (cap, 2) waves whose
    first columns (target rows) are unique within each wave."""
    if len(pairs) == 0:
        return
    a = pairs[:, 0]
    order = np.argsort(a, kind="stable")
    sa = a[order]
    first = np.concatenate([[True], sa[1:] != sa[:-1]])
    group_start = np.maximum.accumulate(
        np.where(first, np.arange(len(sa)), 0))
    rank = np.arange(len(sa)) - group_start       # occurrence # within row
    for w in range(int(rank.max()) + 1 if len(rank) else 0):
        sel = order[rank == w]
        for lo in range(0, len(sel), cap):
            yield _pad_pairs(pairs[sel[lo:lo + cap]], cap)


def apply_updates(ell, osrc, odst, ins: np.ndarray, dels: np.ndarray,
                  delta_cap: int):
    """Apply (k, 2) delete-then-insert batches (relabeled-space host arrays).

    Returns (ell, osrc, odst, touched, n_grows): ``touched`` is an (n_pad,)
    bool device mask of the endpoints of every update (the repair seed set),
    ``n_grows`` counts overflow-buffer doublings performed.
    """
    n_pad = ell.shape[0]
    ins = np.asarray(ins, dtype=np.int32).reshape(-1, 2)
    dels = np.asarray(dels, dtype=np.int32).reshape(-1, 2)

    if len(dels):
        for lo in range(0, len(dels), delta_cap):
            osrc, odst = _delete_overflow(
                osrc, odst, _pad_pairs(dels[lo:lo + delta_cap], delta_cap))
        dd = np.concatenate([dels, dels[:, ::-1]])
        for wave in _waves(dd, delta_cap):
            ell = _delete_ell_wave(ell, wave[:, 0], wave[:, 1])

    grows = 0
    if len(ins):
        ii = np.concatenate([ins, ins[:, ::-1]])
        ii = ii[ii[:, 0] != ii[:, 1]]             # drop self-loops
        for wave in _waves(ii, delta_cap):
            while True:
                ell2, osrc2, odst2, fail = _insert_wave(
                    ell, osrc, odst, wave[:, 0], wave[:, 1])
                if not bool(fail):
                    ell, osrc, odst = ell2, osrc2, odst2
                    break
                # overflow full: grow and re-apply the wave (idempotent)
                osrc, odst = grow_overflow(osrc2, odst2)
                ell = ell2
                grows += 1

    touched = np.zeros((n_pad,), bool)
    for e in (ins, dels):
        if len(e):
            touched[e.ravel()] = True
    return ell, osrc, odst, jnp.asarray(touched), grows


def grow_overflow(osrc, odst, factor: int = 2):
    """Double the overflow buffer (FILL-padded).  One recompile per growth."""
    cap = osrc.shape[0]
    extra = jnp.full((max(cap, 8) * (factor - 1),), FILL, jnp.int32)
    return jnp.concatenate([osrc, extra]), jnp.concatenate([odst, extra])


def overflow_load(osrc) -> int:
    """Live (non-FILL) overflow slots."""
    return int((np.asarray(osrc) >= 0).sum())


def state_to_csr(state) -> CSRGraph:
    """Decode a DynamicColoringState back to a host CSRGraph (original ids)."""
    edges = ell_to_edges(state.ell, state.n, state.ovf_src, state.ovf_dst)
    return from_edges(state.n, state.inv_perm[edges], symmetrize=False)
