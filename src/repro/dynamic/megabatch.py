"""Megabatched multi-tenant stepping (DESIGN.md §13).

``ColoringService.step`` used to loop tenants in Python, dispatching one
jitted delta-apply + repair per graph per batch — per-dispatch overhead
(trace lookup, host→device argument marshalling, device sync) multiplied by
tenant count.  This module stacks same-shape tenants into a leading *slot*
axis so one device dispatch applies wave j of every tenant's update plan and
one dispatch repairs every tenant's coloring.

Slot classes
------------
Two tenants can share a batch only if every jit-static / shape parameter of
the stepping programs matches: ``slot_key`` collects them.  The service
buckets tenants by this key; arrival/departure within a class never
recompiles because the stacked batch is padded to a power-of-two capacity
(duplicating slot 0 with no-op plans), so only O(log N) distinct batch
shapes ever exist per class.

Escape-to-retry
---------------
The per-tenant path has two data-dependent escapes the batched programs
cannot take without punishing the whole class: the full-width fallback when
a frontier overflows ``frontier_cap`` (under ``vmap`` both ``lax.cond``
branches run for every slot) and the ``_run_with_retry`` color-cap doubling
(a new C is a batch-wide recompile).  The mega kernels instead surface
per-slot ``fail``/``escape`` flags; the host discards that slot's outputs,
rebuilds its pre-round state from the previous round's stacked arrays, and
redoes the batch through plain ``recolor_incremental`` — the exact code the
per-tenant loop runs, so escaped tenants are bit-identical by construction.
Non-escaped slots are bit-identical too: the same ``UpdatePlan`` drives both
paths and the ``while_loop`` batching rule freezes finished slots, so each
slot sees the exact scalar pass sequence.

Deferred commit
---------------
Stacked device arrays are carried across batch rounds; per-tenant slices
(one gather per tenant) happen once at the end, not per round.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import frontier
from repro.core.context import PassContext
from repro.dynamic import delta
from repro.dynamic import incremental as inc
from repro.dynamic.incremental import DynamicColoringState
from repro.resilience import ladder


def slot_key(state: DynamicColoringState) -> tuple:
    """Every jit-static / shape parameter of the stepping programs.

    Tenants agreeing on this key stack into one batch without retracing:
    array shapes (n_pad, W, ovf_cap, frontier/delta caps), the
    ``PassContext`` statics (n, C, n_chunks, forbidden_impl), and the
    repair-round bound (static arg of the repair loop).
    """
    return (state.n, state.n_pad, int(state.ell.shape[1]),
            int(state.ovf_src.shape[0]), state.C, state.n_chunks,
            state.frontier_cap, state.delta_cap, state.forbidden_impl,
            state.max_rounds)


def _pow2(k: int) -> int:
    return 1 << max(k - 1, 0).bit_length()


# bound on how many batch rounds one fused dispatch spans: compile time
# grows linearly with the unrolled round count, and the host only holds a
# pre-CHUNK snapshot for escape redos, so an escape replays at most this
# many batches per-tenant
FUSE_ROUNDS = 8


@functools.partial(jax.jit, static_argnames=("ctx", "cap", "max_rounds"))
def _mega_step(ell_b, osrc_b, odst_b, pri_b, colors_b, U_r,
               ovf_r, ell_r, ins_r, ctx, cap, max_rounds):
    """ONE device dispatch advancing a whole slot class by a CHUNK of batch
    rounds: for each round, every delete/insert wave of every slot, then
    the megabatched repair loop.  Both the round count and the per-kind
    wave counts are static leading dims the loops unroll over (one
    compilation per distinct shape tuple — small for steady batch sizes,
    and each dispatch replaces rounds × waves of them).  Inlines the same
    ``delta._mega_*`` kernels ``apply_updates_mega`` dispatches one-by-one,
    so results stay bit-identical to the per-tenant path.

    A slot that escapes (insert spill finds the overflow buffer full, or a
    repair escape — see ``_mega_compact_repair``) is dead for the rest of
    the chunk: its repair is frozen via ``esc0`` so it cannot spin the
    batched ``while_loop``, its arrays keep flowing through later wave
    kernels as garbage, and the host discards them.  Returns
    ``(ell, osrc, odst, colors, fail[r], rounds[r], defects[r], esc[r])``
    with per-round leading dims; ``esc`` is cumulative (a dead slot stays
    flagged), ``fail`` is per-round."""
    n_slots = ell_b.shape[0]
    dead = jnp.zeros((n_slots,), bool)
    fails, rs, tots, escs = [], [], [], []
    for r in range(U_r.shape[0]):
        fail = jnp.zeros((n_slots,), bool)
        for j in range(ovf_r.shape[1]):
            osrc_b, odst_b = delta._mega_delete_overflow(osrc_b, odst_b,
                                                         ovf_r[r, j])
        for j in range(ell_r.shape[1]):
            ell_b = delta._mega_delete_ell_wave(ell_b, ell_r[r, j])
        if ins_r.shape[1]:
            ss_b, ds_b = delta._mega_sort_overflow(osrc_b, odst_b)
            for j in range(ins_r.shape[1]):
                ell_b, osrc_b, odst_b, fj = delta._mega_insert_wave(
                    ell_b, osrc_b, odst_b, ss_b, ds_b, ins_r[r, j])
                fail = fail | fj
        colors_b, r_b, tot_b, esc_b = frontier._repair_mega_loop(
            ell_b, osrc_b, odst_b, pri_b, colors_b, U_r[r], dead | fail,
            ctx, cap, max_rounds)
        dead = dead | fail | esc_b
        fails.append(fail)
        rs.append(r_b)
        tots.append(tot_b)
        escs.append(dead)
    return (ell_b, osrc_b, odst_b, colors_b, jnp.stack(fails),
            jnp.stack(rs), jnp.stack(tots), jnp.stack(escs))


def _stack_rounds(tensors, cap: int):
    """Stack per-round ``(J_r, n_slots, cap, 2)`` wave tensors (one wave
    kind, one chunk of batch rounds) into a ``(n_rounds, J, n_slots, cap,
    2)`` chunk tensor; shorter rounds ride on all-FILL no-op waves.

    The shared wave count J is padded up to a power of two: ``_mega_step``
    unrolls over it, so every distinct (rounds, wave-count) shape tuple is
    a separate (expensive — it contains the repair loops) compilation.
    Random batches wobble the raw counts round to round; pow2 padding
    collapses them onto a handful of stable jit keys at the price of a few
    no-op waves."""
    R = len(tensors)
    _, n_slots, _, _ = tensors[0].shape
    n = max(t.shape[0] for t in tensors)
    n = _pow2(n) if n else 0
    if not n:
        return jnp.zeros((R, 0, n_slots, cap, 2), np.int32)
    out = np.empty((R, n, n_slots, cap, 2), np.int32)
    out[...] = delta.empty_wave(cap)          # broadcast-fill the padding
    for r, t in enumerate(tensors):
        out[r, :t.shape[0]] = t
    return jnp.asarray(out)


def step_group(states: Sequence[DynamicColoringState],
               queues: Sequence[Sequence[Tuple]],
               capacity: int = None,
               ) -> Tuple[List[DynamicColoringState], List[dict]]:
    """Drain every tenant's update-batch queue with megabatched dispatches.

    ``states`` must share one ``slot_key``; ``queues[i]`` is tenant i's list
    of ``(inserts, deletes)`` batches in original vertex ids, applied in
    order.  The queues are drained in chunks of up to ``FUSE_ROUNDS`` batch
    rounds, ONE fused ``_mega_step`` dispatch per chunk: round r of a chunk
    applies the r-th batch of every tenant that has one and repairs every
    coloring.  Slots that raise an escape flag anywhere in a chunk
    (overflow-buffer full, frontier past cap, color cap exceeded) replay
    that chunk's batches through ``recolor_incremental`` from their
    pre-chunk state; if the replay changed the tenant's shapes (grown
    buffer, doubled C) it leaves the batch and drains the rest of its queue
    per-tenant ("solo").

    Returns ``(new_states, outcomes)`` — ``outcomes[i]`` counts the path
    each non-empty batch took: ``{"batched": .., "escaped": .., "solo": ..}``
    (an escape charges every batch of its tenant's chunk to "escaped": the
    whole chunk is replayed).  Empty batches are skipped without a version
    bump, matching ``recolor_incremental``.
    """
    if len(states) != len(queues):
        raise ValueError("one queue per state required")
    k = len(states)
    outcomes = [{"batched": 0, "escaped": 0, "solo": 0} for _ in range(k)]
    if k == 0:
        return [], outcomes
    key = slot_key(states[0])
    for st in states[1:]:
        if slot_key(st) != key:
            raise ValueError("step_group requires a single slot class; "
                             f"got {slot_key(st)} vs {key}")
    st0 = states[0]
    n_pad, delta_cap = st0.n_pad, st0.delta_cap
    ctx = PassContext(n=st0.n, n_pad=st0.n_pad, C=st0.C,
                      n_chunks=st0.n_chunks,
                      forbidden_impl=st0.forbidden_impl)

    # validate + relabel host-side up front: a malformed batch must raise
    # before any tenant's arrays are touched.  Wave planning itself happens
    # per chunk round through ``delta.plan_group`` — ONE fused-key pass for
    # the whole slot class instead of a sort per tenant.
    rel_q: List[list] = []     # per tenant: relabeled (ins, dels) | None
    raw_q: List[list] = []     # per tenant: validated original-id pairs
    for st, q in zip(states, queues):
        rels, raws = [], []
        for ins, dels in q:
            ins = inc._check_edges(ins if ins is not None else [],
                                   st.n, "inserts")
            dels = inc._check_edges(dels if dels is not None else [],
                                    st.n, "deletes")
            if len(ins) == 0 and len(dels) == 0:
                rels.append(None)
                raws.append(None)
                continue
            rels.append((st.perm[ins] if len(ins) else ins,
                         st.perm[dels] if len(dels) else dels))
            raws.append((ins, dels))
        rel_q.append(rels)
        raw_q.append(raws)

    n_batch_rounds = max(len(q) for q in rel_q)
    cap_slots = capacity if capacity is not None else _pow2(k)
    if cap_slots < k:
        raise ValueError(f"capacity {cap_slots} < group size {k}")
    pad_idx = list(range(k)) + [0] * (cap_slots - k)
    ell_b = jnp.stack([states[i].ell for i in pad_idx])
    osrc_b = jnp.stack([states[i].ovf_src for i in pad_idx])
    odst_b = jnp.stack([states[i].ovf_dst for i in pad_idx])
    colors_b = jnp.stack([states[i].colors_dev for i in pad_idx])
    pri_b = jnp.stack([states[i].pri for i in pad_idx])

    cur = list(states)
    # dirty[i]: cur[i]'s array fields are stale — its latest arrays live in
    # the stacked batch and are sliced out at final commit
    dirty = [False] * k
    solo = [False] * k
    empty = (np.zeros((0, 2), np.int32),      # no-op slot for plan_group
             np.zeros((0, 2), np.int32))

    # scalar bookkeeping (version bumps, pass counters) is deferred like the
    # arrays: a dataclasses.replace per tenant per round is measurable host
    # work at service rates, so batched rounds only accumulate here and fold
    # into cur[i] once — at final commit, or on escape (the redo path needs
    # the materialized state)
    pend_ver = [0] * k
    pend_last = [(0, 0)] * k     # (last_rounds, last_conflicts) of latest
    pend_passes = [0] * k

    def _fold(i):
        if pend_ver[i]:
            st = cur[i]
            lr, lc = pend_last[i]
            cur[i] = dataclasses.replace(
                st, version=st.version + pend_ver[i], last_rounds=lr,
                last_conflicts=lc, last_gather_passes=lr,
                total_gather_passes=st.total_gather_passes + pend_passes[i])
            pend_ver[i] = 0
            pend_passes[i] = 0

    for lo in range(0, n_batch_rounds, FUSE_ROUNDS):
        chunk = range(lo, min(lo + FUSE_ROUNDS, n_batch_rounds))
        for i in range(k):          # solo tenants drain per-tenant
            if solo[i]:
                for rnd in chunk:
                    if rnd < len(rel_q[i]) \
                            and rel_q[i][rnd] is not None:
                        ins, dels = raw_q[i][rnd]
                        cur[i], _ = ladder.apply_with_ladder(cur[i], ins,
                                                             dels)
                        outcomes[i]["solo"] += 1
        act = [set(i for i in range(k)
                   if not solo[i] and rnd < len(rel_q[i])
                   and rel_q[i][rnd] is not None)
               for rnd in chunk]
        if not any(act):
            continue
        rounds = [delta.plan_group(
            [rel_q[j][rnd] if (j < k and j in a) else empty
             for j in pad_idx], delta_cap, n_pad)
            for rnd, a in zip(chunk, act)]

        prev = (ell_b, osrc_b, odst_b, colors_b)
        U_r = jnp.asarray(np.stack([t[3] for t in rounds]))
        ell_b, osrc_b, odst_b, colors_b, fail_r, r_r, tot_r, esc_r = \
            _mega_step(ell_b, osrc_b, odst_b, pri_b, colors_b, U_r,
                       _stack_rounds([t[0] for t in rounds], delta_cap),
                       _stack_rounds([t[1] for t in rounds], delta_cap),
                       _stack_rounds([t[2] for t in rounds], delta_cap),
                       ctx, st0.frontier_cap, st0.max_rounds)
        esc = np.asarray(fail_r) | np.asarray(esc_r)    # (rounds, slots)
        r_h = np.asarray(r_r)
        tot_h = np.asarray(tot_r)

        for i in range(k):
            mine = [ri for ri, a in enumerate(act) if i in a]
            if not mine:
                continue
            if not esc[mine, i].any():
                for ri in mine:
                    passes = int(r_h[ri, i])
                    pend_ver[i] += 1
                    pend_last[i] = (passes, int(tot_h[ri, i]))
                    pend_passes[i] += passes
                    outcomes[i]["batched"] += 1
                dirty[i] = True
                continue
            # escaped somewhere in the chunk: this slot's stacked arrays
            # are garbage by contract.  Rebuild its pre-chunk state and
            # replay the chunk's batches through the per-tenant retry path
            # (bit-identical by construction — it IS the reference path).
            _fold(i)
            st = cur[i]
            if dirty[i]:
                st = dataclasses.replace(
                    st, ell=prev[0][i], ovf_src=prev[1][i],
                    ovf_dst=prev[2][i], colors_dev=prev[3][i])
            for ri in mine:
                ins, dels = raw_q[i][chunk[ri]]
                st, _ = ladder.apply_with_ladder(st, ins, dels)
                outcomes[i]["escaped"] += 1
            cur[i] = st
            if slot_key(st) == key:
                # shapes survived: scatter back and stay in the batch
                ell_b = ell_b.at[i].set(st.ell)
                osrc_b = osrc_b.at[i].set(st.ovf_src)
                odst_b = odst_b.at[i].set(st.ovf_dst)
                colors_b = colors_b.at[i].set(st.colors_dev)
                dirty[i] = False
            else:
                # grown buffer / doubled C: can no longer ride this class
                dirty[i] = False
                solo[i] = True

    # deferred commit: one slice + one replace per dirty tenant, once
    for i in range(k):
        _fold(i)
        if dirty[i]:
            cur[i] = dataclasses.replace(
                cur[i], ell=ell_b[i], ovf_src=osrc_b[i], ovf_dst=odst_b[i],
                colors_dev=colors_b[i])
    return cur, outcomes
