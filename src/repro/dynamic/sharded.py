"""Sharded incremental recoloring: distributed × dynamic (DESIGN.md §15).

``ShardedColoringState`` is the mesh-distributed counterpart of
``DynamicColoringState``: the mutable ELL+overflow encode is laid out
per-shard in *slot space* (local slots [0, n_loc), ghost slots n_loc+g for
remote neighbors), and every repair round exchanges exactly one collective
carrying boundary colors plus three termination scalars — bytes per round
∝ boundary, never ∝ n.  Çatalyürek-style speculation is what makes this
sound: the fused detect-and-recolor pass tolerates stale cross-shard colors,
so a round may read ghost colors one exchange old and the next round's
detect repairs any conflict it caused (core/distributed.py docstring).

The differential bar that keeps this honest: on a 1-shard mesh the whole
stack — encode, from-scratch solve, wave-applied updates, frontier-compacted
repair, cap doubling — replays the single-device ``mode="incremental"``
engine bit-for-bit.  That works because ``block_partition`` threads the same
numpy stream ``prepare`` draws from, ``build_halo_mutable`` reproduces the
mutable encode exactly, the sharded loops in ``core/distributed.py`` mirror
the single-device carry schedules, and ``delta.plan_group(directed=True)``
dedups a routed batch to the same wave set ``plan_updates`` emits.

Routing (host side): an undirected update (u, v) becomes two *directed*
slot-space mutations, one per owning shard — (u_loc, slot-of-v-in-u's-shard)
and (v_loc, slot-of-u-in-v's-shard).  Cross-shard targets resolve through
the ghost table; inserts allocate ghost/boundary slots append-only (existing
ghost pointers never move), and a batch that outgrows the slack capacity
re-plans the halo once (``sharded.replan`` counter) with doubled caps —
colors and priorities are per-vertex, so a re-plan never perturbs them.

Budget exhaustion degrades through the same ladder as the single-device
engine (``resilience/ladder.py`` dispatches here): rung 1 re-encodes the
updated graph from scratch through ``api.color``'s front door, rung 2 is
the serial oracle + pure encode.  Rung attribution is preserved verbatim.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro import obs, registry
from repro.core import coloring as col
from repro.core import distributed as dist
from repro.core import frontier
from repro.core import partition as part_mod
from repro.core.context import PassContext
from repro.dynamic import delta
from repro.dynamic.incremental import _check_edges
from repro.graphs.csr import CSRGraph, FILL, from_edges, to_edge_list
from repro.resilience import faults
from repro.resilience.errors import CapRetryExhausted, OvfGrowthExhausted


# --------------------------------------------------------------------------
# state
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedColoringState:
    """Device-resident sharded mutable-graph coloring state.

    Device arrays carry a leading shard axis; ``boundary`` / ``ghost_*``
    halo metadata is authoritative on the host (it changes only on slot
    allocation and re-plan, both host decisions) and is shipped to the
    device per repair call — these arrays are boundary-sized, not n-sized.
    Immutable-by-convention exactly like ``DynamicColoringState``: every
    batch returns a new state, so service snapshot/rollback is free.
    """

    # -- device arrays (leading axis = shard) -------------------------------
    ell: jnp.ndarray          # (D, n_loc, W) slot-space neighbors, FILL pad
    ovf_src: jnp.ndarray      # (D, ovf_cap) overflow COO local rows
    ovf_dst: jnp.ndarray      # (D, ovf_cap) overflow COO slot targets
    pri_tab: jnp.ndarray      # (D, n_tab) priorities: local rows + ghost tail
    colors_tab: jnp.ndarray   # (D, n_tab) colors: local rows + ghost tail
    # -- host halo metadata (copy-on-write) ---------------------------------
    boundary: np.ndarray      # (D, max_b_cap) int32 local slots, FILL pad
    n_boundary: np.ndarray    # (D,) live boundary slots
    ghost_ids: np.ndarray     # (D, max_g_cap) int64 global relabeled ids
    ghost_flat: np.ndarray    # (D, max_g_cap) int32 owner*max_b_cap + slot
    n_ghost: np.ndarray       # (D,) live ghost slots
    # -- geometry / statics -------------------------------------------------
    n: int
    blk: int                  # shard-membership block size (v // blk)
    n_loc: int                # chunk-aligned row-table height per shard
    n_shards: int
    mesh: object              # jax.sharding.Mesh (hashable jit-cache key)
    axis: str
    C: int
    n_chunks: int
    frontier_cap: int         # per-shard compacted-frontier capacity
    delta_cap: int
    ell_cap: int              # encode parameters, persisted for re-plans
    ell_slack: int
    perm: np.ndarray          # old id -> relabeled id
    inv_perm: np.ndarray      # relabeled id -> old id
    pri_global: np.ndarray    # (n,) priority of each relabeled id
    row_of: np.ndarray        # (n,) relabeled id -> flat row d*n_loc + slot
    forbidden_impl: str = "bitset"
    max_rounds: int = 1000
    version: int = 0
    last_rounds: int = 0
    last_conflicts: int = 0
    last_gather_passes: int = 0
    total_gather_passes: int = 0
    retries: int = 0
    ovf_grows: int = 0
    replans: int = 0              # cumulative halo re-plans
    last_halo_bytes: int = 0      # collective payload bytes of the last step
    total_halo_bytes: int = 0
    max_cap_retries: Optional[int] = None
    max_ovf_growth: Optional[int] = None
    last_degrade_rung: int = 0

    # -- derived geometry ---------------------------------------------------

    @property
    def n_tab(self) -> int:
        return int(self.colors_tab.shape[1])

    @property
    def max_b_cap(self) -> int:
        return int(self.boundary.shape[1])

    @property
    def max_g_cap(self) -> int:
        return int(self.ghost_flat.shape[1])

    @property
    def halo_bytes_per_round(self) -> int:
        """One exchange's payload: (boundary colors + 3 scalars) int32 per
        shard, all_gathered — the O(boundary) claim, as a number."""
        return self.n_shards * (self.max_b_cap + 3) * 4

    # -- views --------------------------------------------------------------

    @property
    def colors_dev(self) -> jnp.ndarray:
        """Device color table (the service's sync handle)."""
        return self.colors_tab

    @property
    def colors(self) -> np.ndarray:
        """Current coloring over original vertex ids."""
        flat = np.asarray(self.colors_tab[:, :self.n_loc]).reshape(-1)
        return flat[self.row_of[self.perm[:self.n]]]

    @property
    def n_colors(self) -> int:
        return col.n_colors_used(self.colors)

    def summary(self) -> dict:
        return {"version": self.version, "colors": self.n_colors,
                "rounds": self.last_rounds,
                "conflicts": self.last_conflicts,
                "gather_passes": self.last_gather_passes,
                "total_gather_passes": self.total_gather_passes,
                "final_C": self.C, "retries": self.retries,
                "ovf_grows": self.ovf_grows,
                "degrade_rung": self.last_degrade_rung,
                "ovf_load": delta.overflow_load(self.ovf_src),
                "n_shards": self.n_shards,
                "halo_bytes_per_round": self.halo_bytes_per_round,
                "last_halo_bytes": self.last_halo_bytes,
                "replans": self.replans}

    def to_csr(self) -> CSRGraph:
        """Decode the live slot-space edge set back to a host CSRGraph over
        original ids (``delta.state_to_csr`` dispatches here)."""
        D, n_loc, blk = self.n_shards, self.n_loc, self.blk
        ell = np.asarray(self.ell)
        osrc = np.asarray(self.ovf_src)
        odst = np.asarray(self.ovf_dst)
        srcs, dsts = [], []
        for d in range(D):
            row, slot = np.nonzero(ell[d] >= 0)
            tgt = ell[d][row, slot].astype(np.int64)
            live = (osrc[d] >= 0) & (odst[d] >= 0)
            row = np.concatenate([row.astype(np.int64),
                                  osrc[d][live].astype(np.int64)])
            tgt = np.concatenate([tgt, odst[d][live].astype(np.int64)])
            ghost = tgt >= n_loc
            gidx = np.clip(tgt - n_loc, 0, self.max_g_cap - 1)
            srcs.append(row + d * blk)
            dsts.append(np.where(ghost, self.ghost_ids[d][gidx],
                                 tgt + d * blk))
        edges = np.stack([np.concatenate(srcs), np.concatenate(dsts)],
                         axis=1)
        # cross-shard edges appear once per direction (one per owning
        # shard); symmetrize dedups the union back to the undirected set
        return from_edges(self.n, self.inv_perm[edges], symmetrize=True)


# --------------------------------------------------------------------------
# geometry helpers
# --------------------------------------------------------------------------

def _mesh_size(mesh, axis: str) -> int:
    return int(np.prod([mesh.shape[a] for a in axis.split(",")]))


def _aligned_n_loc(n: int, D: int, n_chunks: int) -> int:
    """Per-shard row-table height: the block size rounded up so every
    shard's sweep divides into n_chunks (at D=1 this IS ``prepare``'s
    n_pad, which the bit-identity bar depends on)."""
    blk = -(-n // D)
    return -(-max(blk, n_chunks) // n_chunks) * n_chunks


def _valid_mask(n: int, D: int, blk: int, n_loc: int) -> np.ndarray:
    valid = np.zeros((D, n_loc), bool)
    for d in range(D):
        k = min(blk, n - d * blk)
        if k > 0:
            valid[d, :k] = True
    return valid


def _row_of(n: int, D: int, blk: int, n_loc: int) -> np.ndarray:
    v = np.arange(n, dtype=np.int64)
    d = np.minimum(v // blk, D - 1)
    return d * n_loc + (v - d * blk)


def _pri_table(pri_global: np.ndarray, plan, n: int, D: int,
               blk: int) -> np.ndarray:
    """(D, n_tab) priority table: local rows then ghost tail.  Ghost
    priorities ride in-table because the fused detect's asymmetric
    tie-break reads the *neighbor's* priority through the same gather as
    its color."""
    n_tab = plan.n_loc + plan.max_g_cap
    pri = np.full((D, n_tab), -1, np.int32)
    for d in range(D):
        lo, hi = d * blk, min((d + 1) * blk, n)
        if hi > lo:
            pri[d, :hi - lo] = pri_global[lo:hi]
        ng = int(plan.n_ghost[d])
        if ng:
            pri[d, plan.n_loc:plan.n_loc + ng] = \
                pri_global[plan.ghost_ids[d, :ng]]
    return pri


# --------------------------------------------------------------------------
# encode + from-scratch solve
# --------------------------------------------------------------------------

def _solve_scratch(state_like, ell, osrc, odst, pri_tab, valid, boundary,
                   ghost_flat, *, n, n_loc, D, mesh, axis, C0, n_chunks,
                   impl, max_rounds, max_cap_retries):
    """Run the sharded from-scratch loop under the shared cap-doubling
    retry.  Returns ((colors_tab, r, trace, tot, ovf), C, retries)."""
    max_b = int(boundary.shape[1])
    max_g = int(ghost_flat.shape[1])
    ellj = jnp.asarray(ell).reshape(D * n_loc, -1)
    osrcj = jnp.asarray(osrc).reshape(-1)
    odstj = jnp.asarray(odst).reshape(-1)
    prij = jnp.asarray(pri_tab).reshape(-1)
    validj = jnp.asarray(valid).reshape(-1)
    boundj = jnp.asarray(boundary).reshape(-1)
    ghostj = jnp.asarray(ghost_flat).reshape(-1)

    def run(C):
        ctx = PassContext(n=n, n_pad=n_loc * D, C=C, n_chunks=n_chunks,
                          forbidden_impl=impl)
        fn = dist.build_sharded_scratch(mesh, axis, D, n_loc, max_b, max_g,
                                        ctx, max_rounds)
        return fn(ellj, osrcj, odstj, prij, validj, boundj, ghostj)

    return col._run_with_retry(run, C0, engine="sharded",
                               max_retries=max_cap_retries)


def sharded_state(g: CSRGraph, mesh, axis: str = "data", seed: int = 0,
                  n_chunks: int = 16, ell_cap: int = 512,
                  C: Optional[int] = None, ell_slack: int = 4,
                  ovf_cap: Optional[int] = None, delta_cap: int = 2048,
                  frontier_frac: float = 0.125, max_rounds: int = 1000,
                  forbidden_impl: Optional[str] = None,
                  max_cap_retries: Optional[int] = None,
                  max_ovf_growth: Optional[int] = None
                  ) -> ShardedColoringState:
    """Partition + encode ``g`` over ``mesh`` and color it from scratch
    once (one halo exchange per round).

    The RNG stream is shared between the partition shuffle and the
    priority draw in ``prepare``'s order, so a 1-shard mesh reproduces the
    single-device ``dynamic_state`` encode — and therefore its colors —
    bit-for-bit.
    """
    impl = col._resolve_impl(forbidden_impl)
    D = _mesh_size(mesh, axis)
    rng = np.random.default_rng(seed)
    with obs.phase("prepare"):
        part = part_mod.block_partition(g, D, rng=rng)       # rng draw 1
        blk = part.n_loc
        n = part.n
        n_loc = _aligned_n_loc(n, D, n_chunks)
        plan = part_mod.build_halo_mutable(
            part, n_loc=n_loc, ell_cap=ell_cap, ell_slack=ell_slack,
            ovf_cap=ovf_cap, delta_cap=delta_cap)
        pri_global = rng.permutation(n).astype(np.int32)     # rng draw 2
        pri_tab = _pri_table(pri_global, plan, n, D, blk)
        valid = _valid_mask(n, D, blk, n_loc)
        C0 = col._pick_C(part.graph, C)

    (tab, r, trace, tot, _), final_C, retries = _solve_scratch(
        None, plan.ell_local, plan.ovf_src, plan.ovf_dst, pri_tab, valid,
        plan.boundary, plan.ghost_flat, n=n, n_loc=n_loc, D=D, mesh=mesh,
        axis=axis, C0=C0, n_chunks=n_chunks, impl=impl,
        max_rounds=max_rounds, max_cap_retries=max_cap_retries)

    n_tab = n_loc + plan.max_g_cap
    hb = (1 + int(r)) * D * (plan.max_b_cap + 3) * 4
    return ShardedColoringState(
        ell=jnp.asarray(plan.ell_local),
        ovf_src=jnp.asarray(plan.ovf_src),
        ovf_dst=jnp.asarray(plan.ovf_dst),
        pri_tab=jnp.asarray(pri_tab),
        colors_tab=jnp.asarray(tab).reshape(D, n_tab),
        boundary=plan.boundary, n_boundary=plan.n_boundary,
        ghost_ids=plan.ghost_ids, ghost_flat=plan.ghost_flat,
        n_ghost=plan.n_ghost,
        n=n, blk=blk, n_loc=n_loc, n_shards=D, mesh=mesh, axis=axis,
        C=final_C, n_chunks=n_chunks,
        frontier_cap=frontier.frontier_cap(n_loc, n_chunks, frontier_frac),
        delta_cap=int(delta_cap), ell_cap=int(ell_cap),
        ell_slack=int(ell_slack),
        perm=part.perm, inv_perm=np.argsort(part.perm),
        pri_global=pri_global, row_of=_row_of(n, D, blk, n_loc),
        forbidden_impl=impl, max_rounds=int(max_rounds),
        version=0, last_rounds=int(r), last_conflicts=int(tot),
        last_gather_passes=1 + int(r), total_gather_passes=1 + int(r),
        retries=retries, ovf_grows=0, replans=0,
        last_halo_bytes=hb, total_halo_bytes=hb,
        max_cap_retries=max_cap_retries, max_ovf_growth=max_ovf_growth)


# --------------------------------------------------------------------------
# routing: undirected updates -> per-shard directed slot-space mutations
# --------------------------------------------------------------------------

class _Replan(Exception):
    """A batch outgrew the boundary/ghost slack; carries the per-shard
    capacities the re-planned halo must cover."""

    def __init__(self, need_b: int, need_g: int):
        self.need_b, self.need_g = int(need_b), int(need_g)


def _route(state: ShardedColoringState, ins_r: np.ndarray,
           dels_r: np.ndarray):
    """Route relabeled-space undirected pairs to their owning shards.

    Returns ``(batches, alloc)``: ``batches[d]`` is shard d's directed
    ``(ins, dels)`` slot-space pairs for ``delta.plan_group``, ``alloc``
    the append-only ghost/boundary slot allocations to commit.  Allocation
    is unbounded here — capacity is checked once at the end so a single
    ``_Replan`` covers the whole batch's need.

    A delete whose remote endpoint is not in the ghost table is a no-op on
    that shard (the edge cannot be present); it is routed as a (row, row)
    self-pair, which every wave kernel ignores but which still seeds the
    repair frontier — mirroring the single-device treatment of deletes of
    absent edges.
    """
    D, blk, n_loc = state.n_shards, state.blk, state.n_loc
    max_b, max_g = state.max_b_cap, state.max_g_cap
    gmap = [
        {int(v): i
         for i, v in enumerate(state.ghost_ids[d, :int(state.n_ghost[d])])}
        for d in range(D)]
    bmap = [
        {int(state.boundary[d, j]) + d * blk: j
         for j in range(int(state.n_boundary[d]))}
        for d in range(D)]
    n_b = [int(x) for x in state.n_boundary]
    n_g = [int(x) for x in state.n_ghost]
    new_bnd = [[] for _ in range(D)]       # new boundary local slots
    new_gst = [[] for _ in range(D)]       # (global id, flat pointer)
    ins_sh = [[] for _ in range(D)]
    del_sh = [[] for _ in range(D)]

    def boundary_slot(owner: int, v: int) -> int:
        j = bmap[owner].get(v)
        if j is None:
            j = n_b[owner]
            n_b[owner] += 1
            bmap[owner][v] = j
            new_bnd[owner].append(v - owner * blk)
        return j

    def ghost_slot(d: int, owner: int, v: int) -> int:
        i = gmap[d].get(v)
        if i is None:
            j = boundary_slot(owner, v)
            i = n_g[d]
            n_g[d] += 1
            gmap[d][v] = i
            new_gst[d].append((v, owner * max_b + j))
        return n_loc + i

    def shard(v: int) -> int:
        return min(v // blk, D - 1)

    for u, v in ins_r:
        u, v = int(u), int(v)
        du, dv = shard(u), shard(v)
        if u == v:
            # self-pair: dropped from insert waves, still seeds the repair
            ins_sh[du].append((u - du * blk, u - du * blk))
            continue
        tu = (v - du * blk) if dv == du else ghost_slot(du, dv, v)
        ins_sh[du].append((u - du * blk, tu))
        tv = (u - dv * blk) if du == dv else ghost_slot(dv, du, u)
        ins_sh[dv].append((v - dv * blk, tv))
    for u, v in dels_r:
        u, v = int(u), int(v)
        du, dv = shard(u), shard(v)
        if u == v:
            del_sh[du].append((u - du * blk, u - du * blk))
            continue
        gi = gmap[du].get(v) if dv != du else None
        tu = ((v - du * blk) if dv == du
              else (n_loc + gi if gi is not None else u - du * blk))
        del_sh[du].append((u - du * blk, tu))
        gj = gmap[dv].get(u) if du != dv else None
        tv = ((u - dv * blk) if du == dv
              else (n_loc + gj if gj is not None else v - dv * blk))
        del_sh[dv].append((v - dv * blk, tv))

    if max(n_b) > max_b or max(n_g) > max_g:
        raise _Replan(max(n_b), max(n_g))

    def pairs(lst):
        return (np.asarray(lst, np.int32).reshape(-1, 2) if lst
                else np.zeros((0, 2), np.int32))

    batches = [(pairs(ins_sh[d]), pairs(del_sh[d])) for d in range(D)]
    return batches, (new_bnd, new_gst, n_b, n_g)


def _commit_alloc(state: ShardedColoringState, alloc):
    """Append routed slot allocations to the host halo tables and scatter
    the new ghosts' priorities into the device table.  Returns the fields
    to replace (no-op fast path when the batch allocated nothing)."""
    new_bnd, new_gst, n_b, n_g = alloc
    if not any(new_bnd) and not any(new_gst):
        return {}
    D, n_loc = state.n_shards, state.n_loc
    boundary = state.boundary.copy()
    n_boundary = state.n_boundary.copy()
    ghost_ids = state.ghost_ids.copy()
    ghost_flat = state.ghost_flat.copy()
    n_ghost = state.n_ghost.copy()
    pri_tab = state.pri_tab
    for d in range(D):
        if new_bnd[d]:
            j0 = int(state.n_boundary[d])
            boundary[d, j0:n_b[d]] = np.asarray(new_bnd[d], np.int32)
            n_boundary[d] = n_b[d]
        if new_gst[d]:
            i0 = int(state.n_ghost[d])
            ids = np.asarray([v for v, _ in new_gst[d]], np.int64)
            flats = np.asarray([f for _, f in new_gst[d]], np.int32)
            ghost_ids[d, i0:n_g[d]] = ids
            ghost_flat[d, i0:n_g[d]] = flats
            n_ghost[d] = n_g[d]
            # new ghost slots need priorities before the next detect; their
            # colors stay -1 — the repair's up-front exchange freshens them
            pri_tab = pri_tab.at[d, n_loc + i0:n_loc + n_g[d]].set(
                jnp.asarray(state.pri_global[ids]))
    return dict(boundary=boundary, n_boundary=n_boundary,
                ghost_ids=ghost_ids, ghost_flat=ghost_flat, n_ghost=n_ghost,
                pri_tab=pri_tab)


def _replan(state: ShardedColoringState, need_b: int,
            need_g: int) -> ShardedColoringState:
    """Rebuild the halo plan of the *current* graph with doubled (and
    need-covering) boundary/ghost capacity.

    The partition geometry — perm, blk, n_loc — is preserved, so colors and
    priorities (per-vertex quantities) carry over untouched; only the
    slot-space tables are re-derived.  Re-encoding also compacts stale
    ghost/boundary slots left behind by deletes.  Not a version bump: the
    served coloring is unchanged."""
    from repro.obs import metrics as obs_metrics

    D, blk, n_loc, n = state.n_shards, state.blk, state.n_loc, state.n
    g_rel = from_edges(n, state.perm[to_edge_list(state.to_csr())
                                     .astype(np.int64)], symmetrize=False)
    part = part_mod.Partition(n=n, n_pad=blk * D, n_shards=D, n_loc=blk,
                              perm=state.perm, graph=g_rel)
    plan = part_mod.build_halo_mutable(
        part, n_loc=n_loc, ell_cap=max(state.ell_cap,
                                       int(state.ell.shape[2])),
        ell_slack=state.ell_slack,
        ovf_cap=int(state.ovf_src.shape[1]), delta_cap=state.delta_cap,
        min_b_cap=max(2 * state.max_b_cap, part_mod._slack_cap(need_b)),
        min_g_cap=max(2 * state.max_g_cap, part_mod._slack_cap(need_g)))
    n_tab = n_loc + plan.max_g_cap
    pri_tab = _pri_table(state.pri_global, plan, n, D, blk)
    colors_tab = np.full((D, n_tab), -1, np.int32)
    colors_tab[:, :n_loc] = np.asarray(state.colors_tab[:, :n_loc])
    for d in range(D):          # ghost colors: fresh from their owners
        ng = int(plan.n_ghost[d])
        if ng:
            ids = plan.ghost_ids[d, :ng]
            flat = np.asarray(state.colors_tab[:, :n_loc]).reshape(-1)
            colors_tab[d, n_loc:n_loc + ng] = flat[state.row_of[ids]]
    obs_metrics.counter("sharded.replan").inc()
    return dataclasses.replace(
        state, ell=jnp.asarray(plan.ell_local),
        ovf_src=jnp.asarray(plan.ovf_src),
        ovf_dst=jnp.asarray(plan.ovf_dst),
        pri_tab=jnp.asarray(pri_tab),
        colors_tab=jnp.asarray(colors_tab),
        boundary=plan.boundary, n_boundary=plan.n_boundary,
        ghost_ids=plan.ghost_ids, ghost_flat=plan.ghost_flat,
        n_ghost=plan.n_ghost, replans=state.replans + 1)


# --------------------------------------------------------------------------
# update application + repair
# --------------------------------------------------------------------------

def _grow_overflow_b(osrc_b, odst_b, factor: int = 2):
    """Uniform per-shard overflow growth (same cap math as
    ``delta.grow_overflow``, applied along axis 1 so every shard keeps the
    same buffer shape — a jit-static requirement of the stacked kernels)."""
    D, cap = osrc_b.shape
    extra = jnp.full((D, max(cap, 8) * (factor - 1)), FILL, jnp.int32)
    return (jnp.concatenate([osrc_b, extra], axis=1),
            jnp.concatenate([odst_b, extra], axis=1))


def _apply_waves(state: ShardedColoringState, batches):
    """Delete-then-insert wave application across all shards in lockstep
    (one stacked dispatch per wave), with the uniform grow-and-retry loop
    of ``delta.apply_updates``.  Returns (ell, osrc, odst, U, grows)."""
    n_tab = state.n_tab
    ovf_w, ell_w, ins_w, touched = delta.plan_group(
        batches, state.delta_cap, n_tab, directed=True)
    ell_b = state.ell
    osrc_b, odst_b = state.ovf_src, state.ovf_dst
    for j in range(ovf_w.shape[0]):
        osrc_b, odst_b = delta._mega_delete_overflow(
            osrc_b, odst_b, jnp.asarray(ovf_w[j]))
    for j in range(ell_w.shape[0]):
        ell_b = delta._mega_delete_ell_wave(ell_b, jnp.asarray(ell_w[j]))
    grows = 0
    n_ins = int(ins_w.shape[0])
    if n_ins:
        ss, ds = delta._mega_sort_overflow(osrc_b, odst_b)
    for j in range(n_ins):
        w = jnp.asarray(ins_w[j])
        while True:
            ell2, osrc2, odst2, fail = delta._mega_insert_wave(
                ell_b, osrc_b, odst_b, ss, ds, w)
            if not bool(np.asarray(fail).any()):
                ell_b, osrc_b, odst_b = ell2, osrc2, odst2
                break
            if (state.max_ovf_growth is not None
                    and grows >= state.max_ovf_growth):
                raise OvfGrowthExhausted(grows=grows,
                                         budget=state.max_ovf_growth,
                                         cap=int(osrc2.shape[1]))
            # grown buffer holds this wave's partial spills: keep it, retake
            # the presence snapshot, re-apply the same wave (idempotent)
            osrc_b, odst_b = _grow_overflow_b(osrc2, odst2)
            ell_b = ell2
            grows += 1
            ss, ds = delta._mega_sort_overflow(osrc_b, odst_b)
    return ell_b, osrc_b, odst_b, touched[:, :state.n_loc], grows


def recolor_sharded(state: ShardedColoringState, inserts=None, deletes=None,
                    max_rounds: Optional[int] = None
                    ) -> ShardedColoringState:
    """Apply an undirected edge update batch and repair the sharded
    coloring — one collective per repair round, bytes ∝ boundary.

    ``inserts`` / ``deletes`` are (k, 2) arrays of *original* vertex ids;
    deletes apply before inserts.  Returns a new state; the input state is
    untouched.  On a 1-shard mesh this is bit-identical to
    ``recolor_incremental`` on the matching single-device state.
    """
    if max_rounds is None:
        max_rounds = state.max_rounds
    ins = _check_edges(inserts if inserts is not None else [], state.n,
                       "inserts")
    dels = _check_edges(deletes if deletes is not None else [], state.n,
                        "deletes")
    if len(ins) == 0 and len(dels) == 0:
        return state
    if faults.fires("ovf.exhaust"):
        raise OvfGrowthExhausted(grows=0, budget=state.max_ovf_growth,
                                 cap=int(state.ovf_src.shape[1]),
                                 forced=True)

    ins_r = state.perm[ins] if len(ins) else ins
    dels_r = state.perm[dels] if len(dels) else dels
    try:
        batches, alloc = _route(state, ins_r, dels_r)
    except _Replan as rp:
        state = _replan(state, rp.need_b, rp.need_g)
        batches, alloc = _route(state, ins_r, dels_r)
    repl = _commit_alloc(state, alloc)
    if repl:
        state = dataclasses.replace(state, **repl)
    ell_b, osrc_b, odst_b, U, grows = _apply_waves(state, batches)

    D, n_loc = state.n_shards, state.n_loc
    max_b, max_g = state.max_b_cap, state.max_g_cap
    validj = jnp.asarray(_valid_mask(state.n, D, state.blk, n_loc)
                         ).reshape(-1)
    boundj = jnp.asarray(state.boundary).reshape(-1)
    ghostj = jnp.asarray(state.ghost_flat).reshape(-1)
    prij = state.pri_tab.reshape(-1)
    colj = state.colors_tab.reshape(-1)
    Uj = jnp.asarray(U).reshape(-1)
    ellj = ell_b.reshape(D * n_loc, -1)
    osrcj = osrc_b.reshape(-1)
    odstj = odst_b.reshape(-1)

    def run(C):
        ctx = PassContext(n=state.n, n_pad=n_loc * D, C=C,
                          n_chunks=state.n_chunks,
                          forbidden_impl=state.forbidden_impl)
        fn = dist.build_sharded_repair(state.mesh, state.axis, D, n_loc,
                                       max_b, max_g, ctx,
                                       state.frontier_cap, max_rounds)
        return fn(ellj, osrcj, odstj, prij, colj, Uj, validj, boundj,
                  ghostj)

    (tab, r, trace, tot, _), C, retries = col._run_with_retry(
        run, state.C, engine="sharded", max_retries=state.max_cap_retries)
    passes = int(r)
    # collectives: one up-front ghost refresh + one per repair round
    hb = (1 + passes) * state.halo_bytes_per_round
    return dataclasses.replace(
        state, ell=ell_b, ovf_src=osrc_b, ovf_dst=odst_b,
        colors_tab=jnp.asarray(tab).reshape(D, state.n_tab),
        C=C, version=state.version + 1, last_rounds=passes,
        last_conflicts=int(tot), last_gather_passes=passes,
        total_gather_passes=state.total_gather_passes + passes,
        retries=state.retries + retries, ovf_grows=state.ovf_grows + grows,
        last_halo_bytes=hb, total_halo_bytes=state.total_halo_bytes + hb,
        last_degrade_rung=0)


# --------------------------------------------------------------------------
# degradation-ladder rungs (dispatched from resilience/ladder.py)
# --------------------------------------------------------------------------

def scratch_sharded(state: ShardedColoringState, inserts=None,
                    deletes=None) -> ShardedColoringState:
    """Rung 1: re-encode + recolor the updated graph through the
    ``api.color`` front door on the tenant's own mesh, inheriting its
    statics and budgets.  Mirrors ``ladder.scratch_state``, including the
    rung attribution when the engine itself had to drop to the oracle."""
    from repro import api
    from repro.resilience.ladder import updated_graph

    empty = np.zeros((0, 2), np.int64)
    g2 = updated_graph(state, empty if inserts is None else inserts,
                       empty if deletes is None else deletes)
    res = api.color(
        g2, mode="incremental", backend="distributed", mesh=state.mesh,
        axis=state.axis, seed=0, n_chunks=state.n_chunks,
        ell_cap=int(state.ell.shape[2]), ell_slack=0, C=None,
        ovf_cap=int(state.ovf_src.shape[1]), delta_cap=state.delta_cap,
        max_rounds=state.max_rounds, forbidden_impl=state.forbidden_impl,
        max_cap_retries=state.max_cap_retries,
        max_ovf_growth=state.max_ovf_growth)
    st = res.state
    rung = 2 if st.last_degrade_rung == 2 else 1
    return dataclasses.replace(
        st, version=state.version + 1, last_degrade_rung=rung,
        retries=state.retries + st.retries, ovf_grows=state.ovf_grows,
        replans=state.replans,
        total_gather_passes=(state.total_gather_passes
                             + st.total_gather_passes),
        total_halo_bytes=state.total_halo_bytes + st.total_halo_bytes)


def oracle_sharded(state: ShardedColoringState, inserts=None,
                   deletes=None) -> ShardedColoringState:
    """Rung 2: serial First-Fit on the host + pure sharded encode — no
    device coloring loop, no collective, nothing left to exhaust."""
    from repro.resilience.ladder import updated_graph

    empty = np.zeros((0, 2), np.int64)
    g2 = updated_graph(state, empty if inserts is None else inserts,
                       empty if deletes is None else deletes)
    st = encode_oracle_sharded(
        g2, state.mesh, axis=state.axis, seed=0, n_chunks=state.n_chunks,
        ell_cap=int(state.ell.shape[2]), ell_slack=0,
        ovf_cap=int(state.ovf_src.shape[1]), delta_cap=state.delta_cap,
        max_rounds=state.max_rounds, forbidden_impl=state.forbidden_impl,
        max_cap_retries=state.max_cap_retries,
        max_ovf_growth=state.max_ovf_growth)
    return dataclasses.replace(
        st, version=state.version + 1, retries=state.retries,
        ovf_grows=state.ovf_grows, replans=state.replans,
        total_gather_passes=state.total_gather_passes,
        total_halo_bytes=state.total_halo_bytes)


def encode_oracle_sharded(g: CSRGraph, mesh, axis: str = "data", *,
                          seed: int = 0, n_chunks: int = 16,
                          ell_cap: int = 512, ell_slack: int = 4,
                          ovf_cap: Optional[int] = None,
                          delta_cap: int = 2048,
                          frontier_frac: float = 0.125,
                          max_rounds: int = 1000,
                          forbidden_impl: Optional[str] = None,
                          max_cap_retries: Optional[int] = None,
                          max_ovf_growth: Optional[int] = None
                          ) -> ShardedColoringState:
    """Serial-oracle colors + the standard sharded encode of ``g`` — the
    sharded counterpart of ``ladder.encode_oracle_state``.  The RNG stream
    is threaded exactly like ``sharded_state`` so the layout (and any later
    1-shard differential run) is deterministic."""
    impl = col._resolve_impl(forbidden_impl)
    D = _mesh_size(mesh, axis)
    colors = col.greedy_sequential(g)
    rng = np.random.default_rng(seed)
    part = part_mod.block_partition(g, D, rng=rng)           # rng draw 1
    blk, n = part.n_loc, part.n
    n_loc = _aligned_n_loc(n, D, n_chunks)
    plan = part_mod.build_halo_mutable(
        part, n_loc=n_loc, ell_cap=ell_cap, ell_slack=ell_slack,
        ovf_cap=ovf_cap, delta_cap=delta_cap)
    pri_global = rng.permutation(n).astype(np.int32)         # rng draw 2
    pri_tab = _pri_table(pri_global, plan, n, D, blk)
    row_of = _row_of(n, D, blk, n_loc)

    colors_rel = np.full((n,), -1, np.int32)
    colors_rel[part.perm] = colors
    n_tab = n_loc + plan.max_g_cap
    colors_tab = np.full((D, n_tab), -1, np.int32)
    for d in range(D):
        lo, hi = d * blk, min((d + 1) * blk, n)
        if hi > lo:
            colors_tab[d, :hi - lo] = colors_rel[lo:hi]
        ng = int(plan.n_ghost[d])
        if ng:
            colors_tab[d, n_loc:n_loc + ng] = \
                colors_rel[plan.ghost_ids[d, :ng]]
    n_used = int(colors.max()) + 1 if len(colors) else 1
    C = max(32, -(-n_used // 32) * 32)   # headroom for future repairs
    return ShardedColoringState(
        ell=jnp.asarray(plan.ell_local),
        ovf_src=jnp.asarray(plan.ovf_src),
        ovf_dst=jnp.asarray(plan.ovf_dst),
        pri_tab=jnp.asarray(pri_tab),
        colors_tab=jnp.asarray(colors_tab),
        boundary=plan.boundary, n_boundary=plan.n_boundary,
        ghost_ids=plan.ghost_ids, ghost_flat=plan.ghost_flat,
        n_ghost=plan.n_ghost,
        n=n, blk=blk, n_loc=n_loc, n_shards=D, mesh=mesh, axis=axis,
        C=C, n_chunks=n_chunks,
        frontier_cap=frontier.frontier_cap(n_loc, n_chunks, frontier_frac),
        delta_cap=int(delta_cap), ell_cap=int(ell_cap),
        ell_slack=int(ell_slack),
        perm=part.perm, inv_perm=np.argsort(part.perm),
        pri_global=pri_global, row_of=row_of,
        forbidden_impl=impl, max_rounds=int(max_rounds), version=0,
        max_cap_retries=max_cap_retries, max_ovf_growth=max_ovf_growth,
        last_degrade_rung=2)


# --------------------------------------------------------------------------
# registry adapter: (rsoc, 1, incremental, distributed) through repro.api
# --------------------------------------------------------------------------

@registry.register_engine("rsoc", distance=1, mode="incremental",
                          backend="distributed", replaces="sharded_state")
def _sharded_engine(g: CSRGraph, spec, *, mesh=None,
                    axis: str = "data") -> col.ColoringResult:
    """Encode ``g`` over the mesh and color it from scratch once; the
    ``ShardedColoringState`` rides the result's ``state`` field so the
    ``ColoringService`` keeps applying ``recolor_sharded`` batches to it.

    Like the single-device incremental engine, a from-scratch solve that
    exhausts a finite ``spec.max_cap_retries`` drops straight to the serial
    oracle encode (rung 2) instead of failing the add."""
    if mesh is None:
        raise ValueError(
            "backend='distributed' requires a device mesh: "
            "repro.api.color(g, spec, mesh=<jax.sharding.Mesh>)")
    try:
        st = sharded_state(
            g, mesh, axis=axis, seed=spec.seed, n_chunks=spec.n_chunks,
            ell_cap=spec.ell_cap, C=spec.C, ell_slack=spec.ell_slack,
            ovf_cap=spec.ovf_cap, delta_cap=spec.delta_cap,
            frontier_frac=spec.frontier_frac, max_rounds=spec.max_rounds,
            forbidden_impl=spec.forbidden_impl,
            max_cap_retries=spec.max_cap_retries,
            max_ovf_growth=spec.max_ovf_growth)
    except CapRetryExhausted:
        from repro.obs import metrics as _metrics
        _metrics.counter("resilience.degrade", rung="oracle").inc()
        st = encode_oracle_sharded(
            g, mesh, axis=axis, seed=spec.seed, n_chunks=spec.n_chunks,
            ell_cap=spec.ell_cap, ell_slack=spec.ell_slack,
            ovf_cap=spec.ovf_cap, delta_cap=spec.delta_cap,
            frontier_frac=spec.frontier_frac, max_rounds=spec.max_rounds,
            forbidden_impl=spec.forbidden_impl,
            max_cap_retries=spec.max_cap_retries,
            max_ovf_growth=spec.max_ovf_growth)
    colors = st.colors
    return col.ColoringResult(
        colors=colors, n_rounds=st.last_rounds,
        conflicts_per_round=np.array([st.last_conflicts]),
        total_conflicts=st.last_conflicts,
        n_colors=col.n_colors_used(colors),
        overflow=st.retries > 0, gather_passes=st.last_gather_passes,
        final_C=st.C, retries=st.retries, distance=1, state=st,
        degrade_rung=st.last_degrade_rung)
