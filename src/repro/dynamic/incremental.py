"""Incremental recoloring for mutating graphs (DESIGN.md §7.2).

``recolor_incremental`` is the paper's fused detect-and-recolor pass turned
into a repair primitive: instead of seeding the defect set U with the whole
vertex set (round 0 of the from-scratch loop), it seeds U with the endpoints
of the edges changed by an update batch.  Properness of the previous coloring
guarantees every post-update conflict lies on an inserted edge, so the seed
set covers all defects; the frontier-compacted repair loop then pays only
O(|U| * W) bytes per round instead of O(n * W).  Termination is the same
asymmetric-priority argument as the static loop (coloring.py docstring): the
highest-priority defective vertex becomes permanently stable every round.

State is immutable-by-convention: every update batch returns a *new*
``DynamicColoringState`` carrying the mutated device arrays, the repaired
colors, a bumped version, and repair statistics.  The previous state remains
valid (arrays are not donated), which gives the service layer cheap
snapshot/rollback semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro import registry
from repro.core import coloring as col
from repro.core import frontier
from repro.core.context import PassContext
from repro.dynamic import delta
from repro.graphs.csr import CSRGraph, FILL
from repro.resilience.errors import CapRetryExhausted
from repro import obs


@dataclasses.dataclass(frozen=True)
class DynamicColoringState:
    """Device-resident mutable-graph coloring state (relabeled space)."""

    ell: jnp.ndarray         # (n_pad, W) neighbor slots, FILL = empty
    ovf_src: jnp.ndarray     # (ovf_cap,) overflow COO, FILL = free slot
    ovf_dst: jnp.ndarray
    pri: jnp.ndarray         # (n_pad,) asymmetric tie-break priorities
    colors_dev: jnp.ndarray  # (n_pad,) current proper coloring
    n: int
    n_pad: int
    C: int                   # color cap (doubles on overflow, persisted)
    n_chunks: int
    frontier_cap: int        # compacted-frontier capacity (rows)
    delta_cap: int           # update-slice width (fixed shape per slice)
    perm: np.ndarray         # old id -> new id
    inv_perm: np.ndarray     # new id -> old id
    forbidden_impl: str = "bitset"  # forbidden-set representation (§10)
    max_rounds: int = 1000          # repair-round bound (from the spec the
                                    # graph was added with; threaded through
                                    # every subsequent repair)
    version: int = 0
    last_rounds: int = 0
    last_conflicts: int = 0
    last_gather_passes: int = 0     # compacted passes of the last repair
    total_gather_passes: int = 0
    retries: int = 0                # cumulative color-cap doublings
    ovf_grows: int = 0              # cumulative overflow-buffer growths
    max_cap_retries: Optional[int] = None  # cap-doubling budget per repair
                                    # (None: unbounded); exhaustion raises
                                    # CapRetryExhausted -> ladder (§14)
    max_ovf_growth: Optional[int] = None   # overflow-growth budget per batch
    last_degrade_rung: int = 0      # ladder rung that produced this state:
                                    # 0 incremental, 1 scratch, 2 oracle

    @property
    def colors(self) -> np.ndarray:
        """Current coloring over original vertex ids."""
        return np.asarray(self.colors_dev)[self.perm[:self.n]]

    @property
    def n_colors(self) -> int:
        return col.n_colors_used(np.asarray(self.colors_dev)[:self.n])

    def summary(self) -> dict:
        return {"version": self.version, "colors": self.n_colors,
                "rounds": self.last_rounds,
                "conflicts": self.last_conflicts,
                "gather_passes": self.last_gather_passes,
                "total_gather_passes": self.total_gather_passes,
                "final_C": self.C, "retries": self.retries,
                "ovf_grows": self.ovf_grows,
                "degrade_rung": self.last_degrade_rung,
                "ovf_load": delta.overflow_load(self.ovf_src)}


def dynamic_state(g: CSRGraph, seed: int = 0, n_chunks: int = 16,
                  ell_cap: int = 512, C: Optional[int] = None,
                  ell_slack: int = 4, ovf_cap: Optional[int] = None,
                  delta_cap: int = 2048, frontier_frac: float = 0.125,
                  max_rounds: int = 1000,
                  forbidden_impl: Optional[str] = None,
                  max_cap_retries: Optional[int] = None,
                  max_ovf_growth: Optional[int] = None
                  ) -> DynamicColoringState:
    """Encode ``g`` for mutation and color it from scratch once.

    ``ell_slack`` free slots are appended to every row so typical inserts
    land in ELL; ``ovf_cap`` sizes the spill buffer (grows on demand).
    ``max_cap_retries`` / ``max_ovf_growth`` are persisted on the state and
    bound every subsequent repair (None: unbounded, the legacy behavior).
    """
    impl = col._resolve_impl(forbidden_impl)
    with obs.phase("prepare"):
        prob = col.prepare(g, seed, n_chunks, ell_cap, C)
    (colors_n, r, trace, tot, _), final_C, retries = col._run_with_retry(
        col._prob_runner(col._rsoc_loop, prob, n_chunks, max_rounds, impl),
        prob.C, engine="incremental", max_retries=max_cap_retries)

    ell_np = np.asarray(prob.ell)
    if ell_slack > 0:
        pad = np.full((ell_np.shape[0], ell_slack), FILL, np.int32)
        ell_np = np.concatenate([ell_np, pad], axis=1)
    n_ovf = int(prob.ovf_src.shape[0])
    cap = int(ovf_cap) if ovf_cap is not None else max(64, 2 * n_ovf,
                                                       delta_cap // 2)
    cap = max(cap, n_ovf, 8)
    osrc = np.full((cap,), FILL, np.int32)
    odst = np.full((cap,), FILL, np.int32)
    osrc[:n_ovf] = np.asarray(prob.ovf_src)
    odst[:n_ovf] = np.asarray(prob.ovf_dst)

    colors_pad = np.full((prob.n_pad,), -1, np.int32)
    colors_pad[:prob.n] = np.asarray(colors_n)
    inv_perm = np.argsort(prob.perm)
    return DynamicColoringState(
        ell=jnp.asarray(ell_np), ovf_src=jnp.asarray(osrc),
        ovf_dst=jnp.asarray(odst), pri=prob.pri,
        colors_dev=jnp.asarray(colors_pad),
        n=prob.n, n_pad=prob.n_pad, C=final_C, n_chunks=n_chunks,
        frontier_cap=frontier.frontier_cap(prob.n_pad, n_chunks,
                                           frontier_frac),
        delta_cap=int(delta_cap), perm=prob.perm, inv_perm=inv_perm,
        forbidden_impl=impl, max_rounds=int(max_rounds),
        version=0, last_rounds=int(r), last_conflicts=int(tot),
        last_gather_passes=1 + int(r), total_gather_passes=1 + int(r),
        retries=retries, ovf_grows=0,
        max_cap_retries=max_cap_retries, max_ovf_growth=max_ovf_growth)


def _check_edges(edges, n: int, what: str, *, tenant: Optional[str] = None,
                 strict: bool = False) -> np.ndarray:
    """Validate a (k, 2) edge batch; returns a defensive int64 copy.

    ``strict`` (the service submit path) additionally rejects non-integer
    dtypes, malformed shapes, and self-loops on inserts, naming the tenant
    in every error so a bad batch is attributable before it is queued.
    """
    who = f"graph {tenant!r}: " if tenant is not None else ""
    if strict:
        raw = np.asarray(edges)
        if raw.size and not np.issubdtype(raw.dtype, np.integer):
            raise ValueError(
                f"{who}{what} must be integer vertex ids "
                f"(got dtype {raw.dtype})")
    # np.array (not asarray): always copy, so a caller reusing its batch
    # buffer cannot mutate edges after validation (service queues them)
    try:
        e = np.array(edges, dtype=np.int64).reshape(-1, 2)
    except (ValueError, TypeError) as exc:
        raise ValueError(
            f"{who}{what} must be a (k, 2) edge array: {exc}") from exc
    if len(e) and (e.min() < 0 or e.max() >= n):
        raise ValueError(f"{who}{what} contains vertex ids outside [0, {n})")
    if strict and what == "inserts" and len(e) and bool((e[:, 0] == e[:, 1]).any()):
        bad = e[e[:, 0] == e[:, 1]][0]
        raise ValueError(
            f"{who}{what} contains self-loop ({int(bad[0])}, {int(bad[1])}); "
            f"self-loops are not colorable edges — filter them out")
    return e


def recolor_incremental(state: DynamicColoringState,
                        inserts=None, deletes=None,
                        max_rounds: Optional[int] = None
                        ) -> DynamicColoringState:
    """Apply an undirected edge update batch and repair the coloring.

    ``inserts`` / ``deletes`` are (k, 2) arrays of *original* vertex ids.
    Deletes are applied before inserts.  Returns a new state whose coloring
    is proper for the mutated graph; the input state is left untouched.
    ``max_rounds`` defaults to the bound persisted on the state (the spec
    the graph was created with); pass an explicit value to override one
    batch without re-persisting it.
    """
    if max_rounds is None:
        max_rounds = state.max_rounds
    ins = _check_edges(inserts if inserts is not None else [], state.n,
                       "inserts")
    dels = _check_edges(deletes if deletes is not None else [], state.n,
                        "deletes")
    if len(ins) == 0 and len(dels) == 0:
        return state

    # host -> relabeled space
    ins_r = state.perm[ins] if len(ins) else ins
    dels_r = state.perm[dels] if len(dels) else dels

    ell, osrc, odst, U, grows = delta.apply_updates(
        state.ell, state.ovf_src, state.ovf_dst, ins_r, dels_r,
        state.delta_cap, max_grows=state.max_ovf_growth)

    # repair: frontier-compacted fused RSOC seeded from touched endpoints
    def run(C):
        ctx = PassContext(n=state.n, n_pad=state.n_pad, C=C,
                          n_chunks=state.n_chunks,
                          forbidden_impl=state.forbidden_impl)
        return frontier._repair_compact_loop(
            ell, osrc, odst, state.pri, state.colors_dev, U, ctx,
            state.frontier_cap, max_rounds)

    (colors2, r, trace, tot, _), C, retries = col._run_with_retry(
        run, state.C, engine="incremental", max_retries=state.max_cap_retries)
    passes = int(r)
    return dataclasses.replace(
        state, ell=ell, ovf_src=osrc, ovf_dst=odst, colors_dev=colors2,
        C=C, version=state.version + 1, last_rounds=int(r),
        last_conflicts=int(tot), last_gather_passes=passes,
        total_gather_passes=state.total_gather_passes + passes,
        retries=state.retries + retries, ovf_grows=state.ovf_grows + grows,
        last_degrade_rung=0)


# --------------------------------------------------------------------------
# registry adapter: mode="incremental" through the repro.api front door
# --------------------------------------------------------------------------

@registry.register_engine("rsoc", distance=1, mode="incremental",
                          replaces="dynamic_state")
def _incremental_engine(g: CSRGraph, spec) -> col.ColoringResult:
    """Encode ``g`` for mutation and color it from scratch once; the
    device-resident ``DynamicColoringState`` rides the result's ``state``
    field so callers (``ColoringService.add_graph``) can keep applying
    ``recolor_incremental`` update batches to it.

    With a finite ``spec.max_cap_retries`` budget the from-scratch solve can
    exhaust its cap doublings; this engine then drops straight to the serial
    oracle encoding (ladder rung 2) rather than failing the add — the
    result's ``degrade_rung`` records the downgrade."""
    try:
        st = dynamic_state(
            g, seed=spec.seed, n_chunks=spec.n_chunks, ell_cap=spec.ell_cap,
            C=spec.C, ell_slack=spec.ell_slack, ovf_cap=spec.ovf_cap,
            delta_cap=spec.delta_cap, frontier_frac=spec.frontier_frac,
            max_rounds=spec.max_rounds, forbidden_impl=spec.forbidden_impl,
            max_cap_retries=spec.max_cap_retries,
            max_ovf_growth=spec.max_ovf_growth)
    except CapRetryExhausted:
        from repro.obs import metrics as _metrics
        from repro.resilience import ladder
        _metrics.counter("resilience.degrade", rung="oracle").inc()
        st = ladder.encode_oracle_state(
            g, seed=spec.seed, n_chunks=spec.n_chunks, ell_cap=spec.ell_cap,
            ell_slack=spec.ell_slack, ovf_cap=spec.ovf_cap,
            delta_cap=spec.delta_cap, frontier_frac=spec.frontier_frac,
            max_rounds=spec.max_rounds, forbidden_impl=spec.forbidden_impl,
            max_cap_retries=spec.max_cap_retries,
            max_ovf_growth=spec.max_ovf_growth)
    colors = st.colors
    return col.ColoringResult(
        colors=colors, n_rounds=st.last_rounds,
        conflicts_per_round=np.array([st.last_conflicts]),
        total_conflicts=st.last_conflicts,
        n_colors=col.n_colors_used(colors),
        overflow=st.retries > 0, gather_passes=st.last_gather_passes,
        final_C=st.C, retries=st.retries, distance=1, state=st,
        degrade_rung=st.last_degrade_rung)
