"""Long-lived coloring service over many mutating graphs (DESIGN.md §7.3).

``ColoringService`` is the dynamic-graph analogue of ``serving/serve_loop``'s
engine: it owns device-resident ``DynamicColoringState``s for many named
graphs, accepts edge-update batches through ``submit`` and applies them on
``step`` (one incremental repair per batch, one version bump each), and
serves coloring-derived artifacts — the color classes consumed by vertex
kernels and the dst-bucket edge coloring consumed by the GNN scatter path —
from a version-keyed memo that mutation invalidates automatically.

Queries between steps are cheap: colors and artifacts always reflect the
last stepped version, never a half-applied batch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core import coloring as col
from repro.core import schedule
from repro.dynamic import delta
from repro.dynamic.incremental import (DynamicColoringState, _check_edges,
                                       recolor_incremental)
from repro.graphs.csr import CSRGraph, to_edge_list
from repro.obs import metrics as obs_metrics


@dataclasses.dataclass
class UpdateBatch:
    inserts: Optional[np.ndarray]
    deletes: Optional[np.ndarray]


class ColoringService:
    def __init__(self, **default_opts):
        self._states: dict[str, DynamicColoringState] = {}
        self._pending: dict[str, list[UpdateBatch]] = {}
        self._memo: dict[tuple[str, str], tuple[int, object]] = {}
        self._opts = dict(default_opts)

    # -- graph lifecycle ----------------------------------------------------

    def add_graph(self, name: str, g: CSRGraph, spec=None, **opts) -> int:
        """Encode + color ``g`` from scratch; returns the initial version.

        Routes through the ``repro.api.color`` front door with
        ``mode='incremental'`` and keeps the resulting
        ``DynamicColoringState``.  Precedence, most specific wins: per-call
        ``opts`` > explicit ``spec`` > service construction defaults (the
        defaults never override a spec the caller passed explicitly).
        """
        if name in self._states:
            raise ValueError(f"graph {name!r} already registered")
        from repro import api
        overrides = dict(opts) if spec is not None else {**self._opts,
                                                         **opts}
        mode = overrides.pop("mode", "incremental")
        if mode != "incremental":
            raise ValueError(
                f"ColoringService graphs are incremental by construction "
                f"(got mode={mode!r})")
        res = api.color(g, spec, mode=mode, **overrides)
        self._states[name] = res.state
        self._pending[name] = []
        return self._states[name].version

    def remove_graph(self, name: str) -> None:
        self._state(name)
        del self._states[name]
        del self._pending[name]
        self._memo = {k: v for k, v in self._memo.items() if k[0] != name}

    def graphs(self) -> list[str]:
        return sorted(self._states)

    def _state(self, name: str) -> DynamicColoringState:
        if name not in self._states:
            raise KeyError(f"unknown graph {name!r}; have {self.graphs()}")
        return self._states[name]

    # -- submit/step --------------------------------------------------------

    def submit(self, name: str, inserts=None, deletes=None) -> int:
        """Queue an update batch; returns the queue depth for ``name``.

        Validation happens *here*, not in step(): a malformed batch must
        bounce back to its submitter, never sit poisoning the queue."""
        st = self._state(name)
        ins = _check_edges(inserts if inserts is not None else [], st.n,
                           "inserts")
        dels = _check_edges(deletes if deletes is not None else [], st.n,
                            "deletes")
        self._pending[name].append(UpdateBatch(ins, dels))
        return len(self._pending[name])

    def pending(self, name: str) -> int:
        self._state(name)
        return len(self._pending[name])

    def step(self, name: Optional[str] = None) -> dict[str, dict]:
        """Drain pending batches (one graph, or all); returns per-graph
        repair stats of the last applied batch."""
        names = [name] if name is not None else self.graphs()
        out = {}
        for nm in names:
            t0 = time.perf_counter()
            st = self._state(nm)
            n_batches = len(self._pending[nm])
            for batch in self._pending[nm]:
                st = recolor_incremental(st, batch.inserts, batch.deletes)
            self._pending[nm] = []
            self._states[nm] = st
            out[nm] = st.summary()   # hosts the colors => blocks on device
            # per-tenant step latency (p50/p99 via step_latency(name));
            # zero-batch steps are ~free and would drown the percentiles
            if n_batches:
                obs_metrics.histogram("service.step_ms", graph=nm).observe(
                    (time.perf_counter() - t0) * 1e3)
        return out

    def step_latency(self, name: str) -> dict:
        """Latency summary of this tenant's non-empty ``step`` calls:
        {count, mean, max, p50, p99} in milliseconds (process-local)."""
        self._state(name)
        return obs_metrics.histogram("service.step_ms", graph=name).summary()

    # -- queries (always reflect the last stepped version) ------------------

    def version(self, name: str) -> int:
        return self._state(name).version

    def colors(self, name: str) -> np.ndarray:
        return self._state(name).colors

    def stats(self, name: str) -> dict:
        return self._state(name).summary()

    def graph(self, name: str) -> CSRGraph:
        """Decode the current device-resident graph (original ids)."""
        return self._memoized(name, "csr",
                              lambda st: delta.state_to_csr(st))

    def vertex_schedule(self, name: str) -> list[np.ndarray]:
        """Color classes (independent sets) of the current coloring — the
        paper's vertex-kernel execution schedule, without recoloring."""
        def build(st: DynamicColoringState):
            colors = st.colors
            return [np.nonzero(colors == c)[0]
                    for c in range(col.n_colors_used(colors))]
        return self._memoized(name, "vertex_schedule", build)

    def edge_colors(self, name: str):
        """Dst-bucket edge coloring of the current graph for conflict-free
        scatter (models.gnn.colored_segment_sum).  (edge_list, colors, k)."""
        def build(st: DynamicColoringState):
            e = to_edge_list(self.graph(name))   # shares the memoized decode
            ec, k = schedule.edge_color_by_dst(e[:, 0], e[:, 1], st.n)
            return e, ec, k
        return self._memoized(name, "edge_colors", build)

    def _memoized(self, name: str, kind: str, build):
        st = self._state(name)
        key = (name, kind)
        hit = self._memo.get(key)
        if hit is not None and hit[0] == st.version:
            obs_metrics.counter("service.memo", kind=kind,
                                outcome="hit").inc()
            return hit[1]
        obs_metrics.counter("service.memo", kind=kind, outcome="miss").inc()
        art = build(st)
        self._memo[key] = (st.version, art)
        return art
