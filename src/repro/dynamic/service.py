"""Long-lived coloring service over many mutating graphs (DESIGN.md §7.3,
§13).

``ColoringService`` is the dynamic-graph analogue of ``serving/serve_loop``'s
engine: it owns device-resident ``DynamicColoringState``s for many named
graphs, accepts edge-update batches through ``submit`` and applies them on
``step`` (one incremental repair per batch, one version bump each), and
serves coloring-derived artifacts — the color classes consumed by vertex
kernels and the dst-bucket edge coloring consumed by the GNN scatter path —
from a version-keyed, byte-budgeted LRU memo that mutation invalidates
automatically.

The submit/step queue is double-buffered: ``step`` swaps each tenant's
pending list for an empty one *before* touching the device, so a submit
racing a step lands cleanly in the next step instead of being silently
dropped mid-drain.  ``step`` itself is megabatched (DESIGN.md §13): tenants
sharing a ``megabatch.slot_key`` are stacked and advanced by ONE device
dispatch per update wave / repair loop instead of one per tenant, with
per-slot escape flags routing the rare overflowing tenant back through the
per-tenant retry path.

Queries between steps are cheap: colors and artifacts always reflect the
last stepped version, never a half-applied batch.
"""
from __future__ import annotations

import collections
import dataclasses
import sys
import time
from collections.abc import Mapping
from typing import Optional

import numpy as np

from repro.core import coloring as col
from repro.core import schedule
from repro.dynamic import delta
from repro.dynamic import megabatch
from repro.dynamic.incremental import (DynamicColoringState, _check_edges,
                                       recolor_incremental)
from repro.graphs.csr import CSRGraph, to_edge_list
from repro.obs import metrics as obs_metrics


@dataclasses.dataclass
class UpdateBatch:
    inserts: Optional[np.ndarray]
    deletes: Optional[np.ndarray]


def _nbytes(obj) -> int:
    """Recursive size estimate for cache admission (host + device arrays
    report ``nbytes``; containers add a small fixed overhead)."""
    if hasattr(obj, "nbytes"):
        return int(obj.nbytes)
    if isinstance(obj, (list, tuple)):
        return sum(_nbytes(o) for o in obj) + 64
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return sum(_nbytes(getattr(obj, f.name))
                   for f in dataclasses.fields(obj)) + 64
    return sys.getsizeof(obj, 64)


class ArtifactCache:
    """Version-keyed LRU artifact memo with a byte budget (DESIGN.md §13).

    Entries are ``(name, kind) -> (version, artifact, nbytes)``.  A hit
    requires the stored version to match the tenant's current state version
    (mutation invalidates implicitly); any hit refreshes recency.  Insertion
    evicts least-recently-used entries until the budget holds — except the
    entry just inserted, so the artifact being handed to the caller is never
    dropped in the same breath even when it alone exceeds the budget.
    Because a stale entry can never be read again (its version can't come
    back — ``restore`` re-versions above the current version precisely to
    keep this true), stale entries age out of the LRU order first.
    """

    def __init__(self, budget_bytes: int):
        self.budget_bytes = int(budget_bytes)
        self._d: "collections.OrderedDict[tuple, tuple]" = \
            collections.OrderedDict()
        self._bytes = 0

    def __len__(self) -> int:
        return len(self._d)

    @property
    def nbytes(self) -> int:
        return self._bytes

    def get(self, key: tuple, version: int):
        """The cached artifact for ``key`` at ``version``, else None."""
        hit = self._d.get(key)
        if hit is None or hit[0] != version:
            return None
        self._d.move_to_end(key)
        return hit[1]

    def put(self, key: tuple, version: int, obj) -> list:
        """Admit ``obj``; returns the list of evicted keys."""
        old = self._d.pop(key, None)
        if old is not None:
            self._bytes -= old[2]
        nb = _nbytes(obj)
        self._d[key] = (version, obj, nb)
        self._bytes += nb
        evicted = []
        while self._bytes > self.budget_bytes and len(self._d) > 1:
            k, (_, _, b) = self._d.popitem(last=False)
            self._bytes -= b
            evicted.append(k)
        return evicted

    def drop_name(self, name: str) -> None:
        for k in [k for k in self._d if k[0] == name]:
            self._bytes -= self._d.pop(k)[2]


class StepStats(Mapping):
    """Lazy per-graph repair stats returned by ``ColoringService.step``.

    Building a stats dict hosts the colors (a blocking device→host copy +
    color count), which used to sit inside the step's timed region and
    pollute ``service.step_ms``.  Values are computed on first access and
    cached; iteration and ``len`` stay free.
    """

    def __init__(self, states: dict):
        self._states = dict(states)
        self._cache: dict = {}

    def __getitem__(self, name: str) -> dict:
        if name not in self._cache:
            self._cache[name] = self._states[name].summary()
        return self._cache[name]

    def __iter__(self):
        return iter(self._states)

    def __len__(self) -> int:
        return len(self._states)

    def __repr__(self) -> str:
        return f"StepStats({sorted(self._states)})"


class ColoringService:
    def __init__(self, *, memo_budget_mb: float = 256.0,
                 megabatch: bool = True, megabatch_min: int = 2,
                 **default_opts):
        self._states: dict[str, DynamicColoringState] = {}
        self._pending: dict[str, list[UpdateBatch]] = {}
        self._memo = ArtifactCache(int(memo_budget_mb * (1 << 20)))
        self._megabatch = bool(megabatch)
        self._megabatch_min = max(2, int(megabatch_min))
        self._opts = dict(default_opts)

    # -- graph lifecycle ----------------------------------------------------

    def add_graph(self, name: str, g: CSRGraph, spec=None, **opts) -> int:
        """Encode + color ``g`` from scratch; returns the initial version.

        Routes through the ``repro.api.color`` front door with
        ``mode='incremental'`` and keeps the resulting
        ``DynamicColoringState``.  Precedence, most specific wins: per-call
        ``opts`` > explicit ``spec`` > service construction defaults (the
        defaults never override a spec the caller passed explicitly).
        """
        if name in self._states:
            raise ValueError(f"graph {name!r} already registered")
        from repro import api
        overrides = dict(opts) if spec is not None else {**self._opts,
                                                         **opts}
        mode = overrides.pop("mode", "incremental")
        if mode != "incremental":
            raise ValueError(
                f"ColoringService graphs are incremental by construction "
                f"(got mode={mode!r})")
        res = api.color(g, spec, mode=mode, **overrides)
        self._states[name] = res.state
        self._pending[name] = []
        return self._states[name].version

    def remove_graph(self, name: str) -> None:
        self._state(name)
        del self._states[name]
        del self._pending[name]
        self._memo.drop_name(name)
        # drop per-tenant observability too: a tenant re-added under this
        # name must not inherit the departed tenant's latency percentiles
        obs_metrics.remove("service.step_ms", graph=name)

    def graphs(self) -> list[str]:
        return sorted(self._states)

    def _state(self, name: str) -> DynamicColoringState:
        if name not in self._states:
            raise KeyError(f"unknown graph {name!r}; have {self.graphs()}")
        return self._states[name]

    # -- snapshot / rollback ------------------------------------------------

    def snapshot(self, name: str) -> DynamicColoringState:
        """The tenant's current immutable state; hold it, keep stepping,
        and ``restore`` later to roll back."""
        return self._state(name)

    def restore(self, name: str, state: DynamicColoringState) -> int:
        """Roll ``name`` back to a snapshot; returns the new version.

        The restored state is re-versioned *above* the tenant's current
        version: version numbers must never repeat with different contents,
        or the artifact memo would serve stale entries as fresh.
        """
        cur = self._state(name)
        if not isinstance(state, DynamicColoringState):
            raise TypeError("restore expects a DynamicColoringState")
        if state.n != cur.n:
            raise ValueError(
                f"snapshot is for a {state.n}-vertex graph; "
                f"{name!r} has {cur.n} vertices")
        st = dataclasses.replace(
            state, version=max(cur.version, state.version) + 1)
        self._states[name] = st
        return st.version

    # -- submit/step --------------------------------------------------------

    def submit(self, name: str, inserts=None, deletes=None) -> int:
        """Queue an update batch; returns the queue depth for ``name``.

        Validation happens *here*, not in step(): a malformed batch must
        bounce back to its submitter, never sit poisoning the queue."""
        st = self._state(name)
        ins = _check_edges(inserts if inserts is not None else [], st.n,
                           "inserts")
        dels = _check_edges(deletes if deletes is not None else [], st.n,
                            "deletes")
        self._pending[name].append(UpdateBatch(ins, dels))
        return len(self._pending[name])

    def pending(self, name: str) -> int:
        self._state(name)
        return len(self._pending[name])

    def step(self, name: Optional[str] = None) -> StepStats:
        """Drain pending batches (one graph, or all); returns lazy
        per-graph repair stats of the last applied batch.

        Tenants sharing a slot class (same shapes/statics, see
        ``megabatch.slot_key``) are advanced together: one device dispatch
        per update wave and one per repair loop for the whole group.
        ``service.step_ms{graph=..}`` times repair dispatch + device sync
        only — stats decoding happens lazily on access.
        """
        names = [name] if name is not None else self.graphs()
        for nm in names:
            self._state(nm)
        # double-buffer swap BEFORE device work: a submit racing this step
        # lands in the fresh list and is applied by the next step
        drained = {nm: self._pending[nm] for nm in names}
        for nm in names:
            self._pending[nm] = []

        busy = [nm for nm in names if drained[nm]]
        groups: dict[tuple, list[str]] = {}
        for nm in busy:
            groups.setdefault(megabatch.slot_key(self._states[nm]),
                              []).append(nm)

        for key, members in groups.items():
            if self._megabatch and len(members) >= self._megabatch_min:
                self._step_mega(members, drained)
            else:
                for nm in members:
                    self._step_loop(nm, drained[nm])
        return StepStats({nm: self._states[nm] for nm in names})

    def _step_loop(self, nm: str, batches: list) -> None:
        """Per-tenant path: one dispatch per batch (repair bound comes from
        the state's persisted ``max_rounds``)."""
        t0 = time.perf_counter()
        st = self._states[nm]
        for batch in batches:
            st = recolor_incremental(st, batch.inserts, batch.deletes)
        st.colors_dev.block_until_ready()
        self._states[nm] = st
        obs_metrics.histogram("service.step_ms", graph=nm).observe(
            (time.perf_counter() - t0) * 1e3)
        obs_metrics.counter("service.mega", outcome="loop").inc(len(batches))

    def _step_mega(self, members: list, drained: dict) -> None:
        """Megabatched path: every member advances in one stacked dispatch
        per wave/repair round.  Each member observes the group wall time —
        that IS the latency a tenant experiences for a batched step."""
        t0 = time.perf_counter()
        states = [self._states[nm] for nm in members]
        queues = [[(b.inserts, b.deletes) for b in drained[nm]]
                  for nm in members]
        new_states, outcomes = megabatch.step_group(states, queues)
        for st in new_states:
            st.colors_dev.block_until_ready()
        dt = (time.perf_counter() - t0) * 1e3
        for nm, st, oc in zip(members, new_states, outcomes):
            self._states[nm] = st
            obs_metrics.histogram("service.step_ms", graph=nm).observe(dt)
            for outcome, cnt in oc.items():
                if cnt:
                    obs_metrics.counter("service.mega",
                                        outcome=outcome).inc(cnt)

    def step_latency(self, name: str) -> dict:
        """Latency summary of this tenant's non-empty ``step`` calls:
        {count, mean, max, p50, p99} in milliseconds (process-local)."""
        self._state(name)
        return obs_metrics.histogram("service.step_ms", graph=name).summary()

    # -- queries (always reflect the last stepped version) ------------------

    def version(self, name: str) -> int:
        return self._state(name).version

    def colors(self, name: str) -> np.ndarray:
        return self._state(name).colors

    def stats(self, name: str) -> dict:
        return self._state(name).summary()

    def graph(self, name: str) -> CSRGraph:
        """Decode the current device-resident graph (original ids)."""
        return self._memoized(name, "csr",
                              lambda st: delta.state_to_csr(st))

    def vertex_schedule(self, name: str) -> list[np.ndarray]:
        """Color classes (independent sets) of the current coloring — the
        paper's vertex-kernel execution schedule, without recoloring."""
        def build(st: DynamicColoringState):
            colors = st.colors
            return [np.nonzero(colors == c)[0]
                    for c in range(col.n_colors_used(colors))]
        return self._memoized(name, "vertex_schedule", build)

    def edge_colors(self, name: str):
        """Dst-bucket edge coloring of the current graph for conflict-free
        scatter (models.gnn.colored_segment_sum).  (edge_list, colors, k)."""
        def build(st: DynamicColoringState):
            e = to_edge_list(self.graph(name))   # shares the memoized decode
            ec, k = schedule.edge_color_by_dst(e[:, 0], e[:, 1], st.n)
            return e, ec, k
        return self._memoized(name, "edge_colors", build)

    def _memoized(self, name: str, kind: str, build):
        st = self._state(name)
        key = (name, kind)
        hit = self._memo.get(key, st.version)
        if hit is not None:
            obs_metrics.counter("service.memo", kind=kind,
                                outcome="hit").inc()
            return hit
        obs_metrics.counter("service.memo", kind=kind, outcome="miss").inc()
        art = build(st)
        for _, ekind in self._memo.put(key, st.version, art):
            obs_metrics.counter("service.memo", kind=ekind,
                                outcome="evict").inc()
        return art
