"""Long-lived coloring service over many mutating graphs (DESIGN.md §7.3,
§13).

``ColoringService`` is the dynamic-graph analogue of ``serving/serve_loop``'s
engine: it owns device-resident ``DynamicColoringState``s for many named
graphs, accepts edge-update batches through ``submit`` and applies them on
``step`` (one incremental repair per batch, one version bump each), and
serves coloring-derived artifacts — the color classes consumed by vertex
kernels and the dst-bucket edge coloring consumed by the GNN scatter path —
from a version-keyed, byte-budgeted LRU memo that mutation invalidates
automatically.

The submit/step queue is double-buffered: ``step`` swaps each tenant's
pending list for an empty one *before* touching the device, so a submit
racing a step lands cleanly in the next step instead of being silently
dropped mid-drain.  ``step`` itself is megabatched (DESIGN.md §13): tenants
sharing a ``megabatch.slot_key`` are stacked and advanced by ONE device
dispatch per update wave / repair loop instead of one per tenant, with
per-slot escape flags routing the rare overflowing tenant back through the
per-tenant retry path.

Queries between steps are cheap: colors and artifacts always reflect the
last stepped version, never a half-applied batch.

Steps are **transactional** (DESIGN.md §14): state is immutable-by-
convention, so a step builds candidate states off to the side and commits
only after the whole drain (and optional post-step verification) succeeds.
Any error — injected fault, improper output, real bug — rolls the tenant
back bit-exactly to its pre-step state and requeues the drained batches at
the *front* of its queue; ``quarantine_after`` consecutive failures freeze
the tenant (steps no-op with a structured reason, submits raise
``QuarantinedError``) and preserve the unapplied batches in a dead-letter
queue that ``heal(name)`` replays after the cause is gone.  Budget
exhaustion (``max_cap_retries`` / ``max_ovf_growth``) never rolls back — it
degrades through the ``resilience.ladder`` rungs and commits a proper,
attributed result.
"""
from __future__ import annotations

import collections
import dataclasses
import sys
import time
from collections.abc import Mapping
from typing import Optional

import numpy as np

from repro.core import coloring as col
from repro.core import schedule
from repro.dynamic import delta
from repro.dynamic import megabatch
from repro.dynamic.incremental import (DynamicColoringState, _check_edges,
                                       recolor_incremental)  # noqa: F401
from repro.dynamic.sharded import ShardedColoringState
from repro.graphs.csr import CSRGraph, FILL, to_edge_list
from repro.obs import metrics as obs_metrics
from repro.resilience import faults, ladder
from repro.resilience.errors import (CapRetryExhausted, HealFailed,
                                     ImproperColoring, InjectedFault,
                                     OvfGrowthExhausted, QuarantinedError)
from repro.resilience.quarantine import (DeadLetter, DeadLetterQueue,
                                         QuarantineEntry)


@dataclasses.dataclass
class UpdateBatch:
    inserts: Optional[np.ndarray]
    deletes: Optional[np.ndarray]


def _classify(exc: BaseException) -> str:
    """Structured failure reason for rollback/quarantine records and the
    ``resilience.rollback{reason=..}`` counter label."""
    if isinstance(exc, InjectedFault):
        return "injected"
    if isinstance(exc, CapRetryExhausted):
        return "cap_exhausted"
    if isinstance(exc, OvfGrowthExhausted):
        return "ovf_exhausted"
    if isinstance(exc, ImproperColoring):
        return "improper"
    return "error"


def _corrupt_colors_sharded(st: ShardedColoringState) -> ShardedColoringState:
    """Sharded ``color.corrupt``: same deterministic conflict injection,
    restricted to shard 0 rows with a *local* neighbor so the copied color
    is a guaranteed same-shard conflict regardless of ghost freshness."""
    ell0 = np.asarray(st.ell[0])
    n0 = min(st.blk, st.n)
    local = (ell0 != FILL) & (ell0 < st.n_loc)
    live_rows = np.nonzero(local[:n0].any(axis=1))[0]
    if len(live_rows) == 0:
        return st
    r = faults.rng("color.corrupt")
    k = min(max(1, int(faults.param("color.corrupt", "k", 1))),
            len(live_rows))
    colors = np.asarray(st.colors_tab[0])
    ct = st.colors_tab
    for v in r.choice(live_rows, size=k, replace=False):
        row = ell0[int(v)]
        w = int(row[local[int(v)]][0])
        ct = ct.at[0, int(v)].set(int(colors[w]))
    return dataclasses.replace(st, colors_tab=ct)


def _corrupt_colors(st: DynamicColoringState) -> DynamicColoringState:
    """``color.corrupt`` payload: copy a live ELL neighbor's color onto
    ``k`` vertices (guaranteed conflicts), drawn from the site's
    deterministic RNG so replays corrupt identically."""
    if isinstance(st, ShardedColoringState):
        return _corrupt_colors_sharded(st)
    ell = np.asarray(st.ell[:st.n])
    live_rows = np.nonzero((ell != FILL).any(axis=1))[0]
    if len(live_rows) == 0:
        return st
    r = faults.rng("color.corrupt")
    k = min(max(1, int(faults.param("color.corrupt", "k", 1))),
            len(live_rows))
    colors = np.asarray(st.colors_dev)
    cd = st.colors_dev
    for v in r.choice(live_rows, size=k, replace=False):
        row = ell[int(v)]
        w = int(row[row != FILL][0])
        cd = cd.at[int(v)].set(int(colors[w]))
    return dataclasses.replace(st, colors_dev=cd)


def _nbytes(obj) -> int:
    """Recursive size estimate for cache admission (host + device arrays
    report ``nbytes``; containers add a small fixed overhead)."""
    if hasattr(obj, "nbytes"):
        return int(obj.nbytes)
    if isinstance(obj, (list, tuple)):
        return sum(_nbytes(o) for o in obj) + 64
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return sum(_nbytes(getattr(obj, f.name))
                   for f in dataclasses.fields(obj)) + 64
    return sys.getsizeof(obj, 64)


class ArtifactCache:
    """Version-keyed LRU artifact memo with a byte budget (DESIGN.md §13).

    Entries are ``(name, kind) -> (version, artifact, nbytes)``.  A hit
    requires the stored version to match the tenant's current state version
    (mutation invalidates implicitly); any hit refreshes recency.  Insertion
    evicts least-recently-used entries until the budget holds — except the
    entry just inserted, so the artifact being handed to the caller is never
    dropped in the same breath even when it alone exceeds the budget.
    Because a stale entry can never be read again (its version can't come
    back — ``restore`` re-versions above the current version precisely to
    keep this true), stale entries age out of the LRU order first.
    """

    def __init__(self, budget_bytes: int):
        self.budget_bytes = int(budget_bytes)
        self._d: "collections.OrderedDict[tuple, tuple]" = \
            collections.OrderedDict()
        self._bytes = 0

    def __len__(self) -> int:
        return len(self._d)

    @property
    def nbytes(self) -> int:
        return self._bytes

    def get(self, key: tuple, version: int):
        """The cached artifact for ``key`` at ``version``, else None."""
        hit = self._d.get(key)
        if hit is None or hit[0] != version:
            return None
        self._d.move_to_end(key)
        return hit[1]

    def put(self, key: tuple, version: int, obj) -> list:
        """Admit ``obj``; returns the list of evicted keys."""
        old = self._d.pop(key, None)
        if old is not None:
            self._bytes -= old[2]
        nb = _nbytes(obj)
        self._d[key] = (version, obj, nb)
        self._bytes += nb
        evicted = []
        while self._bytes > self.budget_bytes and len(self._d) > 1:
            k, (_, _, b) = self._d.popitem(last=False)
            self._bytes -= b
            evicted.append(k)
        return evicted

    def drop_name(self, name: str) -> None:
        for k in [k for k in self._d if k[0] == name]:
            self._bytes -= self._d.pop(k)[2]


class StepStats(Mapping):
    """Lazy per-graph repair stats returned by ``ColoringService.step``.

    Building a stats dict hosts the colors (a blocking device→host copy +
    color count), which used to sit inside the step's timed region and
    pollute ``service.step_ms``.  Values are computed on first access and
    cached; iteration and ``len`` stay free.

    ``notes`` carries per-tenant resilience outcomes merged into the stats
    dict: ``{"rolled_back": reason}`` for a tenant whose drain failed and
    was requeued, ``{"quarantined": reason}`` for a frozen tenant whose
    step was a no-op.
    """

    def __init__(self, states: dict, notes: Optional[dict] = None):
        self._states = dict(states)
        self._notes = dict(notes or {})
        self._cache: dict = {}

    def __getitem__(self, name: str) -> dict:
        if name not in self._cache:
            d = self._states[name].summary()
            d.update(self._notes.get(name, {}))
            self._cache[name] = d
        return self._cache[name]

    def __iter__(self):
        return iter(self._states)

    def __len__(self) -> int:
        return len(self._states)

    def __repr__(self) -> str:
        return f"StepStats({sorted(self._states)})"


class ColoringService:
    def __init__(self, *, memo_budget_mb: float = 256.0,
                 megabatch: bool = True, megabatch_min: int = 2,
                 quarantine_after: int = 2,
                 verify_steps: Optional[bool] = None,
                 dead_letter_cap: int = 64,
                 **default_opts):
        self._states: dict[str, DynamicColoringState] = {}
        self._pending: dict[str, list[UpdateBatch]] = {}
        self._memo = ArtifactCache(int(memo_budget_mb * (1 << 20)))
        self._megabatch = bool(megabatch)
        self._megabatch_min = max(2, int(megabatch_min))
        # resilience knobs: consecutive step failures before a tenant is
        # frozen; post-step properness verification (None: auto — on iff
        # fault injection is armed, so production steps pay nothing)
        self._quarantine_after = max(1, int(quarantine_after))
        self._verify_steps = verify_steps
        self._quarantine: dict[str, QuarantineEntry] = {}
        self._failures: dict[str, int] = {}
        self._dlq = DeadLetterQueue(cap=dead_letter_cap)
        self._dl_seq = 0
        self._opts = dict(default_opts)

    # -- graph lifecycle ----------------------------------------------------

    def add_graph(self, name: str, g: CSRGraph, spec=None, *,
                  mesh=None, axis: Optional[str] = None, **opts) -> int:
        """Encode + color ``g`` from scratch; returns the initial version.

        Routes through the ``repro.api.color`` front door with
        ``mode='incremental'`` and keeps the resulting
        ``DynamicColoringState``.  Precedence, most specific wins: per-call
        ``opts`` > explicit ``spec`` > service construction defaults (the
        defaults never override a spec the caller passed explicitly).

        Passing ``mesh=`` shards the tenant over that device mesh (a
        ``ShardedColoringState``, DESIGN.md §15): with no explicit spec the
        backend defaults to ``'distributed'``, and subsequent steps route
        the tenant's batches through ``recolor_sharded``.
        """
        if name in self._states:
            raise ValueError(f"graph {name!r} already registered")
        from repro import api
        overrides = dict(opts) if spec is not None else {**self._opts,
                                                         **opts}
        mode = overrides.pop("mode", "incremental")
        if mode != "incremental":
            raise ValueError(
                f"ColoringService graphs are incremental by construction "
                f"(got mode={mode!r})")
        if mesh is not None and spec is None:
            overrides.setdefault("backend", "distributed")
        res = api.color(g, spec, mode=mode, mesh=mesh, axis=axis,
                        **overrides)
        self._states[name] = res.state
        self._pending[name] = []
        return self._states[name].version

    def remove_graph(self, name: str) -> None:
        self._state(name)
        del self._states[name]
        del self._pending[name]
        self._memo.drop_name(name)
        self._quarantine.pop(name, None)
        self._failures.pop(name, None)
        self._dlq.drain(name)
        # drop per-tenant observability too: a tenant re-added under this
        # name must not inherit the departed tenant's latency percentiles
        obs_metrics.remove("service.step_ms", graph=name)

    def graphs(self) -> list[str]:
        return sorted(self._states)

    def _state(self, name: str) -> DynamicColoringState:
        if name not in self._states:
            raise KeyError(f"unknown graph {name!r}; have {self.graphs()}")
        return self._states[name]

    # -- snapshot / rollback ------------------------------------------------

    def snapshot(self, name: str) -> DynamicColoringState:
        """The tenant's current immutable state; hold it, keep stepping,
        and ``restore`` later to roll back."""
        return self._state(name)

    def restore(self, name: str, state: DynamicColoringState) -> int:
        """Roll ``name`` back to a snapshot; returns the new version.

        The restored state is re-versioned *above* the tenant's current
        version: version numbers must never repeat with different contents,
        or the artifact memo would serve stale entries as fresh.

        Restoring **flushes the tenant's pending queue**: queued batches
        were submitted against the state line being abandoned, and applying
        them to the snapshot would silently fork history.  Resubmit what
        still applies.  The tenant's ``step_ms`` latency history is also
        cleared — post-restore timings describe a different state and must
        not be averaged into the old tail.  Quarantine is *not* lifted
        (``heal`` is the re-admission path), but the consecutive-failure
        count resets.
        """
        cur = self._state(name)
        if not isinstance(state, (DynamicColoringState,
                                  ShardedColoringState)):
            raise TypeError("restore expects a DynamicColoringState or "
                            "ShardedColoringState")
        if state.n != cur.n:
            raise ValueError(
                f"snapshot is for a {state.n}-vertex graph; "
                f"{name!r} has {cur.n} vertices")
        st = dataclasses.replace(
            state, version=max(cur.version, state.version) + 1)
        self._states[name] = st
        self._pending[name] = []
        self._failures[name] = 0
        obs_metrics.histogram("service.step_ms", graph=name).clear()
        return st.version

    # -- submit/step --------------------------------------------------------

    def submit(self, name: str, inserts=None, deletes=None) -> int:
        """Queue an update batch; returns the queue depth for ``name``.

        Validation happens *here*, not in step(): a malformed batch must
        bounce back to its submitter, never sit poisoning the queue.
        Strict host-side checks name the tenant in every error: integer
        dtype, (k, 2) shape, ids in range, and no self-loops in inserts
        (deletes of a nonexistent edge are a harmless no-op, so they stay
        lenient beyond shape/range).  Submitting to a quarantined tenant
        raises ``QuarantinedError`` immediately — its queue is frozen."""
        st = self._state(name)
        q = self._quarantine.get(name)
        if q is not None:
            raise QuarantinedError(name, q.reason, q.since_version)
        ins = _check_edges(inserts if inserts is not None else [], st.n,
                           "inserts", tenant=name, strict=True)
        dels = _check_edges(deletes if deletes is not None else [], st.n,
                            "deletes", tenant=name, strict=True)
        faults.check("service.submit", tenant=name)
        self._pending[name].append(UpdateBatch(ins, dels))
        return len(self._pending[name])

    def pending(self, name: str) -> int:
        self._state(name)
        return len(self._pending[name])

    def step(self, name: Optional[str] = None) -> StepStats:
        """Drain pending batches (one graph, or all); returns lazy
        per-graph repair stats of the last applied batch.

        Tenants sharing a slot class (same shapes/statics, see
        ``megabatch.slot_key``) are advanced together: one device dispatch
        per update wave and one per repair loop for the whole group.
        ``service.step_ms{graph=..}`` times repair dispatch + device sync
        only — stats decoding happens lazily on access.
        """
        names = [name] if name is not None else self.graphs()
        for nm in names:
            self._state(nm)
        notes: dict[str, dict] = {}
        # quarantined tenants are frozen: their queue stays untouched and
        # the stats row carries the structured reason instead of progress
        live = []
        for nm in names:
            q = self._quarantine.get(nm)
            if q is not None:
                notes[nm] = {"quarantined": q.reason}
            else:
                live.append(nm)
        # double-buffer swap BEFORE device work: a submit racing this step
        # lands in the fresh list and is applied by the next step
        drained = {nm: self._pending[nm] for nm in live}
        for nm in live:
            self._pending[nm] = []

        busy = [nm for nm in live if drained[nm]]
        groups: dict[tuple, list[str]] = {}
        for nm in busy:
            st = self._states[nm]
            # sharded tenants never megabatch (their dispatch is already
            # mesh-wide); a singleton key routes them to the per-tenant path
            key = (("sharded", nm) if isinstance(st, ShardedColoringState)
                   else megabatch.slot_key(st))
            groups.setdefault(key, []).append(nm)

        for key, members in groups.items():
            if self._megabatch and len(members) >= self._megabatch_min:
                self._step_mega(members, drained, notes)
            else:
                for nm in members:
                    self._step_tx(nm, drained[nm], notes)
        return StepStats({nm: self._states[nm] for nm in names}, notes)

    # -- transactional step machinery (DESIGN.md §14) -----------------------

    def _verify(self) -> bool:
        """Post-step properness verification: explicit knob wins; the
        ``None`` default resolves to "on iff fault injection is armed", so
        production steps never pay the decode+check."""
        if self._verify_steps is not None:
            return self._verify_steps
        return faults.active()

    def _apply_one(self, st: DynamicColoringState, batch: UpdateBatch):
        """One batch through the degradation ladder; returns (state, rung).
        With budgets unset and faults off this is exactly
        ``recolor_incremental`` (rung 0) — bit-identical to the pre-§14
        step path."""
        return ladder.apply_with_ladder(st, batch.inserts, batch.deletes)

    def _post_step(self, nm: str,
                   st: DynamicColoringState) -> DynamicColoringState:
        """Pre-commit hook: the ``color.corrupt`` fault perturbs the
        candidate here (never the committed state), and verification
        rejects any improper candidate before it can be served."""
        if faults.fires("color.corrupt", tenant=nm):
            st = _corrupt_colors(st)
        if self._verify():
            if not col.is_proper(delta.state_to_csr(st), st.colors):
                raise ImproperColoring(nm, st.version)
        return st

    def _commit(self, nm: str, st: DynamicColoringState) -> None:
        self._states[nm] = st
        self._failures[nm] = 0

    def _rollback(self, nm: str, batches: list, exc: BaseException,
                  notes: dict) -> None:
        """Discard the failed drain's candidates (the committed state was
        never touched — immutability IS the rollback), requeue the batches
        at the front, and freeze the tenant after repeated failures."""
        reason = _classify(exc)
        obs_metrics.counter("resilience.rollback", reason=reason).inc()
        n = self._failures.get(nm, 0) + 1
        self._failures[nm] = n
        if n >= self._quarantine_after:
            # freeze: every unapplied batch — this drain plus anything
            # submitted since the swap — goes to the dead-letter queue
            # verbatim, as the forensic record and heal's replay source
            letter = tuple((b.inserts, b.deletes)
                           for b in list(batches) + self._pending[nm])
            self._dl_seq += 1
            self._dlq.push(DeadLetter(
                tenant=nm, batches=letter, reason=reason, error=repr(exc),
                version=self._states[nm].version, seq=self._dl_seq))
            self._quarantine[nm] = QuarantineEntry(
                reason=reason, error=repr(exc),
                since_version=self._states[nm].version, failures=n)
            self._pending[nm] = []
            obs_metrics.counter("resilience.quarantine", reason=reason).inc()
            notes[nm] = {"rolled_back": reason, "quarantined": reason}
        else:
            self._pending[nm] = list(batches) + self._pending[nm]
            notes[nm] = {"rolled_back": reason}

    def _step_tx(self, nm: str, batches: list, notes: dict) -> None:
        """Per-tenant transactional drain: one dispatch per batch (repair
        bound comes from the state's persisted ``max_rounds``); commit only
        after every batch applied and the candidate verified."""
        before = self._states[nm]
        t0 = time.perf_counter()
        try:
            faults.check("service.step", tenant=nm)
            st = before
            for batch in batches:
                st, _ = self._apply_one(st, batch)
            st = self._post_step(nm, st)
            st.colors_dev.block_until_ready()
        except Exception as exc:
            self._rollback(nm, batches, exc, notes)
            return
        self._commit(nm, st)
        hb = (getattr(st, "total_halo_bytes", 0)
              - getattr(before, "total_halo_bytes", 0))
        if hb > 0:
            obs_metrics.counter("service.halo_bytes", tenant=nm).inc(hb)
        obs_metrics.histogram("service.step_ms", graph=nm).observe(
            (time.perf_counter() - t0) * 1e3)
        obs_metrics.counter("service.mega", outcome="loop").inc(len(batches))

    def _step_mega(self, members: list, drained: dict, notes: dict) -> None:
        """Megabatched path: every member advances in one stacked dispatch
        per wave/repair round.  Each member observes the group wall time —
        that IS the latency a tenant experiences for a batched step.

        ``step_group`` is functional (nothing commits until it returns), so
        a mid-group error leaves every member's state untouched; the group
        then falls back to per-tenant transactional drains, which isolate
        the failing tenant instead of wedging its whole slot class."""
        t0 = time.perf_counter()
        try:
            faults.check("service.step", group=",".join(members))
            states = [self._states[nm] for nm in members]
            queues = [[(b.inserts, b.deletes) for b in drained[nm]]
                      for nm in members]
            new_states, outcomes = megabatch.step_group(states, queues)
            for st in new_states:
                st.colors_dev.block_until_ready()
        except Exception:
            obs_metrics.counter("service.mega", outcome="group_fail").inc()
            for nm in members:
                self._step_tx(nm, drained[nm], notes)
            return
        dt = (time.perf_counter() - t0) * 1e3
        for nm, st, oc in zip(members, new_states, outcomes):
            try:
                st = self._post_step(nm, st)
            except Exception as exc:
                self._rollback(nm, drained[nm], exc, notes)
                continue
            self._commit(nm, st)
            obs_metrics.histogram("service.step_ms", graph=nm).observe(dt)
            for outcome, cnt in oc.items():
                if cnt:
                    obs_metrics.counter("service.mega",
                                        outcome=outcome).inc(cnt)

    def step_latency(self, name: str) -> dict:
        """Latency summary of this tenant's non-empty ``step`` calls:
        {count, mean, max, p50, p99} in milliseconds (process-local)."""
        self._state(name)
        return obs_metrics.histogram("service.step_ms", graph=name).summary()

    # -- quarantine / heal --------------------------------------------------

    def quarantined(self, name: Optional[str] = None):
        """The tenant's ``QuarantineEntry`` (None if healthy), or the full
        {name: entry} map when called without a name."""
        if name is None:
            return dict(self._quarantine)
        self._state(name)
        return self._quarantine.get(name)

    def dead_letters(self, name: Optional[str] = None) -> list:
        """Preserved unapplied drains (``DeadLetter`` records), oldest
        first; optionally filtered to one tenant."""
        return self._dlq.letters(name)

    def export_dead_letters(self, path) -> int:
        """Write the dead-letter queue as JSONL (CI chaos artifacts);
        returns the number of letters written."""
        return self._dlq.export_jsonl(path)

    def heal(self, name: str, mode: str = "replay") -> int:
        """Re-admit a quarantined tenant; returns the healed version.

        ``mode='replay'`` (default) re-applies the tenant's dead-lettered
        batches from its last-good state through the degradation ladder.
        Because states are deterministic functions of (state, batch), a
        replay whose cause is gone (fault disarmed, budget raised via
        snapshot surgery) commits **bit-identical** colors and versions to
        the run that never failed; success drains the tenant's dead
        letters.  If replay fails or verifies improper, it falls back to
        ``mode='scratch'``: a from-scratch recolor of the *current* graph —
        the dead-lettered updates stay unapplied and their letters are kept
        for inspection.  Either path commits only an oracle-verified proper
        coloring; otherwise ``HealFailed`` and the tenant stays frozen.
        """
        cur = self._state(name)
        if name not in self._quarantine:
            raise ValueError(f"graph {name!r} is not quarantined")
        if mode not in ("replay", "scratch"):
            raise ValueError(f"unknown heal mode {mode!r}; "
                             f"known: replay, scratch")
        if mode == "replay":
            st = cur
            try:
                for letter in self._dlq.letters(name):
                    for ins, dels in letter.batches:
                        st, _ = ladder.apply_with_ladder(st, ins, dels)
                st.colors_dev.block_until_ready()
                if not col.is_proper(delta.state_to_csr(st), st.colors):
                    raise ImproperColoring(name, st.version)
            except Exception:
                mode = "scratch"    # the cause is still live; fall through
            else:
                self._dlq.drain(name)
                return self._readmit(name, st, "replay")
        try:
            st = ladder.scratch_state(cur)
            st.colors_dev.block_until_ready()
            if not col.is_proper(delta.state_to_csr(st), st.colors):
                raise ImproperColoring(name, st.version)
        except Exception as exc:
            raise HealFailed(name, repr(exc)) from exc
        return self._readmit(name, st, "scratch")

    def _readmit(self, name: str, st: DynamicColoringState,
                 mode: str) -> int:
        del self._quarantine[name]
        self._failures[name] = 0
        self._states[name] = st
        obs_metrics.counter("resilience.heal", mode=mode).inc()
        return st.version

    # -- queries (always reflect the last stepped version) ------------------

    def version(self, name: str) -> int:
        return self._state(name).version

    def colors(self, name: str) -> np.ndarray:
        return self._state(name).colors

    def stats(self, name: str) -> dict:
        return self._state(name).summary()

    def graph(self, name: str) -> CSRGraph:
        """Decode the current device-resident graph (original ids)."""
        return self._memoized(name, "csr",
                              lambda st: delta.state_to_csr(st))

    def vertex_schedule(self, name: str) -> list[np.ndarray]:
        """Color classes (independent sets) of the current coloring — the
        paper's vertex-kernel execution schedule, without recoloring."""
        def build(st: DynamicColoringState):
            colors = st.colors
            return [np.nonzero(colors == c)[0]
                    for c in range(col.n_colors_used(colors))]
        return self._memoized(name, "vertex_schedule", build)

    def edge_colors(self, name: str):
        """Dst-bucket edge coloring of the current graph for conflict-free
        scatter (models.gnn.colored_segment_sum).  (edge_list, colors, k)."""
        def build(st: DynamicColoringState):
            e = to_edge_list(self.graph(name))   # shares the memoized decode
            ec, k = schedule.edge_color_by_dst(e[:, 0], e[:, 1], st.n)
            return e, ec, k
        return self._memoized(name, "edge_colors", build)

    def _memoized(self, name: str, kind: str, build):
        st = self._state(name)
        key = (name, kind)
        hit = self._memo.get(key, st.version)
        if hit is not None:
            obs_metrics.counter("service.memo", kind=kind,
                                outcome="hit").inc()
            return hit
        obs_metrics.counter("service.memo", kind=kind, outcome="miss").inc()
        art = build(st)
        for _, ekind in self._memo.put(key, st.version, art):
            obs_metrics.counter("service.memo", kind=ekind,
                                outcome="evict").inc()
        return art
