"""Dynamic-graph incremental recoloring (DESIGN.md §7).

The static pipeline colors a graph once, from scratch.  Production graphs
mutate: edges arrive and leave continuously, and a from-scratch recoloring on
every batch throws away the near-fixed-point coloring already in hand.  This
package keeps a *device-resident* mutable encoding (ELL slots + COO overflow
spill) and repairs the coloring with the frontier-compacted fused RSOC pass,
seeded only from the endpoints of changed edges — work proportional to the
delta, not the graph.

  delta.py        fixed-shape batched edge insert/delete against ELL+overflow
  incremental.py  DynamicColoringState + recolor_incremental
  megabatch.py    slot-class stacking: one device dispatch steps N tenants
  service.py      ColoringService: long-lived multi-graph engine with a
                  double-buffered submit/step queue, megabatched stepping,
                  and a byte-budgeted version-memoized artifact cache
  sharded.py      ShardedColoringState + recolor_sharded: the mutable
                  encoding laid out per-shard over a device mesh, repaired
                  with one boundary-sized collective per round
"""
from repro.dynamic.incremental import (  # noqa: F401
    DynamicColoringState, dynamic_state, recolor_incremental,
)
from repro.dynamic.delta import state_to_csr  # noqa: F401
from repro.dynamic.megabatch import slot_key, step_group  # noqa: F401
from repro.dynamic.service import ArtifactCache, ColoringService  # noqa: F401
from repro.dynamic.sharded import (  # noqa: F401
    ShardedColoringState, recolor_sharded, sharded_state,
)
